"""Total-vs-Kernel decomposition (the paper's second key observation: 4.87x
with transfers vs 37.4x without, E=2%).

Sweeps the wave size (pairs moved host->device per round trip) and reports
the kernel-time fraction — the paper's "Kernel" bar divided by its "Total"
bar.  Larger waves amortize the scatter/gather exactly as the paper's
parallel CPU->DPU transfers do."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core.aligner import WFAligner
from repro.core.pim import PIMBatchAligner
from repro.data.reads import ReadPairSpec, generate_pairs


def run(pairs: int = 8192, read_len: int = 100,
        edit_frac: float = 0.02) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=2)
    P, plen, T, tlen = generate_pairs(spec)
    al = WFAligner(wfa_paper.pen, backend="ring", edit_frac=edit_frac)

    rows: list[Row] = []
    for wave in (256, 1024, 4096, pairs):
        ex = PIMBatchAligner(al, chunk_pairs=wave)
        ex.run_arrays(P[:wave], plen[:wave], T[:wave], tlen[:wave])  # warm
        _, stats = ex.run_arrays(P, plen, T, tlen)
        frac = stats.t_kernel / stats.t_total
        rows.append((f"transfer/wave{wave}",
                     stats.t_total / pairs * 1e6,
                     f"kernel_frac={frac:.2f} "
                     f"in={stats.bytes_in / 1e6:.1f}MB "
                     f"out={stats.bytes_out / 1e6:.2f}MB"))
    return rows
