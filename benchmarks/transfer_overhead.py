"""Total-vs-Kernel decomposition (the paper's second key observation: 4.87x
with transfers vs 37.4x without, E=2%), now measured both ways the engine
can run:

* **sync** — blocking ``align()``: pack -> device_put -> kernel -> gather,
  one wave at a time; the kernel-time fraction is the paper's "Kernel" bar
  divided by its "Total" bar.
* **streamed** — ``engine.stream()``: host packing of wave N+1 overlaps the
  in-flight kernel of wave N (the paper's parallel CPU->DPU transfers
  overlapped with execution), so the sync-vs-streamed wall-clock ratio is
  the overlap win, measured directly.

Sweeps the wave size (pairs moved host->device per round trip): larger
waves amortize the scatter/gather, smaller waves give the pipeline more
chances to overlap."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine
from repro.core.session import run_streamed
from repro.data.reads import ReadPairSpec, generate_pairs


def _sync(eng, P, plen, T, tlen):
    t0 = time.perf_counter()
    res = eng.align_packed(P, plen, T, tlen)
    return res.scores, res.stats, time.perf_counter() - t0


def run(pairs: int = 8192, read_len: int = 100,
        edit_frac: float = 0.02) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=2)
    P, plen, T, tlen = generate_pairs(spec)

    rows: list[Row] = []
    waves = [w for w in (256, 1024, 4096) if w < pairs] + [pairs]
    for wave in waves:
        eng = AlignmentEngine(wfa_paper.pen, backend="ring",
                              edit_frac=edit_frac, chunk_pairs=wave)
        eng.align_packed(P[:wave], plen[:wave], T[:wave], tlen[:wave])  # warm
        # interleaved best-of-2 per mode: wall-clock noise on shared hosts
        # otherwise swamps the few-percent overlap signal.  The reported
        # stats come from the best sync run so kernel_frac matches sync=.
        scores, stats, t_sync = _sync(eng, P, plen, T, tlen)
        streamed, _, _, t_stream = run_streamed(eng, P, plen, T, tlen,
                                                submit_pairs=wave)
        _, stats2, t_sync2 = _sync(eng, P, plen, T, tlen)
        if t_sync2 < t_sync:
            t_sync, stats = t_sync2, stats2
        t_stream = min(t_stream,
                       run_streamed(eng, P, plen, T, tlen,
                                    submit_pairs=wave)[3])
        assert np.array_equal(scores, streamed), "sync/stream score mismatch"
        frac = stats.t_kernel / max(stats.pim.t_total, 1e-12)
        rows.append((f"transfer/wave{wave}",
                     t_sync / pairs * 1e6,
                     f"kernel_frac={frac:.2f} "
                     f"sync={t_sync:.3f}s stream={t_stream:.3f}s "
                     f"overlap={t_sync / max(t_stream, 1e-12):.2f}x "
                     f"in={stats.bytes_in / 1e6:.1f}MB "
                     f"out={stats.bytes_out / 1e6:.2f}MB"))
    return rows
