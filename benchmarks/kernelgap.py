"""Kernel-vs-ring gap tracker: throughput ratio + parity + pruning gate.

Pre-PR the Pallas kernel backend ran two orders of magnitude behind the
jnp ring solver in interpret mode: its extension step fetched characters
with a one-hot compare-and-reduce (materializing ``[B, K, L]`` per LCP
trip), which interpret mode executes eagerly.  The fused-grid kernel now
defaults to an index gather off-TPU (``take_along_axis`` discharges fine
under interpret) and the gap flips — the kernel *beats* the ring because
its per-block early exit retires finished blocks while the jnp solver's
whole-batch loop keeps stepping.

This suite tracks that ratio on every push, plus the two correctness
properties the rewrite must preserve:

* **ratio** — kernel/ring pairs-per-second must stay >= ``RATIO_GATE`` x
  the pre-PR baseline ratio (``BASELINE_RATIO``, from
  BENCH_20260801T164232Z: kernel at ~1% of ring throughput);
* **parity** — scores *and* CIGARs bit-identical kernel-vs-ring on an
  {edit, gap-affine} grid (exact alignment, no tolerance to pick);
* **pruning win** — ``ring/affine/adaptive`` >= ``ring/affine/exact`` on
  the divergent-mix workload.  Masked-lane pruning used to *lose* here
  (the mask work cost more than it saved); the compacting band
  (``backend_opts={"band_cap": "auto"}``) shrinks the vector width to
  the heuristic's own radius, which is what flips it.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import Row, emit, rows_from_json, time_fn
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine
from repro.core.scoring import AdaptiveBand, Edit
from repro.data.reads import ReadPairSpec, generate_pairs

# Pre-PR interpret-mode gap (BENCH_20260801T164232Z, b1024 L100 E0.02):
# ring 211 us/call vs kernel 20,153 us/call -> kernel at ~1.05% of ring.
BASELINE_RATIO = 211.0 / 20153.0
RATIO_GATE = 10.0               # fused kernel must hold >= 10x that ratio
ONEHOT_SLICE = 64               # pairs for the informational one-hot row


def _divergent_mix(n_pairs: int, read_len: int, edit_frac: float, seed: int):
    """Half related mates (within the E budget), half unrelated random
    pairs — the workload where pruning pays and exact alignment walks the
    full band to ``s_max``."""
    half = n_pairs // 2
    P, plen, T, tlen = generate_pairs(ReadPairSpec(
        n_pairs=half, read_len=read_len, edit_frac=edit_frac, seed=seed))
    rng = np.random.default_rng(seed + 1)
    bases = np.frombuffer(b"ACGT", np.uint8).astype(np.int32)
    Pr = bases[rng.integers(0, 4, size=(n_pairs - half, read_len))]
    Tr = bases[rng.integers(0, 4, size=(n_pairs - half, read_len))]
    width = max(P.shape[1], T.shape[1], read_len)

    def fit(a):
        out = np.zeros((a.shape[0], width), np.int32)
        out[:, :a.shape[1]] = a
        return out

    Lr = np.full(n_pairs - half, read_len, np.int32)
    return (np.concatenate([fit(P), fit(Pr)]),
            np.concatenate([plen, Lr]),
            np.concatenate([fit(T), fit(Tr)]),
            np.concatenate([tlen, Lr]))


def _pps(eng, P, plen, T, tlen, n_pairs):
    eng.align_packed(P, plen, T, tlen)           # compile / warm the cache
    sec = time_fn(lambda: eng.align_packed(P, plen, T, tlen).scores,
                  warmup=1, iters=3)
    return n_pairs / sec, sec


def run(pairs: int = 256, read_len: int = 256,
        edit_frac: float = 0.03, onehot: bool = True) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=11)
    P, plen, T, tlen = generate_pairs(spec)
    rows: list[Row] = []

    # -- throughput: ring vs fused kernel, edit distance -------------------
    ring = AlignmentEngine(Edit(), backend="ring", edit_frac=edit_frac)
    kern = AlignmentEngine(Edit(), backend="kernel", edit_frac=edit_frac)
    ring_pps, ring_sec = _pps(ring, P, plen, T, tlen, pairs)
    kern_pps, kern_sec = _pps(kern, P, plen, T, tlen, pairs)
    ratio = kern_pps / ring_pps
    rows.append((f"kernelgap/ring-b{pairs}", ring_sec * 1e6,
                 f"{ring_pps:,.0f} pairs/s jnp ring, edit L={read_len}"))
    rows.append((f"kernelgap/kernel-b{pairs}", kern_sec * 1e6,
                 f"{kern_pps:,.0f} pairs/s fused Pallas grid (interpret)"))
    rows.append(("kernelgap/ratio", ratio,
                 f"kernel/ring pairs/s (gate >= "
                 f"{RATIO_GATE * BASELINE_RATIO:.3f} = {RATIO_GATE:.0f}x "
                 f"pre-PR baseline {BASELINE_RATIO:.4f})"))

    # -- informational: the pre-PR one-hot gather on a small slice ---------
    if onehot:
        n1 = min(ONEHOT_SLICE, pairs)
        k1 = AlignmentEngine(Edit(), backend="kernel", edit_frac=edit_frac,
                             backend_opts={"gather": "onehot"})
        oh_pps, oh_sec = _pps(k1, P[:n1], plen[:n1], T[:n1], tlen[:n1], n1)
        rows.append((f"kernelgap/kernel-onehot-b{n1}", oh_sec * 1e6,
                     f"{oh_pps:,.0f} pairs/s pre-PR one-hot gather "
                     f"(informational)"))

    # -- parity: scores + CIGARs kernel vs ring on {edit, affine} ----------
    ok = 1.0
    for pen in (Edit(), wfa_paper.pen):
        r = AlignmentEngine(pen, backend="ring").align_packed(
            P, plen, T, tlen, output="cigar")
        k = AlignmentEngine(pen, backend="kernel").align_packed(
            P, plen, T, tlen, output="cigar")
        if not (np.array_equal(r.scores, k.scores)
                and all(np.array_equal(a, b)
                        for a, b in zip(r.cigars, k.cigars))):
            ok = 0.0
    rows.append(("kernelgap/parity", ok,
                 "scores+CIGARs kernel==ring over {edit, affine} "
                 "(gate == 1)"))

    # -- pruning: adaptive+band vs exact on the divergent mix --------------
    Pd, pld, Td, tld = _divergent_mix(pairs, read_len, edit_frac, seed=17)
    exact = AlignmentEngine(wfa_paper.pen, backend="ring", adaptive=False)
    adapt = AlignmentEngine(wfa_paper.pen, backend="ring", adaptive=False,
                            heuristic=AdaptiveBand(),
                            backend_opts={"band_cap": "auto"})
    ex_pps, ex_sec = _pps(exact, Pd, pld, Td, tld, pairs)
    ad_pps, ad_sec = _pps(adapt, Pd, pld, Td, tld, pairs)
    rows.append((f"kernelgap/affine-exact-b{pairs}", ex_sec * 1e6,
                 f"{ex_pps:,.0f} pairs/s exact, divergent mix"))
    rows.append((f"kernelgap/affine-adaptive-b{pairs}", ad_sec * 1e6,
                 f"{ad_pps:,.0f} pairs/s AdaptiveBand + compacting band"))
    rows.append(("kernelgap/adaptive-speedup", ad_pps / ex_pps,
                 "adaptive/exact pairs/s on divergent mix (gate >= 1)"))
    return rows


def _value(rows: list[Row], name: str) -> float:
    for n, v, _ in rows:
        if n == name:
            return v
    raise KeyError(name)


def check(rows: list[Row]) -> list[str]:
    """The CI gate over kernelgap rows (live or from a JSON snapshot)."""
    failures = []
    ratio = _value(rows, "kernelgap/ratio")
    floor = RATIO_GATE * BASELINE_RATIO
    if ratio < floor:
        failures.append(
            f"kernelgap/ratio: kernel at {ratio:.3f}x of ring < {floor:.3f}"
            f" ({RATIO_GATE:.0f}x the pre-PR baseline {BASELINE_RATIO:.4f})")
    if _value(rows, "kernelgap/parity") != 1.0:
        failures.append(
            "kernelgap/parity: kernel scores/CIGARs diverge from ring")
    speedup = _value(rows, "kernelgap/adaptive-speedup")
    if speedup < 1.0:
        failures.append(
            f"kernelgap/adaptive-speedup: {speedup:.2f}x < 1.0 — pruning "
            "must not lose to exact on the divergent mix")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=256)
    ap.add_argument("--read-len", type=int, default=256)
    ap.add_argument("--no-onehot", action="store_true",
                    help="skip the (slow) informational one-hot row")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless kernel/ring ratio >= "
                         "10x the pre-PR baseline, kernel parity with "
                         "ring holds, and adaptive >= exact on the "
                         "divergent mix")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: gate on the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running")
    args = ap.parse_args(argv)
    if args.from_json:
        rows = rows_from_json(args.from_json, "kernelgap/")
    else:
        rows = run(pairs=args.pairs, read_len=args.read_len,
                   onehot=not args.no_onehot)
        emit(rows)
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"# kernelgap REGRESSION: {f}", file=sys.stderr)
        if failures:
            if args.from_json:
                from benchmarks.common import snapshot_diff
                for line in snapshot_diff(args.from_json, "kernelgap/"):
                    print(f"# kernelgap {line}", file=sys.stderr)
            return 1
        print("# kernelgap gate passed: ratio >= 10x pre-PR baseline, "
              "kernel==ring parity, adaptive >= exact on divergent mix",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
