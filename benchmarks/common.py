"""Benchmark harness utilities: timing + CSV emission.

Times on this host are CPU-XLA and structure-faithful only (the TPU numbers
are the dry-run roofline terms); throughput *ratios* between configurations
(batch widths, backends, transfer vs kernel) are the meaningful output, as
in the paper's Fig. 1 which is itself a ratio story."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocking on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
