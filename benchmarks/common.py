"""Benchmark harness utilities: timing + CSV emission.

Times on this host are CPU-XLA and structure-faithful only (the TPU numbers
are the dry-run roofline terms); throughput *ratios* between configurations
(batch widths, backends, transfer vs kernel) are the meaningful output, as
in the paper's Fig. 1 which is itself a ratio story."""
from __future__ import annotations

import glob
import json
import sys
import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def rows_from_json(pattern: str, prefix: str) -> List[Row]:
    """Rows starting with ``prefix`` from the newest snapshot matching
    ``pattern`` (a glob over ``benchmarks.run --json`` outputs).

    CI gates call this instead of re-running a suite, and it fails loudly
    (``SystemExit(1)``) when no snapshot matches **or the newest snapshot
    carries zero rows for the suite** — a gate handed an empty row list
    would otherwise pass vacuously (or die with a bare ``KeyError``)
    whenever the smoke step quietly dropped the suite from its ``--only``
    list, which is exactly how BENCH_20260808T185519Z.json ended up
    holding serving rows alone.
    """
    paths = sorted(glob.glob(pattern))
    if not paths:
        print(f"# no snapshot matches {pattern!r}", file=sys.stderr)
        raise SystemExit(1)
    with open(paths[-1]) as f:
        payload = json.load(f)
    rows = [(r["name"], r["us_per_call"], r["derived"])
            for r in payload["rows"] if r["name"].startswith(prefix)]
    if not rows:
        print(f"# newest snapshot {paths[-1]} has no {prefix!r} rows — "
              f"re-run benchmarks.run with that suite in --only before "
              f"gating", file=sys.stderr)
        raise SystemExit(1)
    print(f"# gating on {paths[-1]} ({len(rows)} {prefix.rstrip('/')} rows)",
          file=sys.stderr)
    return rows


def snapshot_diff(pattern: str, prefix: str = "", top: int = 5) -> List[str]:
    """Attribute movement between the two newest snapshots → text lines.

    When a gate fails, "suite X regressed" is only half an answer; this
    compares the two newest ``BENCH_*.json`` captures matching
    ``pattern`` and names the (suite, phase) rows that moved the most
    (``repro.obs.analyze.diff_rows`` ordering).  Returns ``[]`` when
    fewer than two snapshots exist — attribution is best-effort and
    must never mask the underlying gate failure.
    """
    paths = sorted(glob.glob(pattern))
    if len(paths) < 2:
        return []
    try:
        from repro.obs import analyze

        def load(p: str):
            with open(p) as f:
                payload = json.load(f)
            return {r["name"]: float(r["us_per_call"])
                    for r in payload["rows"]
                    if r["name"].startswith(prefix)}

        deltas = analyze.diff_rows(load(paths[-2]), load(paths[-1]))
    except Exception as e:            # pragma: no cover - best-effort
        return [f"snapshot diff failed: {e!r}"]
    if not deltas:
        return []
    lines = [f"snapshot diff {paths[-2]} -> {paths[-1]} "
             f"(biggest movers first):"]
    for d in deltas[:max(1, top)]:
        lines.append(f"  suite={d.suite} phase={d.phase}: "
                     f"{d.a:.4g} -> {d.b:.4g} ({d.ratio:.3f}x)")
    return lines


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocking on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
