"""Benchmark driver: one module per paper table/figure + substrate benches.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig1,scaling,...]
Prints ``name,us_per_call,derived`` CSV (one row per measurement)."""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,scaling,transfer,"
                         "wfa_ops,lm")
    ap.add_argument("--pairs", type=int, default=8192)
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else None

    suites = []
    if want is None or "fig1" in want:
        from benchmarks import fig1_throughput
        suites.append(("fig1", lambda: fig1_throughput.run(pairs=args.pairs)))
    if want is None or "scaling" in want:
        from benchmarks import scaling_batch
        suites.append(("scaling", scaling_batch.run))
    if want is None or "transfer" in want:
        from benchmarks import transfer_overhead
        suites.append(("transfer",
                       lambda: transfer_overhead.run(pairs=args.pairs)))
    if want is None or "wfa_ops" in want:
        from benchmarks import wfa_ops
        suites.append(("wfa_ops", wfa_ops.run))
    if want is None or "lm" in want:
        from benchmarks import lm_substrate
        suites.append(("lm", lm_substrate.run))

    rows = []
    rc = 0
    for name, fn in suites:
        try:
            rows.extend(fn())
        except Exception:
            print(f"# suite {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
            rc = 1
    emit(rows)
    return rc


if __name__ == "__main__":
    sys.exit(main())
