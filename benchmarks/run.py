"""Benchmark driver: one module per paper table/figure + substrate benches.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig1,scaling,...]
Prints ``name,us_per_call,derived`` CSV (one row per measurement).
``--json [PATH]`` additionally writes a machine-readable snapshot (default
``results/perf/BENCH_<utc-timestamp>.json``) so per-commit runs accumulate
a perf trajectory."""
from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import subprocess
import sys
import traceback

from benchmarks.common import emit


def _write_json(path: str, rows, argv, failed) -> str:
    if path == "auto":
        stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
            "%Y%m%dT%H%M%SZ")
        path = os.path.join("results", "perf", f"BENCH_{stamp}.json")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    try:
        commit = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                                capture_output=True, text=True,
                                timeout=10).stdout.strip() or None
    except Exception:
        commit = None
    payload = {
        "created_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "commit": commit,
        "argv": list(argv) if argv is not None else sys.argv[1:],
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "failed_suites": failed,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,scaling,transfer,"
                         "cigar,scoring,mapping,serving,longread,kernelgap,"
                         "wfa_ops,lm,obs")
    ap.add_argument("--pairs", type=int, default=8192)
    ap.add_argument("--json", nargs="?", const="auto", default=None,
                    metavar="PATH",
                    help="also write a JSON snapshot (default "
                         "results/perf/BENCH_<timestamp>.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="run the suites with tracing enabled and write "
                         "one Chrome trace-event JSON timeline (open in "
                         "ui.perfetto.dev)")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else None

    suites = []
    if want is None or "fig1" in want:
        from benchmarks import fig1_throughput
        suites.append(("fig1", lambda: fig1_throughput.run(pairs=args.pairs)))
    if want is None or "scaling" in want:
        from benchmarks import scaling_batch
        suites.append(("scaling", scaling_batch.run))
    if want is None or "transfer" in want:
        from benchmarks import transfer_overhead
        suites.append(("transfer",
                       lambda: transfer_overhead.run(pairs=args.pairs)))
    if want is None or "cigar" in want:
        from benchmarks import cigar_overhead
        suites.append(("cigar",
                       lambda: cigar_overhead.run(
                           pairs=min(args.pairs, 2048))))
    if want is None or "scoring" in want:
        from benchmarks import scoring_models
        suites.append(("scoring",
                       lambda: scoring_models.run(
                           pairs=min(args.pairs, 2048))))
    if want is None or "mapping" in want:
        from benchmarks import mapping
        suites.append(("mapping",
                       lambda: mapping.run(reads=min(args.pairs, 512))))
    if want is None or "serving" in want:
        from benchmarks import serving
        # the ratio gate needs a trace long enough to amortize the
        # form-deadline/drain tail: don't shrink below ~512 requests
        # unless --pairs is tiny
        suites.append(("serving",
                       lambda: serving.run(
                           requests=min(max(args.pairs // 2, 64), 512))))
    if want is None or "longread" in want:
        from benchmarks import longread
        suites.append(("longread",
                       lambda: longread.run(
                           pairs=min(max(args.pairs // 64, 8), 32))))
    if want is None or "kernelgap" in want:
        from benchmarks import kernelgap
        # interpret-mode kernel runs: keep the batch modest and skip the
        # (very slow) informational one-hot row in sweeps
        suites.append(("kernelgap",
                       lambda: kernelgap.run(
                           pairs=min(max(args.pairs // 8, 64), 256),
                           onehot=False)))
    if want is None or "wfa_ops" in want:
        from benchmarks import wfa_ops
        suites.append(("wfa_ops", wfa_ops.run))
    if want is None or "lm" in want:
        from benchmarks import lm_substrate
        suites.append(("lm", lm_substrate.run))
    if want is None or "obs" in want:
        # safe under --trace-out: the suite self-measures inside
        # obs_trace.isolated(), which restores the outer timeline
        from benchmarks import obs_overhead
        suites.append(("obs",
                       lambda: obs_overhead.run(
                           pairs=min(args.pairs, 4096))))

    rows = []
    failed = []
    rc = 0
    from repro import obs
    with obs.capture_trace(args.trace_out):
        for name, fn in suites:
            try:
                rows.extend(fn())
            except Exception:
                print(f"# suite {name} FAILED:", file=sys.stderr)
                traceback.print_exc()
                failed.append(name)
                rc = 1
    if args.trace_out:
        print(f"# trace -> {args.trace_out}", file=sys.stderr)
        try:
            # phase accounting over the capture we just wrote: the
            # paper's transfer/kernel/retrieve split lands in the same
            # snapshot, so snapshot diffs can name the phase that moved
            from repro.obs import analyze
            pt = analyze.phase_accounting(
                analyze.Trace.from_file(args.trace_out))
            rows.extend(pt.as_rows())
        except Exception:
            print("# phase accounting FAILED:", file=sys.stderr)
            traceback.print_exc()
            failed.append("phase")
            rc = 1
    emit(rows)
    if args.json is not None:
        path = _write_json(args.json, rows, argv, failed)
        print(f"# wrote {path}", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
