"""Score-vs-CIGAR throughput: what full alignments cost on each backend.

The paper's numbers are score-only; the follow-up framework paper
(arXiv:2208.01243) makes the case that a usable aligner must emit full
alignments at comparable throughput.  This suite runs the identical
workload through ``output="score"`` and ``output="cigar"`` per backend and
reports the ratio, plus the trace-memory ratio of the packed backtrace
(ring/kernel) against the full offset history (ref) — the reason the fast
backends can serve CIGARs at all.  Rows land in the ``--json`` snapshot,
so the traceback overhead is tracked per push.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core import cigar as cigar_mod
from repro.core.backends import get_backend
from repro.core.engine import AlignmentEngine, problem_bounds
from repro.data.reads import ReadPairSpec, generate_pairs


def _best_of(fn, n=2):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(pairs: int = 2048, read_len: int = 100,
        edit_frac: float = 0.02) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=4)
    P, plen, T, tlen = generate_pairs(spec)

    rows: list[Row] = []
    for backend in ("ring", "kernel", "ref"):
        eng = AlignmentEngine(wfa_paper.pen, backend=backend,
                              edit_frac=edit_frac, chunk_pairs=pairs)
        for output in ("score", "cigar"):      # warm both executables
            eng.align_packed(P, plen, T, tlen, output=output)
        t_score = _best_of(
            lambda: eng.align_packed(P, plen, T, tlen, output="score"))
        t_cigar = _best_of(
            lambda: eng.align_packed(P, plen, T, tlen, output="cigar"))
        rows.append((f"cigar/{backend}",
                     t_cigar / pairs * 1e6,
                     f"score={pairs / t_score:,.0f}pairs/s "
                     f"cigar={pairs / t_cigar:,.0f}pairs/s "
                     f"overhead={t_cigar / t_score:.2f}x"))

    # trace-memory ratio: packed words vs full offset history, one bucket
    s_max, k_max = problem_bounds(wfa_paper.pen, plen, tlen, edit_frac)
    n = min(pairs, 256)
    full = get_backend("ref").variant("cigar")(
        P[:n], T[:n], plen[:n], tlen[:n], pen=wfa_paper.pen,
        s_max=s_max, k_max=k_max)
    packed = get_backend("ring").variant("cigar")(
        P[:n], T[:n], plen[:n], tlen[:n], pen=wfa_paper.pen,
        s_max=s_max, k_max=k_max)
    fb, pb = cigar_mod.trace_nbytes(full), cigar_mod.trace_nbytes(packed)
    rows.append(("cigar/trace_memory", 0.0,
                 f"full={fb / 1e6:.2f}MB packed={pb / 1e6:.3f}MB "
                 f"ratio={fb / pb:.1f}x"))
    return rows
