"""Observability overhead: is `repro.obs` safe to leave compiled in?

The instrumentation layer (PR 9) is always-available: every wave
dispatch/retire crosses `obs.trace` span points and a couple of
`obs.metrics` updates, with a module-global switch gating the trace
emission.  This suite measures both costs the design promises to keep
negligible:

* **disabled** (the default) — each span point is one function call and
  one branch returning a shared no-op object.  ``obs/disabled_ns``
  microbenchmarks that call; ``obs/disabled_frac`` projects it onto a
  wave (a conservative per-wave call count x ns-per-call / measured wave
  time).  Since PR 10 "disabled" includes the **flight recorder**: the
  streamed measurement and the projection both run with the post-mortem
  ring active (``obs/flightrec_ns`` is the ring's full span cycle), so
  the 2% budget covers the always-on configuration a live server
  actually runs in, not just the bare branch.  Gate: <= 2%.
* **enabled** — spans, flow events and counters are actually buffered.
  ``obs/on_ratio`` is enabled/disabled align throughput (warm engine,
  best-of-3 each, interleaved).  Gate: >= 0.90 — capturing a timeline
  costs at most 10%.

``main(--check)`` is the CI gate; ``--from-json`` gates on the newest
``benchmarks.run --json`` snapshot like the other suites.  The whole
measurement runs inside ``obs_trace.isolated()``, so toggling the
switch and emitting ~10^5 throwaway spans never corrupts an outer
``benchmarks.run --trace-out`` capture.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine
from repro.core.session import run_streamed
from repro.data.reads import ReadPairSpec, generate_pairs
from repro.obs import metrics as obs_metrics
from repro.obs import record as obs_record
from repro.obs import trace as obs_trace

ON_RATIO_GATE = 0.90       # tracing-on throughput >= 90% of tracing-off
DISABLED_FRAC_GATE = 0.02  # projected tracing-off overhead <= 2%

# Conservative upper bound on obs entry points crossed per dispatched
# wave (spans + enabled() checks + instants in session._dispatch /
# _retire_one / engine._executable_for), used to project the disabled
# per-call cost onto a wave.  The real count is ~15-25; the margin keeps
# the gate honest if later PRs add span points without re-counting.
CALLS_PER_WAVE = 64
# metrics updates per wave (gauge/counter registry lookups) that run
# regardless of the trace switch
METRIC_CALLS_PER_WAVE = 8


def _bench_stream(eng, P, plen, T, tlen, submit_pairs: int,
                  iters: int = 3) -> float:
    """Best-of-``iters`` wall seconds for one warm streamed pass.

    The streamed session is the instrumented path (wave.scatter /
    wave.kernel / wave.gather spans + per-ticket flows), so this is the
    surface the overhead gates actually protect.
    """
    best = float("inf")
    for _ in range(iters):
        _, _, _, dt = run_streamed(eng, P, plen, T, tlen,
                                   submit_pairs=submit_pairs)
        best = min(best, dt)
    return best


def _ns_per_call(fn, n: int = 200_000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e9


def run(pairs: int = 4096, read_len: int = 100, edit_frac: float = 0.02,
        backend: str = "ring", submit_pairs: int = 256) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=11)
    P, plen, T, tlen = generate_pairs(spec)
    eng = AlignmentEngine(wfa_paper.pen, backend=backend,
                          edit_frac=edit_frac)
    run_streamed(eng, P, plen, T, tlen,
                 submit_pairs=submit_pairs)          # warm the cache

    with obs_trace.isolated():
        # "disabled" is the production default: tracer off, flight
        # recorder ON (a live server keeps the post-mortem ring warm).
        obs_record.acquire()
        try:
            # interleaved off/on/off/on: shared-host noise hits both modes
            obs_trace.disable()
            t_off = _bench_stream(eng, P, plen, T, tlen, submit_pairs)
            obs_trace.enable()
            obs_trace.reset()
            t_on = _bench_stream(eng, P, plen, T, tlen, submit_pairs)
            n_events = len(obs_trace.events())
            obs_trace.reset()
            obs_trace.disable()
            t_off = min(t_off, _bench_stream(eng, P, plen, T, tlen,
                                             submit_pairs))
            obs_trace.enable()
            obs_trace.reset()
            t_on = min(t_on, _bench_stream(eng, P, plen, T, tlen,
                                           submit_pairs))
            obs_trace.reset()

            # ring-only span cost: tracer off, recorder active — a real
            # Span is built and its exit event lands in the ring
            obs_trace.disable()

            def _span_cycle():
                with obs_trace.span("x"):
                    pass

            rec_span_ns = _ns_per_call(_span_cycle)
            g = obs_metrics.gauge("obs_overhead_probe")
            gauge_ns = _ns_per_call(lambda: g.set(1.0))
        finally:
            obs_record.release()
        # bare branch cost: tracer off, recorder off -> NULL span
        span_ns = _ns_per_call(lambda: obs_trace.span("x"))

    n_waves = max(1, -(-pairs // submit_pairs))
    wave_s = t_off / n_waves
    worst_span_ns = max(span_ns, rec_span_ns)
    disabled_frac = (CALLS_PER_WAVE * worst_span_ns
                     + METRIC_CALLS_PER_WAVE * gauge_ns) / 1e9 / wave_s
    on_ratio = t_off / t_on

    return [
        ("obs/off", t_off / pairs * 1e6,
         f"{pairs / t_off:,.0f} pairs/s tracing disabled "
         f"(flight recorder active)"),
        ("obs/on", t_on / pairs * 1e6,
         f"{pairs / t_on:,.0f} pairs/s tracing enabled "
         f"({n_events} trace events over 3 passes)"),
        ("obs/on_ratio", on_ratio,
         f"enabled/disabled throughput (gate >= {ON_RATIO_GATE})"),
        ("obs/disabled_ns", span_ns,
         f"ns per disabled span() call ({gauge_ns:.0f} ns per gauge set)"),
        ("obs/flightrec_ns", rec_span_ns,
         "ns per full span cycle with tracing off + flight-recorder "
         "ring active"),
        ("obs/disabled_frac", disabled_frac,
         f"projected disabled overhead per wave: {CALLS_PER_WAVE} span "
         f"points x {worst_span_ns:.0f} ns (ring-active worst case) + "
         f"{METRIC_CALLS_PER_WAVE} metric updates x {gauge_ns:.0f} ns "
         f"over {wave_s * 1e3:.1f} ms (gate <= {DISABLED_FRAC_GATE})"),
    ]


def _value(rows: list[Row], name: str) -> float:
    for n, v, _ in rows:
        if n == name:
            return v
    raise KeyError(name)


def check(rows: list[Row], on_ratio_gate: float = ON_RATIO_GATE,
          disabled_frac_gate: float = DISABLED_FRAC_GATE) -> list[str]:
    """The CI gate over obs rows (live or from a JSON snapshot)."""
    failures = []
    frac = _value(rows, "obs/disabled_frac")
    if not frac <= disabled_frac_gate:
        failures.append(
            f"obs/disabled_frac: projected tracing-off overhead "
            f"{frac:.1%} > {disabled_frac_gate:.0%} — the disabled hot "
            f"path is no longer a single branch")
    ratio = _value(rows, "obs/on_ratio")
    if not ratio >= on_ratio_gate:
        failures.append(
            f"obs/on_ratio: tracing-on throughput {ratio:.2f}x of "
            f"tracing-off < {on_ratio_gate}x — span emission is too "
            f"expensive to capture timelines in production")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=4096)
    ap.add_argument("--read-len", type=int, default=100)
    ap.add_argument("--backend", default="ring")
    ap.add_argument("--on-ratio-gate", type=float, default=ON_RATIO_GATE)
    ap.add_argument("--disabled-frac-gate", type=float,
                    default=DISABLED_FRAC_GATE)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless disabled overhead <= 2%% "
                         "and tracing-on throughput >= 90%% of tracing-off")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: gate on the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running")
    args = ap.parse_args(argv)
    from benchmarks.common import emit
    if args.from_json:
        from benchmarks.common import rows_from_json
        rows = rows_from_json(args.from_json, "obs/")
    else:
        rows = run(pairs=args.pairs, read_len=args.read_len,
                   backend=args.backend)
        emit(rows)
    if args.check:
        failures = check(rows, on_ratio_gate=args.on_ratio_gate,
                         disabled_frac_gate=args.disabled_frac_gate)
        for f in failures:
            print(f"# obs REGRESSION: {f}", file=sys.stderr)
        if failures:
            if args.from_json:
                from benchmarks.common import snapshot_diff
                for line in snapshot_diff(args.from_json, "obs/"):
                    print(f"# obs {line}", file=sys.stderr)
            return 1
        print("# obs gate passed: disabled overhead <= "
              f"{args.disabled_frac_gate:.0%}, tracing-on within "
              f"{1 - args.on_ratio_gate:.0%} of tracing-off",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
