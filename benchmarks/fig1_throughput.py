"""Paper Fig. 1: time to align a batch of 100bp read pairs at E=2% / 4%.

Roles, mapped to this framework:

* ``gotoh``       — the classical dense DP (the O(n*m) baseline WFA replaced;
                    run on fewer pairs and extrapolated, exactly because it
                    is quadratically slower)
* ``wfa-host``    — single-pair-at-a-time WFA (the "1-thread CPU" row)
* ``wfa-batch``   — lock-step batched WFA, ring buffers (the PIM structural
                    analogue: all lanes advance together, working set stays
                    in the fast tier); reported both as *Total* (with
                    host<->device transfers) and *Kernel* (align only)
* ``wfa-kernel``  — the Pallas kernel (interpret=True on CPU: numbers are
                    correctness-path only, the TPU projection lives in the
                    roofline analysis)

Pair counts are scaled down from the paper's 5M to CPU-feasible sizes;
``--pairs`` scales up.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import Row, time_fn
from repro.configs import wfa_paper
from repro.core.backends import get_backend
from repro.core.engine import AlignmentEngine
from repro.core.gotoh import gotoh_score_vec
from repro.data.reads import ReadPairSpec, generate_pairs


def run(pairs: int = 8192, read_len: int = 100) -> list[Row]:
    rows: list[Row] = []
    for ef in (0.02, 0.04):
        spec = ReadPairSpec(n_pairs=pairs, read_len=read_len, edit_frac=ef,
                            seed=0)
        P, plen, T, tlen = generate_pairs(spec)

        # --- classical dense DP baseline (extrapolated from a sample) ----
        n_dp = min(64, pairs)
        t0 = time.perf_counter()
        for i in range(n_dp):
            gotoh_score_vec(P[i, : plen[i]], T[i, : tlen[i]], wfa_paper.pen)
        dp_per_pair = (time.perf_counter() - t0) / n_dp
        rows.append((f"fig1/E{ef:.0%}/gotoh-dense-dp",
                     dp_per_pair * 1e6,
                     f"{1.0 / dp_per_pair:,.0f} pairs/s (extrapolated)"))

        # --- WFA one pair at a time (1-thread CPU role) -------------------
        # fixed-width padded rows so the jit cache is hit (recompiling per
        # read length would not be a fair single-pair cost)
        from repro.core.engine import problem_bounds
        s_max, k_max = problem_bounds(wfa_paper.pen, plen, tlen, ef)
        ring = get_backend("ring").fn
        one_fn = jax.jit(lambda p, t, pl, tl: ring(
            p, t, pl, tl, pen=wfa_paper.pen, s_max=s_max, k_max=k_max))
        n_one = min(32, pairs)
        one_fn(P[:1], T[:1], plen[:1], tlen[:1])  # compile
        t0 = time.perf_counter()
        for i in range(n_one):
            one_fn(P[i:i+1], T[i:i+1], plen[i:i+1],
                   tlen[i:i+1]).score.block_until_ready()
        one_per_pair = (time.perf_counter() - t0) / n_one
        rows.append((f"fig1/E{ef:.0%}/wfa-host-1pair",
                     one_per_pair * 1e6,
                     f"{1.0 / one_per_pair:,.0f} pairs/s"))

        # --- batched WFA via the engine (Total vs Kernel) ----------------
        eng = AlignmentEngine(wfa_paper.pen, backend="ring", edit_frac=ef,
                              chunk_pairs=pairs)
        # warm with the identical shape so the timed call is steady-state
        # (0 retraces), not compile-dominated
        eng.align_packed(P, plen, T, tlen)
        res = eng.align_packed(P, plen, T, tlen)
        assert res.stats.n_traces == 0
        scores, stats = res.scores, res.stats.pim
        assert (scores >= 0).all()
        rows.append((f"fig1/E{ef:.0%}/wfa-batch-Total",
                     stats.t_total / pairs * 1e6,
                     f"{stats.throughput_total():,.0f} pairs/s"))
        rows.append((f"fig1/E{ef:.0%}/wfa-batch-Kernel",
                     stats.t_kernel / pairs * 1e6,
                     f"{stats.throughput_kernel():,.0f} pairs/s"))
        speedup = one_per_pair / (stats.t_total / pairs)
        rows.append((f"fig1/E{ef:.0%}/batch-vs-1pair-speedup",
                     0.0, f"{speedup:.1f}x"))

        # --- Pallas kernel (interpret mode; correctness-path timing) -----
        from repro.kernels.wfa import wfa_align
        nk = min(512, pairs)
        sec = time_fn(lambda: wfa_align(P[:nk], T[:nk], plen[:nk], tlen[:nk],
                                        pen=wfa_paper.pen, s_max=s_max,
                                        k_max=k_max), warmup=1, iters=2)
        rows.append((f"fig1/E{ef:.0%}/wfa-kernel-interp-{nk}",
                     sec / nk * 1e6,
                     f"{nk / sec:,.0f} pairs/s (interpret)"))
    return rows
