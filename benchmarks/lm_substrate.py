"""LM-substrate step costs on this host (smoke configs): train step, prefill
and decode per architecture family.  These are framework health numbers
(the production-scale projection is §Roofline in EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.configs import smoke_config
from repro.models import get_model_fns, synth_batch
from repro.models.common import ShapeSpec
from repro.optim.adamw import AdamWConfig

ARCHS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "mamba2-780m", "zamba2-7b",
         "whisper-base"]


def run() -> list[Row]:
    rows: list[Row] = []
    shape = ShapeSpec("bench", 128, 2, "train")
    for arch in ARCHS:
        cfg = smoke_config(arch)
        fns = get_model_fns(cfg)
        state, _ = fns.init_train_state(cfg, jax.random.key(0))
        step = jax.jit(fns.make_train_step(cfg, AdamWConfig(total_steps=8), 1))
        batch = synth_batch(cfg, shape, seed=1)
        tokens = shape.seq_len * shape.global_batch

        def one(state=state, batch=batch, step=step):
            s2, m = step(state, batch)
            return m["loss"]

        sec = time_fn(one, warmup=1, iters=3)
        rows.append((f"lm/{arch}/train-step", sec * 1e6,
                     f"{tokens / sec:,.0f} tok/s (smoke cfg)"))

        B, S = 2, 64
        cache = fns.init_cache(cfg, B, S)
        tok = np.array([1, 2], np.int32)
        kw = {}
        if cfg.family == "vlm":
            kw["mrope_pos"] = jnp.zeros((B, 1, 3), jnp.int32)
        dec = jax.jit(lambda p, c, t, l: fns.serve_step(p, cfg, c, t, l, **kw))
        sec = time_fn(lambda: dec(state["params"], cache, tok,
                                  jnp.int32(3))[0], warmup=1, iters=3)
        rows.append((f"lm/{arch}/decode-step", sec * 1e6,
                     f"{B / sec:,.0f} tok/s (smoke cfg)"))
    return rows
