"""Open-loop serving benchmark: latency percentiles at offered load.

The paper's headline is offline throughput — one giant batch, pairs/s.
A service is judged differently: requests arrive continuously (Poisson,
open loop — the schedule does not wait for the server), and the numbers
that matter are **sustained pairs/s at an offered load** and the
**latency tail** (p50/p95/p99 from arrival to future resolution), plus
the batching-efficiency telemetry that explains them (wave occupancy,
padding waste, shed count).

Method: measure the closed-loop batch-mode pairs/s of the backend on the
identical workload, set the offered load to ``load`` x that rate, warm
the serving wave shape, then replay a deterministic Poisson trace through
``repro.serve.ServeLoop`` and read the report.

``main(--check)`` is the CI acceptance gate of the serving subsystem:

* sustained pairs/s >= 50% of batch mode at moderate (default 0.75x)
  offered load — continuous batching must not halve the engine;
* **zero** fresh XLA traces during the measured run — steady-state
  serving rides the warmed executable cache;
* p99 latency within budget (generous for loaded CI boxes);
* every request's future resolved exactly once (ok or typed shed), and
  served scores identical to batch mode — no request lost, duplicated
  or corrupted by out-of-order retirement (live runs only).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine
from repro.data.reads import ArrivalSpec, generate_trace
from repro.serve import ServeLoop, replay_trace

P99_BUDGET_S = 2.0     # default CI gate; generous for 2-core runners


def _serve_once(eng, payloads, arrivals, *, wave_pairs, form_deadline,
                max_queue_depth, n_threads):
    with ServeLoop(eng, wave_pairs=wave_pairs, form_deadline=form_deadline,
                   max_queue_depth=max_queue_depth,
                   n_threads=n_threads) as server:
        report = replay_trace(server, payloads, arrivals)
    return report


def run(requests: int = 512, pairs_per_request: int = 8,
        read_len: int = 100, edit_frac: float = 0.02,
        backend: str = "ring", load: float = 0.75, wave_pairs: int = 256,
        form_deadline: float = 0.015, n_threads: int = 1,
        max_queue_depth: int = 4096, rate: float = None,
        verify: bool = True) -> list[Row]:
    spec = ArrivalSpec(n_requests=requests,
                       pairs_per_request=pairs_per_request,
                       read_len=read_len, edit_frac=edit_frac, seed=13)
    payloads, unit_arrivals = generate_trace(spec)
    n_pairs = requests * pairs_per_request
    P = np.concatenate([p for p, _, _, _ in payloads])
    plen = np.concatenate([pl for _, pl, _, _ in payloads])
    T = np.concatenate([t for _, _, t, _ in payloads])
    tlen = np.concatenate([tl for _, _, _, tl in payloads])

    eng = AlignmentEngine(wfa_paper.pen, backend=backend,
                          edit_frac=edit_frac)
    # closed-loop batch baseline on the identical pairs (warm, best-of-3)
    batch = eng.align_packed(P, plen, T, tlen)
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        eng.align_packed(P, plen, T, tlen)
        t_batch = min(t_batch, time.perf_counter() - t0)
    batch_pps = n_pairs / t_batch

    if rate is None:
        rate = load * batch_pps / pairs_per_request   # requests/s
    # warm the serving wave shape (full + padded-partial are one shape):
    # a couple of waves' worth of requests, arrivals compressed so waves
    # fill instantly
    n_warm = min(requests, max(2 * wave_pairs // pairs_per_request, 2))
    _serve_once(eng, payloads[:n_warm], np.zeros(n_warm),
                wave_pairs=wave_pairs, form_deadline=form_deadline,
                max_queue_depth=max_queue_depth, n_threads=n_threads)
    traces0 = eng.cache_traces()

    with ServeLoop(eng, wave_pairs=wave_pairs, form_deadline=form_deadline,
                   max_queue_depth=max_queue_depth,
                   n_threads=n_threads) as server:
        report = replay_trace(server, payloads, unit_arrivals / rate)
    retraces = eng.cache_traces() - traces0

    if verify:
        # no request lost / duplicated / corrupted by out-of-order
        # retirement: every future resolved exactly once, and every served
        # request's scores equal batch mode's
        assert report.n_ok + report.n_shed + report.n_failed \
            == requests, "request futures lost or duplicated"
        assert report.n_failed == 0, "requests failed (non-shed)"
        for i, res in enumerate(report.results):
            if res is not None:
                lo = i * pairs_per_request
                np.testing.assert_array_equal(
                    res.scores, batch.scores[lo:lo + pairs_per_request],
                    err_msg=f"request {i} scores diverge from batch mode")

    st = report.stats
    sustained = report.sustained_pairs_per_s
    pre = f"serving/{backend}"
    return [
        (f"{pre}/batch", 1e6 / batch_pps,
         f"{batch_pps:,.0f} pairs/s closed-loop batch baseline"),
        (f"{pre}/sustained", 1e6 / max(sustained, 1e-9),
         f"{sustained:,.0f} pairs/s open-loop @ {load:.0%} offered load "
         f"({rate:,.0f} req/s, {report.n_ok}/{requests} served)"),
        (f"{pre}/ratio", sustained / batch_pps,
         "sustained/batch pairs/s (gate >= 0.5)"),
        (f"{pre}/p50", report.percentile_ms(50) * 1e3,
         f"{report.percentile_ms(50):.1f} ms request latency"),
        (f"{pre}/p95", report.percentile_ms(95) * 1e3,
         f"{report.percentile_ms(95):.1f} ms request latency"),
        (f"{pre}/p99", report.percentile_ms(99) * 1e3,
         f"{report.percentile_ms(99):.1f} ms request latency "
         f"(gate <= {P99_BUDGET_S:.1f}s) over {report.latencies.size} "
         f"completions"),
        (f"{pre}/occupancy", st.wave_occupancy,
         f"request rows / device rows ({st.waves_full} full, "
         f"{st.waves_deadline} deadline, {st.waves_drain} drain flushes)"),
        (f"{pre}/waste", st.padding_waste_frac,
         "padding waste fraction of dispatched rows"),
        (f"{pre}/shed", float(report.n_shed),
         f"requests shed by admission control (queue depth "
         f"{max_queue_depth})"),
        (f"{pre}/retraces", float(retraces),
         "fresh XLA traces during measured run (gate == 0)"),
    ]


def _value(rows: list[Row], name: str) -> float:
    for n, v, _ in rows:
        if n == name:
            return v
    raise KeyError(name)


def check(rows: list[Row], backend: str = "ring",
          p99_budget_s: float = P99_BUDGET_S) -> list[str]:
    """The CI gate over serving rows (live or from a JSON snapshot)."""
    pre = f"serving/{backend}"
    failures = []
    ratio = _value(rows, f"{pre}/ratio")
    if ratio < 0.5:
        failures.append(
            f"{pre}/ratio: sustained {ratio:.2f}x of batch mode < 0.5x")
    retraces = _value(rows, f"{pre}/retraces")
    if retraces != 0:
        failures.append(
            f"{pre}/retraces: {retraces:.0f} fresh XLA traces during the "
            "measured run (steady state must be fully cached)")
    p99_us = _value(rows, f"{pre}/p99")
    if not np.isfinite(p99_us) or p99_us > p99_budget_s * 1e6:
        failures.append(
            f"{pre}/p99: {p99_us / 1e3:.1f} ms > budget "
            f"{p99_budget_s * 1e3:.0f} ms")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--pairs-per-request", type=int, default=8)
    ap.add_argument("--read-len", type=int, default=100)
    ap.add_argument("--backend", default="ring")
    ap.add_argument("--load", type=float, default=0.75,
                    help="offered load as a fraction of measured "
                         "batch-mode pairs/s")
    ap.add_argument("--wave-pairs", type=int, default=256)
    ap.add_argument("--form-deadline-ms", type=float, default=15.0)
    ap.add_argument("--p99-budget-s", type=float, default=P99_BUDGET_S)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless sustained >= 50%% of batch "
                         "pairs/s, zero measured-run retraces, p99 within "
                         "budget, and (live runs) every future resolved "
                         "exactly once with batch-identical scores")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: gate on the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running the service")
    args = ap.parse_args(argv)
    from benchmarks.common import emit
    if args.from_json:
        from benchmarks.common import rows_from_json
        rows = rows_from_json(args.from_json, "serving/")
    else:
        rows = run(requests=args.requests,
                   pairs_per_request=args.pairs_per_request,
                   read_len=args.read_len, backend=args.backend,
                   load=args.load, wave_pairs=args.wave_pairs,
                   form_deadline=args.form_deadline_ms / 1e3)
        emit(rows)
    if args.check:
        failures = check(rows, backend=args.backend,
                         p99_budget_s=args.p99_budget_s)
        for f in failures:
            print(f"# serving REGRESSION: {f}", file=sys.stderr)
        if failures:
            if args.from_json:
                from benchmarks.common import snapshot_diff
                for line in snapshot_diff(args.from_json, "serving/"):
                    print(f"# serving {line}", file=sys.stderr)
            return 1
        print("# serving gate passed: >=50% of batch pairs/s, 0 retraces, "
              "p99 within budget", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
