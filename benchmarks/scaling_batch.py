"""Worker-scaling ablation (the paper's CPU-threads-vs-DPUs axis).

On UPMEM, throughput scales with DPU count because each DPU owns its
bandwidth; on TPU the analogue axis is the *lock-step batch width* (how many
pairs advance per vector op).  This benchmark sweeps the batch width through
the unified :class:`AlignmentEngine` (bucketing off: one rectangular wave
per call, so the width under test is exactly the device batch) and reports
pairs/s — the knee shows where the vector units saturate, the plateau is
the single-chip equivalent of the paper's full-scale PIM bar."""
from __future__ import annotations

from benchmarks.common import Row, time_fn
from repro.configs import wfa_paper
from repro.data.reads import ReadPairSpec, generate_pairs
from repro.core.engine import AlignmentEngine


def run(max_pairs: int = 4096, read_len: int = 100,
        edit_frac: float = 0.02) -> list[Row]:
    spec = ReadPairSpec(n_pairs=max_pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=1)
    P, plen, T, tlen = generate_pairs(spec)
    eng = AlignmentEngine(wfa_paper.pen, backend="ring",
                          edit_frac=edit_frac, bucket_by_length=False,
                          adaptive=False)

    rows: list[Row] = []
    width = 64
    base = None
    while width <= max_pairs:
        sec = time_fn(
            lambda w=width: eng.align_packed(P[:w], plen[:w], T[:w],
                                             tlen[:w]).scores,
            warmup=1, iters=3)
        thr = width / sec
        if base is None:
            base = thr
        rows.append((f"scaling/batch{width}", sec / width * 1e6,
                     f"{thr:,.0f} pairs/s ({thr / base:.2f}x of batch64)"))
        width *= 4
    return rows
