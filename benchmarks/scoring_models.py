"""Scoring-model × heuristic × backend throughput grid.

The follow-up framework paper (arXiv:2208.01243) argues the PIM pipeline
pays off across distance metrics and that WFA-adaptive pruning buys large
additional speedups.  This suite runs the identical read-pair workload
through every penalty model (edit / gap-linear / gap-affine) and heuristic
(exact / adaptive band) per backend and reports pairs/s, so the cost model
of each variant is tracked per push:

* **edit / linear** should beat **affine** — the one-matrix recurrence
  carries a third of the wavefront state and the E-derived ``s_max`` is
  smaller (cheaper per-edit unit cost), so the score loop is shorter;
* **adaptive** should at least match **exact** on the paper's regime —
  the band stays short on convergent reads, so pruning costs (a masked
  compare per step) are bounded, while divergent pairs get cheaper.

Two workloads, because the two claims differ:

* the **grid** rows run the paper's convergent regime (all pairs within E)
  under the optimistic E-derived bounds — the model comparison, where the
  band is already tight and pruning is roughly free;
* the **mixed** rows add an unmappable fraction (25% unrelated pairs, the
  read-mapping reality) under exact worst-case bounds — the heuristic
  comparison, where the wavefront band blows up on divergent pairs and
  adaptive pruning pays directly.

``main(--check)`` is the CI regression gate: it fails when edit-mode
throughput drops below exact gap-affine (grid batch) or adaptive-pruning
throughput drops below exact (mixed batch) — the acceptance contract of
the scoring subsystem.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine
from repro.core.scoring import (EXACT, AdaptiveBand, Edit, GapAffine,
                                GapLinear)
from repro.data.reads import BASES, ReadPairSpec, generate_pairs

MODELS = [
    ("edit", Edit()),
    ("linear", GapLinear(mismatch=wfa_paper.pen.x,
                         gap_extend=wfa_paper.pen.e)),
    ("affine", GapAffine(mismatch=wfa_paper.pen.x,
                         gap_open=wfa_paper.pen.o,
                         gap_extend=wfa_paper.pen.e)),
]
HEURISTICS = [("exact", EXACT), ("adaptive", AdaptiveBand())]


def run(pairs: int = 2048, read_len: int = 100, edit_frac: float = 0.02,
        backends=("ring", "kernel"), rounds: int = 3) -> list[Row]:
    spec = ReadPairSpec(n_pairs=pairs, read_len=read_len,
                        edit_frac=edit_frac, seed=7)
    P, plen, T, tlen = generate_pairs(spec)

    rows: list[Row] = []
    for backend in backends:
        eng = AlignmentEngine(wfa_paper.pen, backend=backend,
                              edit_frac=edit_frac, chunk_pairs=pairs)
        variants = []
        for mname, model in MODELS:
            for hname, heur in HEURISTICS:
                def run_one(model=model, heur=heur):
                    eng.align_packed(P, plen, T, tlen, penalties=model,
                                     heuristic=heur)
                run_one()                        # warm the executable
                variants.append((f"scoring/{backend}/{mname}/{hname}",
                                 run_one))
        # interleave rounds (round-robin over variants) so slow drift in
        # host load hits every variant equally — the grid is a ratio story
        # and best-of-sequential is systematically unfair to whichever
        # variant runs during a busy spell
        best = {name: float("inf") for name, _ in variants}
        for _ in range(rounds):
            for name, fn in variants:
                t0 = time.perf_counter()
                fn()
                best[name] = min(best[name], time.perf_counter() - t0)
        for name, _ in variants:
            t = best[name]
            rows.append((name, t / pairs * 1e6, f"{pairs / t:,.0f}pairs/s"))

    rows.extend(run_mixed(pairs=max(pairs // 4, 64), read_len=read_len,
                          edit_frac=edit_frac, rounds=rounds))
    return rows


def run_mixed(pairs: int = 512, read_len: int = 100,
              edit_frac: float = 0.02, divergent_frac: float = 0.25,
              backend: str = "ring", rounds: int = 3) -> list[Row]:
    """Exact vs adaptive on a batch with an unmappable-read fraction.

    Exact worst-case bounds (no E budget): divergent pairs drive the band
    to its full width, which is precisely where per-step lane pruning
    recovers throughput.  Same batch for both variants — a pure heuristic
    ablation.
    """
    nd = int(pairs * divergent_frac)
    spec = ReadPairSpec(n_pairs=pairs - nd, read_len=read_len,
                        edit_frac=edit_frac, seed=7)
    P, plen, T, tlen = generate_pairs(spec)
    rng = np.random.default_rng(11)
    D1 = BASES[rng.integers(0, 4, size=(nd, P.shape[1]))].astype(np.int32)
    D2 = BASES[rng.integers(0, 4, size=(nd, T.shape[1]))].astype(np.int32)
    P = np.concatenate([P, D1])
    T = np.concatenate([T, D2])
    plen = np.concatenate([plen, np.full(nd, read_len, np.int32)])
    tlen = np.concatenate([tlen, np.full(nd, read_len, np.int32)])

    eng = AlignmentEngine(wfa_paper.pen, backend=backend, chunk_pairs=pairs)
    variants = []
    for hname, heur in HEURISTICS:
        def run_one(heur=heur):
            eng.align_packed(P, plen, T, tlen, heuristic=heur)
        run_one()                                # warm the executable
        variants.append((f"scoring/{backend}/affine/{hname}-mixed",
                         run_one))
    best = {name: float("inf") for name, _ in variants}
    for _ in range(rounds):
        for name, fn in variants:
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return [(name, best[name] / pairs * 1e6,
             f"{pairs / best[name]:,.0f}pairs/s "
             f"({divergent_frac:.0%} divergent, exact bounds)")
            for name, _ in variants]


def _pairs_per_s(rows: list[Row], name: str) -> float:
    for n, us, _ in rows:
        if n == name:
            return 1e6 / us
    raise KeyError(name)


def check(rows: list[Row], backend: str = "ring") -> list[str]:
    """The CI gate: each claim against its own batch.

    Edit mode must beat exact gap-affine on the convergent grid batch;
    adaptive pruning must beat exact on the mixed (divergent-fraction)
    batch.  Both margins are structural (shorter score loop / pruned
    band), not measurement luck.
    """
    failures = []
    for variant, baseline in (
            (f"scoring/{backend}/edit/exact",
             f"scoring/{backend}/affine/exact"),
            (f"scoring/{backend}/affine/adaptive-mixed",
             f"scoring/{backend}/affine/exact-mixed")):
        got = _pairs_per_s(rows, variant)
        base = _pairs_per_s(rows, baseline)
        if got < base:
            failures.append(f"{variant}: {got:,.0f} pairs/s < "
                            f"{baseline}: {base:,.0f} pairs/s")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=2048)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if edit-mode or adaptive-pruning "
                         "throughput regresses below exact gap-affine")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: read rows from the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running the grid (CI runs the smoke once and "
                         "gates on its output)")
    args = ap.parse_args(argv)
    from benchmarks.common import emit
    if args.from_json:
        from benchmarks.common import rows_from_json
        rows = rows_from_json(args.from_json, "scoring/")
    else:
        rows = run(pairs=args.pairs)
        emit(rows)
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"# scoring REGRESSION: {f}", file=sys.stderr)
        if failures:
            return 1
        print("# scoring gate passed: edit/adaptive >= exact affine",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
