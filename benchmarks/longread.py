"""Long-read traceback: BiWFA's O(s) trace memory vs the packed O(s^2).

The packed 2-bit backtrace that makes short-read CIGARs nearly free keeps
``ceil(s/16)`` provenance words per wavefront cell — at ONT/PacBio lengths
(10-100 kb, thousands of score steps) that resident trace is the binding
constraint, not compute.  ``trace_variant="bidir"`` (``repro.biwfa``)
replaces it with a meet-in-the-middle recursion whose resident state is
two O(s)-deep rolling windows plus sub-traces capped by the trace budget.

This suite measures the trade on ONT-profile pairs
(``data.reads.sample_from_reference``: lognormal-length regime, 40/30/30
sub/ins/del mix) and emits the rows the CI gate (``--check``) enforces:

* **score parity** — bidir scores identical to the packed oracle, and
  every bidir CIGAR re-scores *exactly* to that cost (all lengths);
* **trace memory** — resident-trace high-water mark ratio >= 8x at
  L = 10 kb (the headline O(s) vs O(s^2) claim);
* **throughput** — bidir within 2x of packed at L = 1 kb, where the
  packed path is still comfortable (the score-pass + capped-trace split
  must not tank short workloads);
* **L = 50 kb** — one long pair aligns to an exact CIGAR without
  exceeding the configured trace budget.

``--check --from-json GLOB`` gates the newest ``benchmarks.run --json``
snapshot instead of re-running — and fails if the snapshot has no
longread rows at all, so the per-commit perf trajectory must include
this suite from now on.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Row
from repro.configs import wfa_paper
from repro.core import gotoh
from repro.core.engine import AlignmentEngine
from repro.biwfa import DEFAULT_TRACE_BUDGET
from repro.data.reads import sample_from_reference

MEM_RATIO_GATE = 8.0       # bidir resident trace >= 8x under packed @ 10kb
SLOWDOWN_GATE = 2.0        # bidir wall <= 2x packed @ 1kb


def _ont_pairs(L: int, n: int, div: float, seed: int):
    """(patterns, texts): reference windows + ONT-profile mutated reads."""
    rng = np.random.default_rng(seed)
    ref = rng.choice(np.frombuffer(b"ACGT", np.uint8), size=L * (n + 2))
    reads = sample_from_reference(ref, n, read_len=L, edit_frac=div,
                                  rc_frac=0.0, error_profile="ont",
                                  seed=seed)
    pats = [ref[r.pos: r.pos + r.win_len] for r in reads]
    texts = [r.read for r in reads]
    return pats, texts


def _rescore_exact(res, pats, texts, pen) -> bool:
    for i, (p, t) in enumerate(zip(pats, texts)):
        cost, ci, cj, ok = gotoh.score_cigar(res.cigars[i], p, t, pen)
        if not (ok and ci == len(p) and cj == len(t)
                and cost == res.scores[i]):
            return False
    return True


def _best_of(fn, n=2):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(pairs: int = 32, long_pair: bool = True) -> list[Row]:
    pen = wfa_paper.pen
    rows: list[Row] = []

    # -- L = 1 kb: throughput — the capped split must not tank the short
    # regime where packed is still comfortable
    L, div, n = 1000, 0.05, max(4, min(pairs, 32))
    pats, texts = _ont_pairs(L, n, div, seed=21)
    eng = AlignmentEngine(pen, backend="ring", edit_frac=div)
    packed = eng.align(pats, texts, output="cigar")              # warm
    bidir = eng.align(pats, texts, output="cigar",
                      trace_variant="bidir")
    parity = (np.array_equal(packed.scores, bidir.scores)
              and _rescore_exact(bidir, pats, texts, pen))
    t_packed = _best_of(lambda: eng.align(pats, texts, output="cigar"))
    t_bidir = _best_of(lambda: eng.align(pats, texts, output="cigar",
                                         trace_variant="bidir"))
    slowdown = t_bidir / t_packed
    rows += [
        (f"longread/L={L}/packed", t_packed / n * 1e6,
         f"{n / t_packed:,.0f} pairs/s packed trace "
         f"(peak {packed.stats.peak_trace_bytes / 1e6:.2f} MB)"),
        (f"longread/L={L}/bidir", t_bidir / n * 1e6,
         f"{n / t_bidir:,.0f} pairs/s bidir trace "
         f"(peak {bidir.stats.peak_trace_bytes / 1e6:.2f} MB, "
         f"{bidir.stats.n_bidir_fallback} fallbacks)"),
        (f"longread/L={L}/slowdown", slowdown,
         f"bidir/packed wall (gate <= {SLOWDOWN_GATE:.0f}x)"),
        (f"longread/L={L}/parity", float(parity),
         "bidir scores == packed, CIGARs re-score exact (gate == 1)"),
    ]

    # -- L = 10 kb: the headline — resident-trace high-water mark
    L, div, n = 10000, 0.03, 2
    pats, texts = _ont_pairs(L, n, div, seed=22)
    eng = AlignmentEngine(pen, backend="ring", edit_frac=div)
    packed = eng.align(pats, texts, output="cigar")
    bidir = eng.align(pats, texts, output="cigar",
                      trace_variant="bidir")
    parity = (np.array_equal(packed.scores, bidir.scores)
              and _rescore_exact(bidir, pats, texts, pen))
    pk, bd = packed.stats.peak_trace_bytes, bidir.stats.peak_trace_bytes
    ratio = pk / max(bd, 1)
    rows += [
        (f"longread/L={L}/trace_memory", ratio,
         f"packed={pk / 1e6:.2f}MB bidir={bd / 1e6:.3f}MB resident "
         f"high-water (gate >= {MEM_RATIO_GATE:.0f}x)"),
        (f"longread/L={L}/parity", float(parity),
         "bidir scores == packed, CIGARs re-score exact (gate == 1)"),
    ]

    # -- L = 50 kb: one pair end to end — exact CIGAR, budget respected
    if long_pair:
        L, div = 50000, 0.01
        pats, texts = _ont_pairs(L, 1, div, seed=23)
        eng = AlignmentEngine(pen, backend="ring", edit_frac=div)
        t0 = time.perf_counter()
        res = eng.align(pats, texts, output="cigar",
                        trace_variant="bidir")
        wall = time.perf_counter() - t0
        # budget is in trace *cells* (s * (plen+tlen)); the packed child
        # traces pack 16 cells per int32 word, so cells is a ~4x-headroom
        # byte bound on the resident trace
        budget = eng.trace_budget or DEFAULT_TRACE_BUDGET
        exact = (int(res.scores[0]) >= 0
                 and _rescore_exact(res, pats, texts, pen)
                 and res.stats.peak_trace_bytes <= budget)
        rows.append((f"longread/L={L}/exact", float(exact),
                     f"1 pair in {wall:.1f}s, score={int(res.scores[0])}, "
                     f"peak trace {res.stats.peak_trace_bytes / 1e6:.2f} MB "
                     f"<= budget bound {budget / 1e6:.0f} MB (gate == 1)"))
    return rows


def _value(rows: list[Row], name: str) -> float:
    for n, v, _ in rows:
        if n == name:
            return v
    raise KeyError(name)


def check(rows: list[Row]) -> list[str]:
    """The CI gate over longread rows (live or from a JSON snapshot)."""
    failures = []
    if not rows:
        return ["no longread rows in snapshot — the bench smoke must "
                "include --only ...,longread"]
    for name, v, _ in rows:
        if name.endswith("/parity") and v != 1.0:
            failures.append(f"{name}: bidir diverged from the packed "
                            "oracle (scores or CIGAR re-score)")
        if name.endswith("/exact") and v != 1.0:
            failures.append(f"{name}: long pair failed to align exactly "
                            "within the trace budget")
    slowdown = _value(rows, "longread/L=1000/slowdown")
    if slowdown > SLOWDOWN_GATE:
        failures.append(f"longread/L=1000/slowdown: bidir {slowdown:.2f}x "
                        f"slower than packed > {SLOWDOWN_GATE:.0f}x")
    ratio = _value(rows, "longread/L=10000/trace_memory")
    if ratio < MEM_RATIO_GATE:
        failures.append(f"longread/L=10000/trace_memory: {ratio:.1f}x "
                        f"< {MEM_RATIO_GATE:.0f}x packed-vs-bidir resident "
                        "trace")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=32)
    ap.add_argument("--no-long-pair", action="store_true",
                    help="skip the L=50kb single-pair row")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless bidir matches the packed "
                         "oracle, trace memory is >= 8x under packed at "
                         "L=10kb, and bidir is within 2x of packed at "
                         "L=1kb")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: gate on the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running")
    args = ap.parse_args(argv)
    from benchmarks.common import emit
    if args.from_json:
        from benchmarks.common import rows_from_json
        rows = rows_from_json(args.from_json, "longread/")
    else:
        rows = run(pairs=args.pairs, long_pair=not args.no_long_pair)
        emit(rows)
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"# longread REGRESSION: {f}", file=sys.stderr)
        if failures:
            return 1
        print("# longread gate passed: bidir exact, trace memory "
              f">={MEM_RATIO_GATE:.0f}x under packed @10kb, within "
              f"{SLOWDOWN_GATE:.0f}x throughput @1kb", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
