"""Micro-decomposition of the WFA inner loop (DPU-kernel ops): cost of one
score step (recurrences) vs one extension trip vs the one-hot char fetch —
the quantities the Pallas kernel's VMEM schedule is built around."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.configs import wfa_paper
from repro.core.engine import AlignmentEngine, problem_bounds
from repro.core.wavefront import NEG, _extend, wfa_scores
from repro.data.reads import ReadPairSpec, generate_pairs


def run(batch: int = 1024, read_len: int = 100,
        edit_frac: float = 0.02) -> list[Row]:
    spec = ReadPairSpec(n_pairs=batch, read_len=read_len,
                        edit_frac=edit_frac, seed=3)
    P, plen, T, tlen = generate_pairs(spec)
    s_max, k_max = problem_bounds(wfa_paper.pen, plen, tlen, edit_frac)
    K = 2 * k_max + 1
    Pj, Tj = jnp.asarray(P), jnp.asarray(T)
    plj, tlj = jnp.asarray(plen), jnp.asarray(tlen)
    ks = jnp.arange(K, dtype=jnp.int32) - k_max

    rows: list[Row] = []

    # full solve
    sec = time_fn(lambda: wfa_scores(Pj, Tj, plj, tlj, pen=wfa_paper.pen,
                                     s_max=s_max, k_max=k_max).score,
                  warmup=1, iters=3)
    res = wfa_scores(Pj, Tj, plj, tlj, pen=wfa_paper.pen, s_max=s_max,
                     k_max=k_max)
    steps = int(res.n_steps)
    rows.append((f"wfa_ops/full-solve-b{batch}", sec * 1e6,
                 f"{batch / sec:,.0f} pairs/s, {steps} score steps"))
    rows.append((f"wfa_ops/per-score-step-b{batch}", sec / steps * 1e6,
                 f"K={K} diagonals live"))

    # one extension trip in isolation (jitted)
    M0 = jnp.full((batch, K), NEG, jnp.int32).at[:, k_max].set(0)
    ext = jax.jit(lambda M: _extend(M, Pj, Tj, plj, tlj, ks))
    sec_e = time_fn(ext, M0, warmup=1, iters=3)
    rows.append((f"wfa_ops/extend-full-lcp-b{batch}", sec_e * 1e6,
                 "greedy LCP along all diagonals (worst-case trips)"))

    # the gather primitive (take_along_axis char fetch)
    idx = jnp.clip(M0, 0, Tj.shape[1] - 1)
    fetch = jax.jit(lambda i: jnp.take_along_axis(Tj, i, axis=1))
    sec_f = time_fn(fetch, idx, warmup=1, iters=5)
    rows.append((f"wfa_ops/char-fetch-b{batch}", sec_f * 1e6,
                 f"[B={batch},K={K}] gather"))

    # end-to-end engine path (bucketing + executable cache + recovery):
    # the Total-vs-Kernel overhead the micro-ops above decompose
    eng = AlignmentEngine(wfa_paper.pen, backend="ring", edit_frac=edit_frac)
    eng.align_packed(P, plen, T, tlen)          # compile / populate cache
    sec_g = time_fn(lambda: eng.align_packed(P, plen, T, tlen).scores,
                    warmup=1, iters=3)
    res = eng.align_packed(P, plen, T, tlen)
    rows.append((f"wfa_ops/engine-cached-b{batch}", sec_g * 1e6,
                 f"{batch / sec_g:,.0f} pairs/s, "
                 f"{res.stats.cache_hits} cache hits, "
                 f"{res.stats.n_traces} retraces"))
    return rows
