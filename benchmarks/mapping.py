"""Read-mapping throughput: index build, candidate generation, end-to-end.

The mapping subsystem's contract is that the WFA extension stage — the
part the paper accelerates — dominates end-to-end time, with seeding and
chaining as bounded overhead on top.  This suite tracks the stages
separately and the ratio that enforces the contract:

* ``mapping/index_build`` — minimizer index construction rate (Mbp/s);
* ``mapping/candidates``  — seed + chain only (candidates/read derived);
* ``mapping/map``         — full seed-chain-extend-trim per read through
  ``AlignmentEngine.stream()`` (mappings/s);
* ``mapping/pairwise``    — the same engine aligning the same number of
  same-length pairs with CIGARs, no mapping stages (pairs/s) — the
  paper's raw workload as the baseline.

``main(--check)`` is the CI gate: end-to-end mappings/s must stay within
``--max-ratio`` (default 10x) of raw pairwise pairs/s at the same read
count.  If indexing or chaining ever swamps extension, the ratio blows
past the bound and the build fails.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Row
from repro.data.dna import random_reference
from repro.data.reads import (ReadPairSpec, generate_pairs,
                              sample_from_reference)
from repro.mapping.chain import candidates
from repro.mapping.extend import ReadMapper
from repro.mapping.index import MinimizerIndex


def run(reads: int = 512, read_len: int = 100, ref_len: int = 200_000,
        edit_frac: float = 0.02, backend: str = "ring",
        rounds: int = 3) -> list[Row]:
    rows: list[Row] = []
    ref = random_reference(ref_len, seed=5)

    # index build rate (fresh build each round — build cost is the point)
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        index = MinimizerIndex.build([ref], ["chr1"])
        best = min(best, time.perf_counter() - t0)
    rows.append(("mapping/index_build", best * 1e6,
                 f"{ref_len / best / 1e6:.1f}Mbp/s "
                 f"{index.nbytes() / 1e6:.1f}MB"))

    sampled = sample_from_reference(ref, reads, read_len=read_len,
                                    edit_frac=edit_frac, seed=9)
    batch = [r.read for r in sampled]

    # seed + chain only (no extension)
    def run_candidates():
        for r in batch:
            candidates(index, r, top_n=2)
    run_candidates()
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        run_candidates()
        best = min(best, time.perf_counter() - t0)
    n_cand = sum(len(candidates(index, r, top_n=2)) for r in batch)
    rows.append(("mapping/candidates", best / reads * 1e6,
                 f"{reads / best:,.0f}reads/s "
                 f"{n_cand / reads:.2f}cand/read"))

    # end-to-end mapping vs raw pairwise through the SAME engine: the
    # pairwise batch lands in the same length bucket, so the ratio
    # isolates the mapping stages + window padding, not compile shapes
    mapper = ReadMapper(index, top_n=2, edit_frac=edit_frac,
                        read_len=read_len, backend=backend)
    spec = ReadPairSpec(n_pairs=reads, read_len=read_len,
                        edit_frac=edit_frac, seed=9)
    P, plen, T, tlen = generate_pairs(spec)

    def run_map():
        mapper.map(batch)

    def run_pairwise():
        mapper.engine.align_packed(P, plen, T, tlen, output="cigar")

    variants = []
    for name, fn in (("mapping/map", run_map),
                     ("mapping/pairwise", run_pairwise)):
        fn()                               # warm executables
        variants.append((name, fn))
    best = {name: float("inf") for name, _ in variants}
    for _ in range(rounds):                # interleaved: fair under drift
        for name, fn in variants:
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    rows.append(("mapping/map", best["mapping/map"] / reads * 1e6,
                 f"{reads / best['mapping/map']:,.0f}mappings/s"))
    rows.append(("mapping/pairwise",
                 best["mapping/pairwise"] / reads * 1e6,
                 f"{reads / best['mapping/pairwise']:,.0f}pairs/s"))
    return rows


def _per_s(rows: list[Row], name: str) -> float:
    for n, us, _ in rows:
        if n == name:
            return 1e6 / us
    raise KeyError(name)


def check(rows: list[Row], max_ratio: float = 10.0) -> list[str]:
    """CI gate: extension must dominate end-to-end mapping time."""
    mapped = _per_s(rows, "mapping/map")
    pairwise = _per_s(rows, "mapping/pairwise")
    if mapped * max_ratio < pairwise:
        return [f"mapping/map: {mapped:,.0f} mappings/s is more than "
                f"{max_ratio:.0f}x below mapping/pairwise: "
                f"{pairwise:,.0f} pairs/s — seeding/chaining dominates"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reads", type=int, default=512)
    ap.add_argument("--ref-len", type=int, default=200_000)
    ap.add_argument("--max-ratio", type=float, default=10.0,
                    help="--check: max allowed pairwise/mapping "
                         "throughput ratio")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) when mappings/s falls more than "
                         "--max-ratio below raw pairwise throughput")
    ap.add_argument("--from-json", default=None, metavar="GLOB",
                    help="with --check: read rows from the newest matching "
                         "benchmarks.run --json snapshot instead of "
                         "re-running")
    args = ap.parse_args(argv)
    from benchmarks.common import emit
    if args.from_json:
        from benchmarks.common import rows_from_json
        rows = rows_from_json(args.from_json, "mapping/")
    else:
        rows = run(reads=args.reads, ref_len=args.ref_len)
        emit(rows)
    if args.check:
        failures = check(rows, max_ratio=args.max_ratio)
        for f in failures:
            print(f"# mapping REGRESSION: {f}", file=sys.stderr)
        if failures:
            return 1
        print("# mapping gate passed: extension dominates end-to-end",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
