"""The serve loop: worker threads feeding one shared AlignmentSession.

This is the always-on layer over the streaming engine: callers (any
thread) ``submit()`` independent :class:`AlignRequest`s; the bounded
:class:`RequestQueue` admits or sheds them; worker threads drain
admissions into the :class:`WaveFormer`, dispatch flush-ready waves into
one shared :class:`~repro.core.session.AlignmentSession` (whose per-bucket
executable cache guarantees zero retraces at steady state), and deliver
out-of-order wave retirements back to per-request futures via the
session's non-blocking ``poll()``.  Per-request penalty model, heuristic
and output mode ride the engine's existing per-submit seams — a mixed
traffic stream compiles one executable per (seams, bucket) key and then
never retraces.

The JetStream-style split (model: MaxText's ``OfflineInference`` harness —
background threads around cached per-shape executables): the *device* is
saturated by JAX async dispatch + session backpressure; the *threads* only
run host-side work (packing, wave forming, traceback, delivery), which
overlaps the in-flight kernels.

:class:`ServerStats` is the observable contract: queue depth, wave
occupancy / padding waste, shed count, and p50/p95/p99 request latency.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import AlignmentEngine, Seq
from repro.obs import metrics as obs_metrics
from repro.obs import record as obs_record
from repro.obs import trace as obs_trace
from repro.serve.queue import RequestQueue
from repro.serve.request import AlignFuture, AlignRequest
from repro.serve.waves import FormedWave, WaveFormer

__all__ = ["ServeLoop", "ServerStats"]


@dataclasses.dataclass(frozen=True)
class ServerStats:
    """One consistent snapshot of the service (``ServeLoop.stats()``).

    Latency percentiles come from the loop's bounded
    :class:`repro.obs.metrics.Histogram` (log-bucketed, so each is within
    one bucket — ≤19% — of exact, in constant memory no matter how long
    the service runs); ``latency_mean``/``latency_max`` stay exact.  The
    same histogram backs the Prometheus ``serve_request_latency_seconds``
    series, so a scrape and this snapshot always agree.
    """
    uptime: float
    queue_depth: int             # admitted, not yet wave-formed
    pending_pairs: int           # forming (accumulated, not dispatched)
    inflight_waves: int
    n_offered: int
    n_accepted: int
    n_shed: int
    n_completed: int
    n_outstanding: int           # accepted, future not yet resolved
    n_pairs_done: int
    n_waves: int                 # device waves dispatched (incl. recovery)
    waves_full: int              # flush reasons (wave-forming telemetry)
    waves_deadline: int
    waves_drain: int
    wave_occupancy: float        # request rows / device rows dispatched
    padding_waste_frac: float
    n_retraces: int              # fresh XLA traces since start (0 = warm)
    cache_hits: int
    cache_misses: int
    latency_p50: float           # seconds, arrival -> future resolution
    latency_p95: float
    latency_p99: float
    latency_mean: float
    latency_max: float
    n_latency_samples: int

    @property
    def completed_pairs_per_s(self) -> float:
        return self.n_pairs_done / max(self.uptime, 1e-12)


class ServeLoop:
    """Always-on alignment service over one :class:`AlignmentEngine`.

    Parameters
    ----------
    engine : the (ideally pre-warmed) engine; its executable cache is
        what makes steady-state serving retrace-free.
    wave_pairs : rows per formed wave (the flush-when-full threshold and
        the device batch shape when ``pad_waves``).
    form_deadline : seconds a forming wave may wait for company before a
        deadline flush (the latency end of the deadline-vs-throughput
        dial; per-request ``deadline=`` can only shorten it).
    max_queue_depth : admission bound — arrivals beyond it are shed with
        a typed :class:`~repro.serve.request.ShedError`.
    max_inflight_waves : session backpressure (device memory bound).
    n_threads : worker threads sharing the session (host-side work
        overlaps in-flight kernels; 1 is enough at CPU smoke scale).
    pad_waves : pad partial (deadline/drain) flushes to ``wave_pairs``
        rows in-bucket so every wave hits one cached executable shape.
    poll_interval : worker nap between polls when nothing progressed.
    """

    def __init__(self, engine: AlignmentEngine, *, wave_pairs: int = 256,
                 form_deadline: float = 0.02, max_queue_depth: int = 1024,
                 max_inflight_waves: int = 2, n_threads: int = 1,
                 pad_waves: bool = True, poll_interval: float = 1e-3,
                 min_bucket_len: Optional[int] = None):
        if n_threads < 1:
            raise ValueError("n_threads must be >= 1")
        self.engine = engine
        self.wave_pairs = int(wave_pairs)
        self.n_threads = int(n_threads)
        self.max_inflight_waves = int(max_inflight_waves)
        self.poll_interval = float(poll_interval)
        self._queue = RequestQueue(max_queue_depth)
        self._former = WaveFormer(
            wave_pairs, form_deadline, pad_to_full=pad_waves,
            min_bucket_len=(engine.min_bucket_len if min_bucket_len is None
                            else min_bucket_len))
        self._mutex = threading.RLock()
        self._session = None
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._started = False
        self._error: Optional[BaseException] = None
        self._live: set = set()          # accepted, future unresolved
        # bounded latency distribution (satellite fix: this replaced an
        # ever-growing stored sample list) — per-loop so concurrent/warm
        # loops don't pollute each other; attached to the global registry
        # at start() so a Prometheus scrape sees the live server's series
        self._latency_hist = obs_metrics.Histogram(
            "serve_request_latency_seconds",
            "arrival -> future-resolution latency")
        self._t_start = 0.0
        self._n_accepted = 0
        self._n_completed = 0
        self._n_pairs_done = 0
        self._pairs_real = 0             # request rows dispatched
        self._wave_reasons: Dict[str, int] = {"full": 0, "deadline": 0,
                                              "drain": 0}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ServeLoop":
        if self._started:
            raise RuntimeError("server already started")
        self._started = True
        self._t_start = time.monotonic()
        obs_metrics.REGISTRY.attach(self._latency_hist)
        # Flight recorder: a live server always keeps the post-mortem
        # ring warm, so a shed/timeout/failure can dump recent history
        # even when full tracing is off.  Released in stop().
        obs_record.acquire()
        self._rec_held = True
        self._session = self.engine.stream(
            max_inflight_waves=self.max_inflight_waves,
            wave_pairs=self.wave_pairs)
        for i in range(self.n_threads):
            th = threading.Thread(target=self._run, daemon=True,
                                  name=f"serve-align-{i}")
            th.start()
            self._threads.append(th)
        return self

    def stop(self) -> ServerStats:
        """Stop admissions, drain everything in flight, join workers.

        Every accepted request's future is resolved before this returns
        (with a result, or with the loop's failure if one occurred).
        """
        try:
            self._stop.set()
            self._queue.close()
            for th in self._threads:
                th.join()
            self._threads = []
            if self._error is not None:
                raise RuntimeError("serve loop failed") from self._error
            if self._session is not None:
                self._session.close()
            return self.stats()
        finally:
            if getattr(self, "_rec_held", False):
                self._rec_held = False
                obs_record.release()

    def __enter__(self) -> "ServeLoop":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False

    # -- submission (any thread) ---------------------------------------------

    def submit(self, patterns: Sequence[Seq], texts: Sequence[Seq], *,
               penalties=None, heuristic=None, output: Optional[str] = None,
               deadline: Optional[float] = None) -> AlignFuture:
        """Pack on the caller's thread, then admit. Returns the future."""
        return self.submit_request(AlignRequest.from_seqs(
            patterns, texts, penalties=penalties, heuristic=heuristic,
            output=output, deadline=deadline))

    def submit_packed(self, p, plen, t, tlen, *, penalties=None,
                      heuristic=None, output: Optional[str] = None,
                      deadline: Optional[float] = None) -> AlignFuture:
        return self.submit_request(AlignRequest(
            p, plen, t, tlen, penalties=penalties, heuristic=heuristic,
            output=output, deadline=deadline))

    def submit_request(self, req: AlignRequest) -> AlignFuture:
        """Admission control: resolve the request's seams, then offer it
        to the bounded queue.  The returned future resolves exactly once —
        with an :class:`AlignResult`, the resolution error, or a
        :class:`~repro.serve.request.ShedError`."""
        if not self._started:
            raise RuntimeError("server not started")
        try:
            # fail fast (typed, on the future) before the queue ever sees
            # an un-servable request — same checks a session submit runs
            req.pen = self.engine.resolve_penalties(req.penalties)
            req.out = self.engine.resolve_output(req.output, req.pen)
            req.heur = self.engine.resolve_heuristic(req.heuristic, req.out)
        except Exception as e:
            req.future.set_exception(e)
            return req.future
        if req.n_pairs == 0:
            req.t_arrival = time.monotonic()
            with self._mutex:
                self._n_accepted += 1
                self._n_completed += 1
                self._latency_hist.observe(req._resolve(req.t_arrival))
            return req.future
        with obs_trace.span("serve.admit", cat="serve",
                            args={"request": req.request_id,
                                  "pairs": req.n_pairs}
                            if obs_trace.enabled() else None) as sp:
            if obs_trace.enabled():
                # the request's flow: the arrow Perfetto draws from this
                # admit through form/dispatch/kernel/retire to delivery
                req.flow_id = obs_trace.new_flow()
                sp.flow_start(req.flow_id)
            with self._mutex:
                self._live.add(req)
                self._n_accepted += 1
            if not self._queue.offer(req):   # shed: future already resolved
                with self._mutex:
                    self._live.discard(req)
                    self._n_accepted -= 1
                obs_metrics.counter("serve_shed_total",
                                    "requests rejected by admission "
                                    "control").inc()
                if obs_trace.enabled():
                    obs_trace.instant("serve.shed", cat="serve",
                                      args={"request": req.request_id})
                obs_record.dump("shed",
                                {"request": req.request_id,
                                 "n_pairs": req.n_pairs,
                                 "queue_depth": len(self._queue)})
        return req.future

    # -- observability -------------------------------------------------------

    def stats(self) -> ServerStats:
        with self._mutex:
            lat = self._latency_hist
            sess = self._session.stats if self._session is not None else None
            return ServerStats(
                uptime=(time.monotonic() - self._t_start
                        if self._started else 0.0),
                queue_depth=len(self._queue),
                pending_pairs=self._former.n_pending,
                inflight_waves=(self._session.n_inflight
                                if self._session is not None else 0),
                n_offered=self._queue.n_offered,
                n_accepted=self._n_accepted,
                n_shed=self._queue.n_shed,
                n_completed=self._n_completed,
                n_outstanding=len(self._live),
                n_pairs_done=self._n_pairs_done,
                n_waves=sess.n_waves if sess else 0,
                waves_full=self._wave_reasons["full"],
                waves_deadline=self._wave_reasons["deadline"],
                waves_drain=self._wave_reasons["drain"],
                wave_occupancy=(self._pairs_real / sess.rows_padded
                                if sess and sess.rows_padded else 1.0),
                padding_waste_frac=(1.0 - self._pairs_real / sess.rows_padded
                                    if sess and sess.rows_padded else 0.0),
                n_retraces=sess.n_traces if sess else 0,
                cache_hits=sess.cache_hits if sess else 0,
                cache_misses=sess.cache_misses if sess else 0,
                latency_p50=lat.quantile(0.5), latency_p95=lat.quantile(0.95),
                latency_p99=lat.quantile(0.99),
                latency_mean=lat.mean,
                latency_max=lat.max if lat.count else float("nan"),
                n_latency_samples=lat.count)

    # -- worker loop ---------------------------------------------------------

    def _idle(self) -> bool:
        with self._mutex:
            return (len(self._queue) == 0 and self._former.n_pending == 0
                    and not self._live)

    def _run(self) -> None:
        try:
            while True:
                progressed = self._serve_step(time.monotonic())
                if self._stop.is_set() and self._idle():
                    return
                if not progressed:
                    timeout = self.poll_interval
                    with self._mutex:
                        nd = self._former.next_deadline()
                    if nd is not None:
                        timeout = min(timeout, nd - time.monotonic())
                    self._queue.wait(max(timeout, 1e-4))
        except BaseException as e:         # noqa: BLE001 - fail the service
            self._fail(e)

    def _serve_step(self, now: float) -> bool:
        """One scheduling round: admit -> form -> dispatch -> deliver."""
        progressed = False
        arrivals = self._queue.drain()
        obs_metrics.gauge("serve_queue_depth",
                          "admitted requests not yet wave-formed"
                          ).set(len(self._queue))
        obs_trace.counter("queue_depth", len(self._queue), cat="serve")
        if arrivals:
            progressed = True
            with self._mutex:
                for req in arrivals:
                    self._former.add(req, now)
        with self._mutex:
            waves = (self._former.flush_all() if self._stop.is_set()
                     else self._former.take_ready(now))
        if waves:
            with obs_trace.span("serve.form", cat="serve",
                                args={"waves": len(waves)}
                                if obs_trace.enabled() else None) as sp:
                for wave in waves:
                    for sl in wave.slices:
                        if sl.request.flow_id:
                            sp.flow_step(sl.request.flow_id)
        for wave in waves:
            progressed = True
            self._dispatch(wave)
        for ticket in self._session.poll():
            progressed = True
            self._deliver(ticket)
        return progressed

    def _dispatch(self, wave: FormedWave) -> None:
        pen, heur, out, _bucket = wave.key
        flows = tuple(sl.request.flow_id for sl in wave.slices
                      if sl.request.flow_id)
        with obs_trace.span("serve.dispatch", cat="serve",
                            args={"rows": int(wave.p.shape[0]),
                                  "real": wave.n_real,
                                  "reason": wave.reason}
                            if obs_trace.enabled() else None) as sp:
            for fid in flows:
                sp.flow_step(fid)
            ticket = self._session.submit_packed(
                wave.p, wave.plen, wave.t, wave.tlen, output=out,
                penalties=pen, heuristic=heur, meta=wave,
                _flows=flows)
        del ticket
        with self._mutex:
            self._pairs_real += wave.n_real
            self._wave_reasons[wave.reason] += 1

    def _deliver(self, ticket) -> None:
        wave: FormedWave = ticket.meta
        res = ticket.result()                # completed: no blocking
        now = time.monotonic()
        with obs_trace.span("serve.deliver", cat="serve",
                            args={"slices": len(wave.slices)}
                            if obs_trace.enabled() else None) as sp, \
                self._mutex:
            for sl in wave.slices:
                scores = res.scores[sl.row_lo: sl.row_lo + sl.n]
                cigars = (res.cigars[sl.row_lo: sl.row_lo + sl.n]
                          if res.cigars is not None else None)
                done = sl.request._deliver_rows(
                    slice(sl.req_lo, sl.req_lo + sl.n), scores, cigars)
                if done:
                    if sl.request.flow_id:
                        sp.flow_end(sl.request.flow_id)
                    self._latency_hist.observe(sl.request._resolve(now))
                    self._live.discard(sl.request)
                    self._n_completed += 1
                    self._n_pairs_done += sl.request.n_pairs

    def _fail(self, e: BaseException) -> None:
        """Poison the service: every unresolved accepted future gets the
        failure (exactly-once answering holds even on the error path)."""
        obs_record.dump("serve_failure", {"error": repr(e)})
        with self._mutex:
            if self._error is None:
                self._error = e
            live = list(self._live)
            self._live.clear()
        self._stop.set()
        self._queue.close()
        for req in self._queue.drain():
            live.append(req)
        for req in live:
            try:
                req.future.set_exception(e)
            except Exception:                # already resolved: keep first
                pass
