"""Deadline-or-full continuous batching: requests -> well-formed waves.

The engine's executable cache serves zero-retrace steady state only when
traffic keeps arriving in the same few rectangular shapes; independent
requests arrive one at a time in whatever shape they like.  The
:class:`WaveFormer` is the adapter: it accumulates compatible requests —
same resolved (penalty model, heuristic, output mode), greedily grouped
by the power-of-two length bucket their longest sequence lands in — and
flushes a group as one wave when either

* it is **full** (``wave_pairs`` rows — the MRAM-capacity analogue), or
* the **forming deadline** of its oldest member expires
  (``arrival + min(form_deadline, request.deadline)``): a lonely request
  rides a mostly-padding wave rather than waiting forever for company.

``pad_to_full`` (the default) pads every flushed wave up to ``wave_pairs``
rows with self-aligning dummy rows *in the same length bucket*, so the
session dispatches exactly one batch shape per (bucket, seams) key and
the executable cache stays warm even for deadline-flushed stragglers —
the padding cost is visible, not hidden: it is exactly what
``ServerStats.padding_waste_frac`` reports.

Requests larger than a wave are split across consecutive waves of the
same group; delivery tracks per-request outstanding rows, so a split
request still resolves exactly once.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import _fit_width, _next_pow2
from repro.serve.request import AlignRequest

__all__ = ["FormedWave", "WaveFormer", "WaveSlice"]


@dataclasses.dataclass
class WaveSlice:
    """Rows ``[row_lo, row_lo + n)`` of a wave belong to ``request`` rows
    ``[req_lo, req_lo + n)``."""
    request: AlignRequest
    req_lo: int
    row_lo: int
    n: int


@dataclasses.dataclass
class FormedWave:
    """One flush-ready wave: stacked arrays + the slices that own them."""
    key: tuple                   # (pen, heur, output, bucket)
    slices: List[WaveSlice]
    p: np.ndarray
    plen: np.ndarray
    t: np.ndarray
    tlen: np.ndarray
    n_real: int                  # request rows (excludes pad rows)
    reason: str                  # "full" | "deadline" | "drain"

    @property
    def n_rows(self) -> int:
        return int(self.p.shape[0])


class _Group:
    """One forming bucket: compatible request slices awaiting flush."""

    def __init__(self, key: tuple):
        self.key = key
        self.members: List[Tuple[AlignRequest, int, int]] = []  # (req, lo, hi)
        self.n_rows = 0
        self.deadline: Optional[float] = None    # oldest member's

    def add(self, req: AlignRequest, lo: int, hi: int,
            member_deadline: float) -> None:
        self.members.append((req, lo, hi))
        self.n_rows += hi - lo
        if self.deadline is None or member_deadline < self.deadline:
            self.deadline = member_deadline


class WaveFormer:
    """Groups compatible requests into deadline-or-full waves."""

    def __init__(self, wave_pairs: int, form_deadline: float, *,
                 min_bucket_len: int = 16, pad_to_full: bool = True):
        if wave_pairs < 1:
            raise ValueError("wave_pairs must be >= 1")
        if form_deadline <= 0:
            raise ValueError("form_deadline must be > 0")
        self.wave_pairs = int(wave_pairs)
        self.form_deadline = float(form_deadline)
        self.min_bucket_len = int(min_bucket_len)
        self.pad_to_full = bool(pad_to_full)
        self._groups: Dict[tuple, _Group] = {}
        self._full: List[_Group] = []
        self.n_formed = 0

    # -- state ---------------------------------------------------------------

    @property
    def n_pending(self) -> int:
        """Rows accumulated but not yet flushed."""
        return (sum(g.n_rows for g in self._groups.values())
                + sum(g.n_rows for g in self._full))

    def next_deadline(self) -> Optional[float]:
        """Earliest forming deadline across open groups (loop wake-up)."""
        deadlines = [g.deadline for g in self._groups.values()
                     if g.deadline is not None]
        return min(deadlines) if deadlines else None

    # -- accumulate ----------------------------------------------------------

    def add(self, req: AlignRequest, now: float) -> None:
        """File one admitted request into its forming group (splitting
        across waves when it is larger than ``wave_pairs``)."""
        bucket = _next_pow2(max(req.max_len, self.min_bucket_len))
        key = (req.pen, req.heur, req.out, bucket)
        member_deadline = now + self.form_deadline
        if req.deadline is not None:
            member_deadline = min(member_deadline, now + req.deadline)
        lo = 0
        while lo < req.n_pairs:
            group = self._groups.get(key)
            if group is None:
                group = self._groups[key] = _Group(key)
            space = self.wave_pairs - group.n_rows
            hi = min(req.n_pairs, lo + space)
            group.add(req, lo, hi, member_deadline)
            if group.n_rows >= self.wave_pairs:
                self._full.append(self._groups.pop(key))
            lo = hi

    # -- flush ---------------------------------------------------------------

    def take_ready(self, now: float) -> List[FormedWave]:
        """Pop every full group plus every group whose oldest member's
        forming deadline has expired."""
        out = [self._build(g, "full") for g in self._full]
        self._full = []
        for key in [k for k, g in self._groups.items()
                    if g.deadline is not None and g.deadline <= now]:
            out.append(self._build(self._groups.pop(key), "deadline"))
        return out

    def flush_all(self) -> List[FormedWave]:
        """Drain every forming group (shutdown path)."""
        out = [self._build(g, "full") for g in self._full]
        self._full = []
        out.extend(self._build(g, "drain")
                   for g in self._groups.values())
        self._groups.clear()
        return out

    def _build(self, group: _Group, reason: str) -> FormedWave:
        width = 1
        lmax = 1
        for req, lo, hi in group.members:
            width = max(width, req.p.shape[1], req.t.shape[1])
            lmax = max(lmax, int(req.plen[lo:hi].max(initial=1)),
                       int(req.tlen[lo:hi].max(initial=1)))
        ps, ts, plens, tlens, slices = [], [], [], [], []
        row = 0
        for req, lo, hi in group.members:
            ps.append(_fit_width(req.p[lo:hi], width))
            ts.append(_fit_width(req.t[lo:hi], width))
            plens.append(req.plen[lo:hi])
            tlens.append(req.tlen[lo:hi])
            slices.append(WaveSlice(req, lo, row, hi - lo))
            row += hi - lo
        n_real = row
        if self.pad_to_full and n_real < self.wave_pairs:
            # self-aligning pad rows (zeros vs zeros, full bucket length):
            # they land in the same length bucket as the real rows, so the
            # padded wave is the SAME executable shape as a full one —
            # zero retraces even for a deadline-flushed lonely request.
            n_pad = self.wave_pairs - n_real
            pad_len = min(lmax, width)
            ps.append(np.zeros((n_pad, width), np.int32))
            ts.append(np.zeros((n_pad, width), np.int32))
            plens.append(np.full((n_pad,), pad_len, np.int32))
            tlens.append(np.full((n_pad,), pad_len, np.int32))
        self.n_formed += 1
        return FormedWave(
            key=group.key, slices=slices,
            p=np.concatenate(ps), plen=np.concatenate(plens),
            t=np.concatenate(ts), tlen=np.concatenate(tlens),
            n_real=n_real, reason=reason)
