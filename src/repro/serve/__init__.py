"""repro.serve — the always-on alignment service.

Continuous batching over the streaming engine: a bounded
:class:`RequestQueue` (admission control + load shedding), a
:class:`WaveFormer` (deadline-or-full wave formation with length-bucket
affinity), and a :class:`ServeLoop` whose worker threads feed one shared
:class:`~repro.core.session.AlignmentSession` and deliver out-of-order
completions to per-request futures.  ``launch/serve_align.py`` is the
CLI; ``benchmarks/serving.py`` the open-loop load harness.
"""
from repro.serve.driver import ReplayReport, replay_trace
from repro.serve.loop import ServeLoop, ServerStats
from repro.serve.queue import RequestQueue
from repro.serve.request import (AlignFuture, AlignRequest, AlignResult,
                                 ShedError)
from repro.serve.waves import FormedWave, WaveFormer, WaveSlice

__all__ = [
    "AlignFuture", "AlignRequest", "AlignResult", "FormedWave",
    "ReplayReport", "RequestQueue", "ServeLoop", "ServerStats", "ShedError",
    "WaveFormer", "WaveSlice", "replay_trace",
]
