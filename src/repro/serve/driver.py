"""Open-loop replay: offered load that does not wait for the server.

A closed-loop driver (submit, wait, submit) measures the server at
exactly its own pace and hides queueing entirely; the serving literature's
standard harness is **open-loop**: arrivals fire on a fixed schedule (here
a Poisson process scaled to the offered load) whether or not earlier
requests have completed, so queueing delay, shedding and tail latency
become visible.  ``replay_trace`` is that harness — shared by
``launch/serve_align.py`` and ``benchmarks/serving.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.loop import ServeLoop, ServerStats
from repro.serve.request import AlignFuture, AlignResult, ShedError

__all__ = ["ReplayReport", "replay_trace"]

Payload = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class ReplayReport:
    """What one open-loop replay observed."""
    n_requests: int
    n_ok: int
    n_shed: int
    n_failed: int                     # non-shed exceptions (should be 0)
    latencies: np.ndarray             # seconds, completed requests only
    pairs_done: int
    t_offered: float                  # last scheduled arrival - first
    t_sustained: float                # first arrival -> last completion
    lag_max: float                    # worst driver-side schedule slip
    results: List[Optional[AlignResult]]    # per request; None if shed
    stats: ServerStats                # server snapshot at drain

    @property
    def sustained_pairs_per_s(self) -> float:
        return self.pairs_done / max(self.t_sustained, 1e-12)

    def percentile_ms(self, q: float) -> float:
        return (float(np.percentile(self.latencies, q)) * 1e3
                if self.latencies.size else float("nan"))


def replay_trace(server: ServeLoop, payloads: Sequence[Payload],
                 arrivals: np.ndarray, *, penalties=None, heuristic=None,
                 output: Optional[str] = None,
                 deadline: Optional[float] = None) -> ReplayReport:
    """Submit ``payloads[i]`` at time ``t0 + arrivals[i]``, then drain.

    Open loop: the schedule is absolute (no drift when a submit runs
    long); ``lag_max`` reports how far the driver itself fell behind its
    schedule, so an overloaded *driver* is distinguishable from an
    overloaded *server*.  Waits on every future at the end — each must
    resolve exactly once (ok / shed / failure), which the report tallies.
    """
    assert len(payloads) == len(arrivals)
    futures: List[AlignFuture] = []
    t0 = time.monotonic()
    lag_max = 0.0
    for (p, plen, t, tlen), at in zip(payloads, arrivals):
        due = t0 + float(at)
        now = time.monotonic()
        if due > now:
            time.sleep(due - now)
        else:
            lag_max = max(lag_max, now - due)
        futures.append(server.submit_packed(
            p, plen, t, tlen, penalties=penalties, heuristic=heuristic,
            output=output, deadline=deadline))

    results: List[Optional[AlignResult]] = []
    latencies: List[float] = []
    n_ok = n_shed = n_failed = pairs_done = 0
    t_last_done = t0
    for fut in futures:
        try:
            res = fut.result(timeout=600.0)
            results.append(res)
            latencies.append(res.latency)
            pairs_done += len(res.scores)
            n_ok += 1
            t_last_done = max(t_last_done,
                              fut.request.t_arrival + res.latency)
        except ShedError:
            results.append(None)
            n_shed += 1
        except Exception:
            results.append(None)
            n_failed += 1
    stats = server.stats()
    return ReplayReport(
        n_requests=len(futures), n_ok=n_ok, n_shed=n_shed,
        n_failed=n_failed, latencies=np.asarray(latencies, float),
        pairs_done=pairs_done,
        t_offered=float(arrivals[-1] - arrivals[0]) if len(arrivals) else 0.0,
        t_sustained=max(t_last_done - t0, 1e-12), lag_max=lag_max,
        results=results, stats=stats)
