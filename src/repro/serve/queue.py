"""Bounded admission queue — the service's load-shedding front door.

A server that queues without bound converts overload into unbounded
latency; the paper-scale regime (millions of independent small requests)
instead sheds at admission: when ``max_depth`` requests are already
waiting, ``offer()`` refuses and the caller's future resolves with a
typed :class:`~repro.serve.request.ShedError` immediately.  Accepted
requests are handed to the serve loop in arrival order via ``drain()``;
``wait()`` is the loop's parking spot between arrivals (condition-based,
so an arrival wakes the loop instead of a poll finding it later).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Deque, List, Optional

from repro.serve.request import AlignRequest, ShedError

__all__ = ["RequestQueue"]


class RequestQueue:
    """Thread-safe bounded FIFO of :class:`AlignRequest` with shedding."""

    def __init__(self, max_depth: int = 1024):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = int(max_depth)
        self._items: Deque[AlignRequest] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self.n_offered = 0
        self.n_shed = 0

    def __len__(self) -> int:
        return len(self._items)

    @property
    def closed(self) -> bool:
        return self._closed

    def offer(self, req: AlignRequest) -> bool:
        """Admit ``req`` (stamping its arrival time) or shed it.

        Returns True on admission.  On shed, the request's future is
        resolved here with :class:`ShedError` — exactly-once answering is
        the queue's contract, not the caller's cleanup problem.
        """
        with self._cond:
            self.n_offered += 1
            if self._closed:
                self.n_shed += 1
                req.future.set_exception(ShedError(
                    "server stopped", queue_depth=len(self._items),
                    max_depth=self.max_depth))
                return False
            if len(self._items) >= self.max_depth:
                self.n_shed += 1
                req.future.set_exception(ShedError(
                    "queue full", queue_depth=len(self._items),
                    max_depth=self.max_depth))
                return False
            req.t_arrival = time.monotonic()
            self._items.append(req)
            self._cond.notify()
            return True

    def drain(self, max_items: Optional[int] = None) -> List[AlignRequest]:
        """Pop up to ``max_items`` requests (all, when None). Non-blocking."""
        with self._cond:
            n = len(self._items) if max_items is None \
                else min(max_items, len(self._items))
            return [self._items.popleft() for _ in range(n)]

    def wait(self, timeout: float) -> bool:
        """Park until an arrival (or ``timeout`` seconds); True if items
        are waiting."""
        with self._cond:
            if not self._items:
                self._cond.wait(timeout)
            return bool(self._items)

    def close(self) -> None:
        """Refuse (shed) all future offers; queued items still drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
