"""Request/response types of the always-on alignment service.

One :class:`AlignRequest` is one caller-sized unit of work — a handful of
(pattern, text) pairs plus the per-request seams the engine already
exposes per submit (penalty model, wavefront heuristic, output mode) and
an optional latency deadline.  The service answers through an
:class:`AlignFuture` (a ``concurrent.futures.Future``): accepted requests
resolve with an :class:`AlignResult`, shed requests resolve with a typed
:class:`ShedError`.  Every future resolves exactly once — the stdlib
future raises ``InvalidStateError`` on a double resolution, which is the
service's lost/duplicated-request tripwire.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
from typing import List, Optional, Sequence

import numpy as np

from repro.core.engine import Seq, pack_batch

__all__ = ["AlignFuture", "AlignRequest", "AlignResult", "ShedError"]

_ids = itertools.count()


class ShedError(RuntimeError):
    """Typed admission-control rejection.

    Raised *through the request's future* (``future.result()`` re-raises
    it), never silently: a shed request is answered, just not served.
    ``reason`` is ``"queue full"`` (bounded queue at capacity) or
    ``"server stopped"`` (submitted after shutdown began).
    """

    def __init__(self, reason: str, *, queue_depth: int = 0,
                 max_depth: int = 0):
        super().__init__(
            f"request shed: {reason} "
            f"(queue depth {queue_depth}/{max_depth})")
        self.reason = reason
        self.queue_depth = queue_depth
        self.max_depth = max_depth


@dataclasses.dataclass
class AlignResult:
    """What an accepted request's future resolves with."""
    scores: np.ndarray                      # [n_pairs] int32
    cigars: Optional[List[np.ndarray]]      # per-pair op arrays (cigar mode)
    latency: float                          # seconds, arrival -> delivery
    n_waves: int                            # device waves this request rode


class AlignFuture(concurrent.futures.Future):
    """`concurrent.futures.Future` + a back-pointer to its request."""

    def __init__(self, request: "AlignRequest"):
        super().__init__()
        self.request = request


class AlignRequest:
    """One service request: packed pairs + per-request engine seams.

    ``deadline`` is a *relative* latency budget in seconds: the wave
    former will not hold this request's forming group open past
    ``arrival + min(form_deadline, deadline)``.  ``None`` means the
    server-wide forming deadline alone applies.

    The mutable delivery state (``_scores`` buffer, ``_remaining`` row
    count, per-wave cigar scatter) is owned by the serve loop; callers
    only touch the future.
    """

    def __init__(self, p: np.ndarray, plen: np.ndarray, t: np.ndarray,
                 tlen: np.ndarray, *, penalties=None, heuristic=None,
                 output: Optional[str] = None,
                 deadline: Optional[float] = None):
        self.p = np.asarray(p)
        self.t = np.asarray(t)
        self.plen = np.asarray(plen, np.int32)
        self.tlen = np.asarray(tlen, np.int32)
        if self.p.shape[0] != self.t.shape[0]:
            raise ValueError("patterns and texts disagree on pair count")
        self.n_pairs = int(self.p.shape[0])
        self.penalties = penalties
        self.heuristic = heuristic
        self.output = output
        self.deadline = None if deadline is None else float(deadline)
        self.request_id = next(_ids)
        self.future = AlignFuture(self)
        # -- delivery state (serve-loop owned) --------------------------------
        self.t_arrival: float = 0.0          # stamped at admission
        self.flow_id: int = 0                # trace flow (0 = tracing off)
        self.pen = None                      # resolved at admission
        self.heur = None
        self.out: str = "score"
        self._scores = np.full((self.n_pairs,), -1, np.int32)
        self._cigars: Optional[List[Optional[np.ndarray]]] = None
        self._remaining = self.n_pairs
        self._n_waves = 0

    @classmethod
    def from_seqs(cls, patterns: Sequence[Seq], texts: Sequence[Seq],
                  **kw) -> "AlignRequest":
        """Pack python sequences on the caller's thread (keeps host-side
        encoding off the serve loop)."""
        if len(patterns) != len(texts):
            raise ValueError("patterns and texts disagree on pair count")
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        return cls(p, plen, t, tlen, **kw)

    @property
    def max_len(self) -> int:
        """Longest sequence in the request — the bucket-affinity key."""
        return int(max(self.plen.max(initial=1), self.tlen.max(initial=1)))

    # -- serve-loop delivery hooks -------------------------------------------

    def _deliver_rows(self, rows: slice, scores: np.ndarray,
                      cigars: Optional[List[np.ndarray]]) -> bool:
        """Scatter one wave's slice of results; True when complete."""
        self._scores[rows] = scores
        if cigars is not None:
            if self._cigars is None:
                self._cigars = [None] * self.n_pairs
            self._cigars[rows] = cigars
        self._remaining -= len(scores)
        self._n_waves += 1
        return self._remaining == 0

    def _resolve(self, now: float) -> float:
        """Complete the future -> the request's arrival->delivery latency."""
        latency = now - self.t_arrival
        self.future.set_result(AlignResult(
            scores=self._scores, cigars=self._cigars, latency=latency,
            n_waves=self._n_waves))
        return latency
