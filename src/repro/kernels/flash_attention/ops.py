"""jit'd wrapper for the flash-attention kernel: padding + defaults.

Pads Sq/Sk up to block multiples; padded KV positions are masked out by the
causal structure (pad keys sit at positions >= every real query) for causal
use; the non-causal path requires dividing blocks (checked).  The wrapper
exposes the same signature as the jnp oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_gqa
from repro.kernels.flash_attention.ref import ref_attention_gqa  # noqa: F401


def _round_up(x, m):
    return ((x + m - 1) // m) * m


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """Drop-in blocked attention. q [B,Sq,H,dh]; k/v [B,Sk,KV,dh]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    bq = min(block_q, _round_up(Sq, 128))
    bk = min(block_k, _round_up(Sk, 128))
    Sq_p, Sk_p = _round_up(Sq, bq), _round_up(Sk, bk)

    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    if not causal and Sk_p != Sk:
        # mask pad keys by pushing them outside every query's window:
        # simplest correct route — fall back to biasing via huge negative
        # handled in-kernel only for causal; mask here by zeroing V and
        # subtracting their softmax mass is NOT exact, so instead shift pad
        # keys to -inf via a causal=False kernel pass over the REAL Sk only.
        raise ValueError("non-causal flash path requires Sk % block_k == 0 "
                         "(pad upstream or pick a dividing block)")

    o = flash_attention_gqa(q, k, v, causal=causal, block_q=bq, block_k=bk,
                            interpret=interpret)
    return o[:, :Sq]
