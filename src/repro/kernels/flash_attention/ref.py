"""Pure-jnp oracle for the flash-attention kernel: materialized-scores GQA
attention with fp32 softmax (numerically the reference the kernel must
match block-for-block)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention_gqa(q, k, v, *, causal: bool = True):
    """q [B,Sq,H,dh]; k/v [B,Sk,KV,dh] -> [B,Sq,H,dh]."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(dh)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, Sq, H, dh)
