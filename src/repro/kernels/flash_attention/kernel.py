"""Pallas TPU flash-attention (forward): online-softmax blocked attention.

Why it exists here: the dry-run roofline showed every attention arch's
memory term dominated by the materialized S^2 score tensors (XLA cannot
fuse matmul->softmax->matmul, so scores round-trip HBM in fp32 —
EXPERIMENTS.md §Roofline).  This kernel is the standard fix: Q/K/V stream
HBM->VMEM in (block_q x block_k) tiles, the softmax runs online with
running (max, denom) carried in VMEM scratch, and only O leaves the core —
HBM traffic drops from O(S^2) to O(S*d).

Grid: (B * KV_heads, n_q_blocks, n_kv_blocks) — the LAST dim iterates
innermost/sequentially on a TPU core, so the scratch carries (m, l, acc)
persist across KV blocks of one (batch-head, q-block) cell.  GQA: the G
query heads sharing one KV head ride in the same block (the MXU matmul is
[G*bq, dh] @ [dh, bk] — G fattens the tile, good for small bq).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, scale: float, block_q: int, block_k: int,
            n_kv_blocks: int):
    # q_ref [1, G, bq, dh]; k_ref/v_ref [1, bk, dh]; o_ref like q_ref
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [G, bq, dh]
    k = k_ref[0]                                   # [bk, dh]
    v = v_ref[0]
    G, bq, dh = q.shape

    s = jax.lax.dot_general(q.reshape(G * bq, dh), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(G, bq, k.shape[0]) * scale       # [G, bq, bk] f32

    if causal:
        q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        k_pos = ik * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]                            # [G, bq]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])              # [G, bq, bk]
    l_new = l_prev * corr + jnp.sum(p, axis=-1)

    pv = jax.lax.dot_general(
        p.reshape(G * bq, -1).astype(v.dtype), v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(G, bq, dh)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_gqa(q, k, v, *, causal: bool = True, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True):
    """q [B, Sq, H, dh]; k/v [B, Sk, KV, dh]; H % KV == 0.
    -> o [B, Sq, H, dh].  Sq % block_q == 0 == Sk % block_k (ops.py pads)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(dh)

    # [B,S,H,dh] -> [B*KV, G, Sq, dh]; k/v -> [B*KV, Sk, dh]
    qr = (q.reshape(B, Sq, KV, G, dh).transpose(0, 2, 3, 1, 4)
          .reshape(B * KV, G, Sq, dh))
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dh)

    kernel = functools.partial(_kernel, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k,
                               n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, block_q, dh), lambda bh, iq, ik: (bh, 0, iq, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, dh),
                               lambda bh, iq, ik: (bh, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    return (out.reshape(B, KV, G, Sq, dh).transpose(0, 3, 1, 2, 4)
            .reshape(B, Sq, H, dh))
