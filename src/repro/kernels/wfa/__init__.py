from repro.kernels.wfa.ops import (  # noqa: F401
    wfa_align, wfa_align_np, wfa_align_trace, wfa_bidir_meet_kernel)
from repro.kernels.wfa.ref import ref_scores  # noqa: F401
