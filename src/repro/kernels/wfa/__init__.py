from repro.kernels.wfa.ops import wfa_align, wfa_align_np  # noqa: F401
from repro.kernels.wfa.ref import ref_scores  # noqa: F401
