"""jit'd wrapper around the Pallas WFA kernel: padding, blocking, unpadding.

Hardware-alignment contract (DESIGN.md §2): sequence buffers pad to lane
multiples (128), the diagonal axis pads to a lane multiple, the pair axis
pads to the block size — the TPU analogue of UPMEM's 8-byte DMA alignment,
absorbed here by the wrapper exactly like the paper's custom allocator.

Tuning knobs (all optional, threaded from
``AlignmentEngine(backend_opts=...)``):

* ``block_pairs`` — pairs per grid program.  ``None`` picks the platform
  auto-default (8: one int32 sublane tile on TPU, and the measured best in
  interpret mode, where a small block keeps the per-block early exit
  effective).
* ``gather`` — extension character fetch, ``"index"``/``"onehot"``
  (default: index under interpret, onehot compiled — see ``kernel.py``).
* ``ext_stride`` — characters fetched per extend trip (index mode).
* ``band_cap`` — compacting-band width; lane-aligned here (rounded up to
  128) before reaching the kernel, so the compact rings stay legal TPU
  tiles.  None = full width.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.kernels.wfa.kernel import wfa_pallas

LANE = 128
DEFAULT_BLOCK_PAIRS = 8


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_axis(x, axis: int, to: int, value=0):
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def resolve_block_pairs(block_pairs: Optional[int]) -> int:
    """Auto-default for pairs-per-grid-program (one int32 sublane tile)."""
    if block_pairs is None:
        return DEFAULT_BLOCK_PAIRS
    bp = int(block_pairs)
    if bp < 1:
        raise ValueError(f"block_pairs must be >= 1, got {block_pairs}")
    return bp


def _band_lanes(band_cap, k_pad: int) -> Optional[int]:
    """Lane-aligned compact ring width, or None for full width."""
    if band_cap is None:
        return None
    kc = _round_up(max(int(band_cap), 1), LANE)
    return kc if kc < k_pad else None


def _prep(pattern, text, plen, tlen, block_pairs):
    pattern = jnp.asarray(pattern, jnp.int32)
    text = jnp.asarray(text, jnp.int32)
    plen = jnp.asarray(plen, jnp.int32).reshape(-1)
    tlen = jnp.asarray(tlen, jnp.int32).reshape(-1)
    B, Lp = pattern.shape
    Lt = text.shape[1]
    Bp = _round_up(max(B, 1), block_pairs)
    pattern = _pad_axis(_pad_axis(pattern, 1, _round_up(max(Lp, 1), LANE)),
                        0, Bp)
    text = _pad_axis(_pad_axis(text, 1, _round_up(max(Lt, 1), LANE)), 0, Bp)
    # padded pairs have plen = tlen = 0 -> score 0 at s = 0, no extra trips
    plen2 = _pad_axis(plen[:, None], 0, Bp)
    tlen2 = _pad_axis(tlen[:, None], 0, Bp)
    return pattern, text, plen2, tlen2, B


def wfa_align(pattern, text, plen, tlen, *, pen, s_max: int,
              k_max: int, block_pairs: Optional[int] = None,
              interpret: Optional[bool] = None, heur=None,
              gather: Optional[str] = None, ext_stride: int = 1,
              band_cap: Optional[int] = None):
    """Batched WFA scores via the Pallas kernel.

    pattern/text: [B, L*] int; plen/tlen: [B] int.  Returns [B] int32 costs
    (-1 where the optimal cost exceeds ``s_max``).  ``pen`` may be any
    ``PenaltyModel`` (or a legacy ``Penalties`` triple) and ``heur`` an
    optional ``WavefrontHeuristic``; both specialize the kernel statically.
    ``interpret`` defaults to True off-TPU (CPU validation) and False on
    TPU; the remaining knobs are documented in the module docstring.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bp = resolve_block_pairs(block_pairs)
    pattern, text, plen2, tlen2, B = _prep(pattern, text, plen, tlen, bp)
    k_pad = _round_up(2 * k_max + 1, LANE)

    score, _ = wfa_pallas(pattern, text, plen2, tlen2, pen=pen, s_max=s_max,
                          k_pad=k_pad, block_pairs=bp, interpret=interpret,
                          heur=scoring.as_heuristic(heur), gather=gather,
                          ext_stride=ext_stride,
                          band_cap=_band_lanes(band_cap, k_pad))
    return score[:B, 0]


def wfa_align_trace(pattern, text, plen, tlen, *, pen, s_max: int,
                    k_max: int, block_pairs: Optional[int] = None,
                    interpret: Optional[bool] = None, heur=None,
                    gather: Optional[str] = None, ext_stride: int = 1,
                    band_cap: Optional[int] = None):
    """Batched WFA scores *plus* packed backtrace via the Pallas kernel.

    Same padding contract as :func:`wfa_align`; returns
    ``(score [B], m_bt, i_bt, d_bt)`` where the bt arrays are
    ``[n_words, B, k_pad]`` int32 packed 2-bit provenance words
    (``core.cigar.traceback_packed_batch`` decodes them; the diagonal
    center is ``k_pad // 2`` — under a compacting band the codes are
    scattered back to absolute k in-kernel, so the decoder is unchanged).
    Linear penalty models record a single M plane: ``i_bt = d_bt = None``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bp = resolve_block_pairs(block_pairs)
    pattern, text, plen2, tlen2, B = _prep(pattern, text, plen, tlen, bp)
    k_pad = _round_up(2 * k_max + 1, LANE)

    out = wfa_pallas(
        pattern, text, plen2, tlen2, pen=pen, s_max=s_max, k_pad=k_pad,
        block_pairs=bp, interpret=interpret, trace=True,
        heur=scoring.as_heuristic(heur), gather=gather,
        ext_stride=ext_stride, band_cap=_band_lanes(band_cap, k_pad))
    if scoring.as_model(pen).kind == "linear":
        score, _, m_bt = out
        return score[:B, 0], m_bt[:, :B, :], None, None
    score, _, m_bt, i_bt, d_bt = out
    return (score[:B, 0], m_bt[:, :B, :], i_bt[:, :B, :], d_bt[:, :B, :])


def wfa_align_np(pattern, text, plen, tlen, **kw):
    return np.asarray(wfa_align(pattern, text, plen, tlen, **kw))


def wfa_bidir_meet_kernel(pattern, text, plen, tlen, starget, *, pen,
                          s_max: int, k_max: int, heur=None,
                          begin_state: str = "M", end_state: str = "M",
                          block_pairs: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """Device-resident BiWFA meet search via the fused Pallas grid.

    Drop-in for ``core.wavefront.wfa_bidir_meet`` (same signature and
    ``BidirMeetResult``), selected by the ``kernel`` backend for
    ``trace_variant="bidir"`` meet waves: both fronts' rings live in VMEM
    scratch and each grid program exits as soon as its own block's pairs
    have met, instead of the jnp solver's whole-batch early-exit.  The
    meet detector's per-pair ring reads are real gathers, so the fused
    grid is interpret-mode only for now — compiled TPU runs delegate to
    the jnp solver (same results, already fully jitted).
    """
    from repro.core.wavefront import BidirMeetResult, _reverse_rows
    from repro.core import wavefront as _wf
    from repro.kernels.wfa.kernel import wfa_meet_pallas

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not interpret:
        return _wf.wfa_bidir_meet(pattern, text, plen, tlen, starget,
                                  pen=pen, s_max=s_max, k_max=k_max,
                                  heur=heur, begin_state=begin_state,
                                  end_state=end_state)
    bp = resolve_block_pairs(block_pairs)
    pattern2, text2, plen2, tlen2, B = _prep(pattern, text, plen, tlen, bp)
    starget2 = _pad_axis(
        jnp.asarray(starget, jnp.int32).reshape(-1)[:, None], 0,
        pattern2.shape[0])
    k_pad = _round_up(2 * k_max + 1, LANE)
    pat_rev = _reverse_rows(pattern2, plen2[:, 0])
    txt_rev = _reverse_rows(text2, tlen2[:, 0])

    (score, steps, state, a, b, k, h,
     safe) = wfa_meet_pallas(pattern2, text2, pat_rev, txt_rev, plen2,
                             tlen2, starget2, pen=pen, s_max=s_max,
                             k_pad=k_pad, block_pairs=bp,
                             interpret=interpret,
                             heur=scoring.as_heuristic(heur),
                             begin_state=begin_state, end_state=end_state)
    return BidirMeetResult(score[:B, 0], jnp.max(steps), state[:B, 0],
                           a[:B, 0], b[:B, 0], k[:B, 0], h[:B, 0],
                           safe[:B, 0])
