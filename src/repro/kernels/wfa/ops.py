"""jit'd wrapper around the Pallas WFA kernel: padding, blocking, unpadding.

Hardware-alignment contract (DESIGN.md §2): sequence buffers pad to lane
multiples (128), the diagonal axis pads to a lane multiple, the pair axis
pads to the block size — the TPU analogue of UPMEM's 8-byte DMA alignment,
absorbed here by the wrapper exactly like the paper's custom allocator.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.kernels.wfa.kernel import wfa_pallas

LANE = 128


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_axis(x, axis: int, to: int, value=0):
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def wfa_align(pattern, text, plen, tlen, *, pen, s_max: int,
              k_max: int, block_pairs: int = 8,
              interpret: Optional[bool] = None, heur=None):
    """Batched WFA scores via the Pallas kernel.

    pattern/text: [B, L*] int; plen/tlen: [B] int.  Returns [B] int32 costs
    (-1 where the optimal cost exceeds ``s_max``).  ``pen`` may be any
    ``PenaltyModel`` (or a legacy ``Penalties`` triple) and ``heur`` an
    optional ``WavefrontHeuristic``; both specialize the kernel statically.
    ``interpret`` defaults to True off-TPU (CPU validation) and False on
    TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pattern = jnp.asarray(pattern, jnp.int32)
    text = jnp.asarray(text, jnp.int32)
    plen = jnp.asarray(plen, jnp.int32).reshape(-1)
    tlen = jnp.asarray(tlen, jnp.int32).reshape(-1)

    B, Lp = pattern.shape
    Lt = text.shape[1]
    Bp = _round_up(max(B, 1), block_pairs)
    Lp_p = _round_up(max(Lp, 1), LANE)
    Lt_p = _round_up(max(Lt, 1), LANE)
    k_pad = _round_up(2 * k_max + 1, LANE)

    pattern = _pad_axis(_pad_axis(pattern, 1, Lp_p), 0, Bp)
    text = _pad_axis(_pad_axis(text, 1, Lt_p), 0, Bp)
    # padded pairs have plen = tlen = 0 -> score 0 at s = 0, no extra trips
    plen2 = _pad_axis(plen[:, None], 0, Bp)
    tlen2 = _pad_axis(tlen[:, None], 0, Bp)

    score, _ = wfa_pallas(pattern, text, plen2, tlen2, pen=pen, s_max=s_max,
                          k_pad=k_pad, block_pairs=block_pairs,
                          interpret=interpret,
                          heur=scoring.as_heuristic(heur))
    return score[:B, 0]


def wfa_align_trace(pattern, text, plen, tlen, *, pen, s_max: int,
                    k_max: int, block_pairs: int = 8,
                    interpret: Optional[bool] = None, heur=None):
    """Batched WFA scores *plus* packed backtrace via the Pallas kernel.

    Same padding contract as :func:`wfa_align`; returns
    ``(score [B], m_bt, i_bt, d_bt)`` where the bt arrays are
    ``[n_words, B, k_pad]`` int32 packed 2-bit provenance words
    (``core.cigar.traceback_packed_batch`` decodes them; the diagonal
    center is ``k_pad // 2``).  Linear penalty models record a single M
    plane: ``i_bt = d_bt = None``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pattern = jnp.asarray(pattern, jnp.int32)
    text = jnp.asarray(text, jnp.int32)
    plen = jnp.asarray(plen, jnp.int32).reshape(-1)
    tlen = jnp.asarray(tlen, jnp.int32).reshape(-1)

    B, Lp = pattern.shape
    Lt = text.shape[1]
    Bp = _round_up(max(B, 1), block_pairs)
    Lp_p = _round_up(max(Lp, 1), LANE)
    Lt_p = _round_up(max(Lt, 1), LANE)
    k_pad = _round_up(2 * k_max + 1, LANE)

    pattern = _pad_axis(_pad_axis(pattern, 1, Lp_p), 0, Bp)
    text = _pad_axis(_pad_axis(text, 1, Lt_p), 0, Bp)
    plen2 = _pad_axis(plen[:, None], 0, Bp)
    tlen2 = _pad_axis(tlen[:, None], 0, Bp)

    out = wfa_pallas(
        pattern, text, plen2, tlen2, pen=pen, s_max=s_max, k_pad=k_pad,
        block_pairs=block_pairs, interpret=interpret, trace=True,
        heur=scoring.as_heuristic(heur))
    if scoring.as_model(pen).kind == "linear":
        score, _, m_bt = out
        return score[:B, 0], m_bt[:, :B, :], None, None
    score, _, m_bt, i_bt, d_bt = out
    return (score[:B, 0], m_bt[:, :B, :], i_bt[:, :B, :], d_bt[:, :B, :])


def wfa_align_np(pattern, text, plen, tlen, **kw):
    return np.asarray(wfa_align(pattern, text, plen, tlen, **kw))
