"""Pallas TPU kernel for batched WFA — the DPU inner loop, re-vectorized.

Hardware mapping (DESIGN.md §2):

* one **grid program** ≙ one DPU: it owns a block of ``BP`` pairs and runs
  their entire alignment without leaving VMEM;
* **BlockSpec** HBM→VMEM tiling of the pair batch ≙ the MRAM→WRAM DMA;
* the wavefront **ring buffers** (depth ``window = max(x,o+e)+1``) live in
  VMEM scratch ≙ the WFA metadata the paper keeps hot in WRAM;
* wavefronts are laid out ``[pairs, diagonals]`` on (sublane, lane) —
  every arithmetic op is a full-width vector op;
* character fetch during extension uses a **one-hot compare-and-reduce**
  (``sum_l [idx == l] * seq[l]``) instead of a per-lane gather, which TPUs
  lack (UPMEM's scalar loads do not transfer);
* no communication between grid programs ≙ no inter-DPU communication.

The kernel is specialized per **penalty model** (``core.scoring``): affine
models run the three-matrix M/I/D recurrence over three VMEM rings;
linear models (``GapLinear`` / ``Edit``) collapse to the one-matrix
recurrence over a **single** ring — a third of the per-step VMEM working
set and fewer VPU ops per score step.  A **wavefront heuristic**
(``AdaptiveBand`` / ``ZDrop``) optionally masks pruned k-lanes to the
invalid sentinel after each step, so dead diagonals cost no further
extension trips.

Two output modes, built from the same kernel body:

* score-only (throughput) — exactly like the ring-buffer jnp reference
  ``kernels.wfa.ref.ref_scores`` it is validated against;
* packed backtrace (``trace=True``) — additionally OR-accumulates 2-bit
  per-cell provenance codes into ``[n_words, B, K]`` int32 words (16 score
  steps per word, same encoding as ``core.wavefront.wfa_scores_packed``;
  three planes for affine, one for linear), which ``core.cigar`` decodes
  into exact CIGARs on the host.  The rings stay the only per-step working
  set in VMEM; the packed words are ~16x smaller than a full offset
  history, so full alignments fit the same bucketed batches the score path
  serves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import scoring
from repro.core.wavefront import (BT_GAP_EXT, BT_GAP_OPEN, BT_M_FROM_D,
                                  BT_M_FROM_I, BT_M_FROM_X,
                                  TRACE_CELLS_PER_WORD, keep_mask,
                                  n_trace_words)

NEG = -(1 << 20)
_THRESH = NEG // 2


def _gather_chars(seq, idx):
    """seq [BP, L], idx [BP, K] -> seq[b, idx[b, k]] as [BP, K].

    One-hot contraction (VPU compare + reduce); idx is pre-clipped by the
    caller's validity mask so out-of-range lanes read junk that is never used.
    """
    BP, L = seq.shape
    K = idx.shape[1]
    l_iota = lax.broadcasted_iota(jnp.int32, (BP, K, L), 2)
    idx_c = jnp.clip(idx, 0, L - 1)
    hit = (l_iota == idx_c[:, :, None])
    return jnp.sum(jnp.where(hit, seq[:, None, :], 0), axis=2)


def _make_kernel(model, heur, s_max: int, trace: bool = False):
    x, o, e = model.x, model.o, model.e
    W = model.window
    affine = model.kind == "affine"
    n_bt = (3 if affine else 1) if trace else 0

    def kernel(p_ref, t_ref, pl_ref, tl_ref, out_ref, steps_ref, *refs):
        bt_refs = refs[:n_bt]
        rings = refs[n_bt:]
        if affine:
            m_ring, i_ring, d_ring = rings
        else:
            (m_ring,) = rings
        BP, Lp = p_ref.shape
        _, Lt = t_ref.shape
        K = m_ring.shape[-1]
        kc = K // 2

        pat = p_ref[...]
        txt = t_ref[...]
        plen = pl_ref[...]                       # [BP, 1]
        tlen = tl_ref[...]
        ks = lax.broadcasted_iota(jnp.int32, (BP, K), 1) - kc

        def extend(M):
            def trip(st):
                M, _ = st
                v = M - ks
                can = ((M > _THRESH) & (M >= 0) & (M < tlen)
                       & (v >= 0) & (v < plen))
                tc = _gather_chars(txt, M)
                pc = _gather_chars(pat, v)
                adv = can & (tc == pc)
                return M + adv.astype(jnp.int32), jnp.any(adv)

            st = trip((M, jnp.bool_(True)))
            M, _ = lax.while_loop(lambda st: st[1], trip, st)
            return M

        def reached(M):
            """[BP, 1] bool: furthest offset hit the (tlen, plen) corner."""
            k_final = tlen - plen                # [BP, 1] diagonal value
            hit = (ks == k_final) & (M >= tlen) & (M > _THRESH)
            return jnp.any(hit, axis=1, keepdims=True)

        def prune(M):
            # shared policy implementation; plen/tlen/ks are already in
            # keep_mask's 2-D convention ([BP, 1] / [BP, K])
            keep = keep_mask(heur, M, plen, tlen, ks)
            if keep is None:
                return M, None
            return jnp.where(keep, M, NEG), keep

        def store_row(ring, row, val):
            ring[pl.ds(row, 1)] = val[None]

        def load_row(ring, s, delta):
            row = lax.rem(jnp.maximum(s - delta, 0), W)
            val = ring[pl.ds(row, 1)][0]
            return jnp.where(s >= delta, val, NEG)

        def pack_code(bt_ref, s, code):
            """OR the [BP, K] 2-bit code plane into word s//16 of bt_ref."""
            w = s // TRACE_CELLS_PER_WORD
            off = 2 * lax.rem(s, TRACE_CELLS_PER_WORD)
            cur = bt_ref[pl.ds(w, 1)]
            bt_ref[pl.ds(w, 1)] = cur | jnp.left_shift(code, off)[None]

        # s = 0
        if trace:
            # out buffers are uninitialized; codes are OR-accumulated
            for bt in bt_refs:
                bt[...] = jnp.zeros_like(bt)
        M0 = jnp.where(ks == 0, 0, NEG)
        M0 = extend(M0)
        store_row(m_ring, 0, M0)
        if affine:
            store_row(i_ring, 0, jnp.full((BP, K), NEG, jnp.int32))
            store_row(d_ring, 0, jnp.full((BP, K), NEG, jnp.int32))
        score0 = jnp.where(reached(M0), 0, -1)

        neg_col = jnp.full((BP, 1), NEG, jnp.int32)
        sh_r = lambda w: jnp.concatenate([neg_col, w[:, :-1]], axis=1)
        sh_l = lambda w: jnp.concatenate([w[:, 1:], neg_col], axis=1)

        def body(carry):
            s, score = carry
            m_x = load_row(m_ring, s, x)
            if affine:
                m_owe = load_row(m_ring, s, o + e)
                i_e = load_row(i_ring, s, e)
                d_e = load_row(d_ring, s, e)
                i_open, i_ext = sh_r(m_owe), sh_r(i_e)
                i_src = jnp.maximum(i_open, i_ext)
                d_open, d_ext = sh_l(m_owe), sh_l(d_e)
                d_src = jnp.maximum(d_open, d_ext)
            else:
                m_e = m_x if x == e else load_row(m_ring, s, e)
                i_src = sh_r(m_e)
                d_src = sh_l(m_e)

            I_new = jnp.where((i_src > _THRESH) & (i_src + 1 <= tlen),
                              i_src + 1, NEG)
            D_new = jnp.where((d_src > _THRESH) & (d_src - ks <= plen),
                              d_src, NEG)
            X_new = jnp.where((m_x > _THRESH) & (m_x + 1 <= tlen)
                              & (m_x + 1 - ks <= plen), m_x + 1, NEG)
            M_pre = jnp.maximum(jnp.maximum(X_new, I_new), D_new)
            M_new = extend(M_pre)

            if trace:
                # codes from the PRE-prune fronts — bit-identical to
                # wfa_scores_packed even on lanes a heuristic then kills
                # (those codes are unreachable in traceback either way)
                code_m = jnp.where(
                    M_pre > _THRESH,
                    jnp.where(M_pre == X_new, BT_M_FROM_X,
                              jnp.where(M_pre == I_new, BT_M_FROM_I,
                                        BT_M_FROM_D)), 0)
                pack_code(bt_refs[0], s, code_m)
                if affine:
                    code_i = jnp.where(
                        I_new > _THRESH,
                        jnp.where(i_ext >= i_open, BT_GAP_EXT,
                                  BT_GAP_OPEN), 0)
                    code_d = jnp.where(
                        D_new > _THRESH,
                        jnp.where(d_ext >= d_open, BT_GAP_EXT,
                                  BT_GAP_OPEN), 0)
                    pack_code(bt_refs[1], s, code_i)
                    pack_code(bt_refs[2], s, code_d)

            score = jnp.where((score < 0) & reached(M_new), s, score)
            M_new, keep = prune(M_new)
            if affine and keep is not None:
                I_new = jnp.where(keep, I_new, NEG)
                D_new = jnp.where(keep, D_new, NEG)

            row = lax.rem(s, W)
            store_row(m_ring, row, M_new)
            if affine:
                store_row(i_ring, row, I_new)
                store_row(d_ring, row, D_new)
            return s + 1, score

        def cond(carry):
            s, score = carry
            return (s <= s_max) & jnp.any(score < 0)

        s_end, score = lax.while_loop(cond, body, (jnp.int32(1), score0))
        out_ref[...] = score
        steps_ref[...] = jnp.broadcast_to(s_end, steps_ref.shape)

    return kernel, W, affine


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_pad",
                                             "block_pairs", "interpret",
                                             "trace", "heur"))
def wfa_pallas(pattern, text, plen, tlen, *, pen, s_max: int,
               k_pad: int, block_pairs: int = 8, interpret: bool = True,
               trace: bool = False, heur=None):
    """pattern/text [B, L*] int32 (B % block_pairs == 0, L* % 128 == 0),
    plen/tlen [B, 1] int32, k_pad % 128 == 0 is the padded diagonal count.
    -> (score [B, 1] int32, steps [B, 1] int32); with ``trace`` additionally
    the [n_words, B, k_pad] int32 packed provenance arrays (three for
    affine models, one for linear)."""
    B, Lp = pattern.shape
    Lt = text.shape[1]
    BP = block_pairs
    assert B % BP == 0, (B, BP)
    model = scoring.as_model(pen)
    heur = scoring.as_heuristic(heur)
    kernel, W, affine = _make_kernel(model, heur, s_max, trace=trace)
    grid = (B // BP,)
    n_rings = 3 if affine else 1

    spec2 = lambda L: pl.BlockSpec((BP, L), lambda i: (i, 0))
    out_specs = [spec2(1), spec2(1)]
    out_shape = [jax.ShapeDtypeStruct((B, 1), jnp.int32),
                 jax.ShapeDtypeStruct((B, 1), jnp.int32)]
    if trace:
        NW = n_trace_words(s_max)
        n_bt = 3 if affine else 1
        bt_spec = pl.BlockSpec((NW, BP, k_pad), lambda i: (0, i, 0))
        out_specs += [bt_spec] * n_bt
        out_shape += [jax.ShapeDtypeStruct((NW, B, k_pad), jnp.int32)] * n_bt
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec2(Lp), spec2(Lt), spec2(1), spec2(1)],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((W, BP, k_pad), jnp.int32)] * n_rings,
        interpret=interpret,
    )(pattern, text, plen, tlen)
