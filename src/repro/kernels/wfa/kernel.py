"""Pallas TPU kernel for batched WFA — the DPU inner loop, re-vectorized.

Hardware mapping (DESIGN.md §2):

* one **grid program** ≙ one DPU: it owns a block of ``BP`` pairs and runs
  their entire alignment without leaving VMEM — the whole score loop is a
  ``lax.while_loop`` *inside* the kernel body with an all-pairs-done early
  exit per block;
* **BlockSpec** HBM→VMEM tiling of the pair batch ≙ the MRAM→WRAM DMA;
* the wavefront **ring buffers** (depth ``window = max(x,o+e)+1``) live in
  VMEM scratch ≙ the WFA metadata the paper keeps hot in WRAM;
* wavefronts are laid out ``[pairs, diagonals]`` on (sublane, lane) —
  every arithmetic op is a full-width vector op;
* no communication between grid programs ≙ no inter-DPU communication.

Character fetch during extension is selected by the static ``gather`` mode:

* ``"onehot"`` — compare-and-reduce (``sum_l [idx == l] * seq[l]``), the
  only formulation a real TPU VPU supports (no per-lane gather); it
  materializes a ``[BP, K, L]`` intermediate per extend trip, which is
  exactly what made interpret mode ~100x slower than the jnp ring solver;
* ``"index"`` — ``jnp.take_along_axis``: in interpret mode the kernel body
  is discharged to plain jax ops on CPU, where a real gather exists and is
  ~25x faster.  The wrapper defaults to ``index`` under ``interpret`` and
  ``onehot`` when compiled.

``ext_stride`` fetches several consecutive characters per extend trip
(index mode): each trip gathers ``C`` chars of both sequences, takes the
cumulative-AND of the matches along the stride, and advances each lane by
its matched prefix — long match runs finish in ``len/C`` trips.

``band_cap`` switches on the **compacting band** (the in-kernel counterpart
of ``core.wavefront``'s ``band_cap``): rings are allocated at a compact
width ``Kc`` and a per-block window offset tracks where those lanes sit on
the absolute diagonal axis.  Each step the window re-centers on the union
of the block's live lanes (min/max reduction), ring reads from older score
rows realign by the offset delta (pad + dynamic slice — no gather needed),
and packed-backtrace codes scatter to absolute k before OR-packing, so the
trace decoder is oblivious.  Lanes outside the window are pruned exactly
like heuristic kills; when the heuristic's live span fits ``Kc`` the
results are identical to full width.

The kernel is specialized per **penalty model** (``core.scoring``): affine
models run the three-matrix M/I/D recurrence over three VMEM rings;
linear models (``GapLinear`` / ``Edit``) collapse to the one-matrix
recurrence over a **single** ring.  A **wavefront heuristic**
(``AdaptiveBand`` / ``ZDrop``) optionally masks pruned k-lanes to the
invalid sentinel after each step (shared ``keep_mask`` policy).

Two output modes, built from the same kernel body:

* score-only (throughput) — exactly like the ring-buffer jnp reference
  ``kernels.wfa.ref.ref_scores`` it is validated against;
* packed backtrace (``trace=True``) — additionally OR-accumulates 2-bit
  per-cell provenance codes into ``[n_words, B, K]`` int32 words (16 score
  steps per word, same encoding as ``core.wavefront.wfa_scores_packed``;
  three planes for affine, one for linear), which ``core.cigar`` decodes
  into exact CIGARs on the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import scoring
from repro.core.wavefront import (BT_GAP_EXT, BT_GAP_OPEN, BT_M_FROM_D,
                                  BT_M_FROM_I, BT_M_FROM_X,
                                  TRACE_CELLS_PER_WORD, keep_mask,
                                  n_trace_words)

NEG = -(1 << 20)
_THRESH = NEG // 2


def _gather_chars(seq, idx, mode: str):
    """seq [BP, L], idx [BP, K] -> seq[b, idx[b, k]] as [BP, K].

    idx is pre-clipped here; out-of-range lanes read junk that the caller's
    validity mask discards.  ``onehot`` is the VPU compare-and-reduce
    formulation (TPUs lack per-lane gather); ``index`` is a real gather for
    interpret mode, where the body runs as plain jax ops.
    """
    L = seq.shape[1]
    idx_c = jnp.clip(idx, 0, L - 1)
    if mode == "index":
        return jnp.take_along_axis(seq, idx_c, axis=1)
    BP, K = idx.shape
    l_iota = lax.broadcasted_iota(jnp.int32, (BP, K, L), 2)
    hit = (l_iota == idx_c[:, :, None])
    return jnp.sum(jnp.where(hit, seq[:, None, :], 0), axis=2)


def _gather_strided(seq, idx, C: int):
    """seq [BP, L], idx [BP, K] -> seq[b, idx[b, k] + c] as [BP, K, C].

    One flattened take_along_axis for all C consecutive characters
    (index-gather mode only)."""
    BP, L = seq.shape
    K = idx.shape[1]
    cidx = lax.broadcasted_iota(jnp.int32, (BP, K, C), 2)
    flat = jnp.clip(idx[:, :, None] + cidx, 0, L - 1).reshape(BP, K * C)
    return jnp.take_along_axis(seq, flat, axis=1).reshape(BP, K, C)


def _make_kernel(model, heur, s_max: int, k_pad: int, trace: bool,
                 gather: str, ext_stride: int, band: bool):
    x, o, e = model.x, model.o, model.e
    W = model.window
    affine = model.kind == "affine"
    n_bt = (3 if affine else 1) if trace else 0
    C = ext_stride if gather == "index" else 1
    kc_full = k_pad // 2                     # absolute diagonal center

    def kernel(p_ref, t_ref, pl_ref, tl_ref, out_ref, steps_ref, *refs):
        bt_refs = refs[:n_bt]
        rings = refs[:-1][n_bt:] if band else refs[n_bt:]
        off_ref = refs[-1] if band else None  # [W, 1] SMEM row offsets
        if affine:
            m_ring, i_ring, d_ring = rings
        else:
            (m_ring,) = rings
        BP, Lp = p_ref.shape
        _, Lt = t_ref.shape
        Kc = m_ring.shape[-1]                # compact (== k_pad unless band)

        pat = p_ref[...]
        txt = t_ref[...]
        plen = pl_ref[...]                   # [BP, 1]
        tlen = tl_ref[...]
        jidx = lax.broadcasted_iota(jnp.int32, (BP, Kc), 1)

        def ks_of(off):
            """Absolute diagonal of each compact lane (off = 0 unbanded)."""
            return jidx + (off - kc_full)

        def extend(M, ks):
            def trip(st):
                M, _ = st
                v = M - ks
                base = (M > _THRESH)
                if C == 1:
                    can = (base & (M >= 0) & (M < tlen)
                           & (v >= 0) & (v < plen))
                    tc = _gather_chars(txt, M, gather)
                    pc = _gather_chars(pat, v, gather)
                    adv = (can & (tc == pc)).astype(jnp.int32)
                    return M + adv, jnp.any(adv == 1)
                tcs = _gather_strided(txt, M, C)
                pcs = _gather_strided(pat, v, C)
                cidx = lax.broadcasted_iota(jnp.int32, (BP, Kc, C), 2)
                h3 = M[:, :, None] + cidx
                v3 = v[:, :, None] + cidx
                ok = (base[:, :, None]
                      & (h3 >= 0) & (h3 < tlen[:, :, None])
                      & (v3 >= 0) & (v3 < plen[:, :, None])
                      & (tcs == pcs))
                # matched prefix length along the stride
                adv = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=2),
                              axis=2)
                return M + adv, jnp.any(adv >= C)

            st = trip((M, jnp.bool_(True)))
            M, _ = lax.while_loop(lambda st: st[1], trip, st)
            return M

        def reached(M, ks):
            """[BP, 1] bool: furthest offset hit the (tlen, plen) corner."""
            k_final = tlen - plen            # [BP, 1] diagonal value
            hit = (ks == k_final) & (M >= tlen) & (M > _THRESH)
            return jnp.any(hit, axis=1, keepdims=True)

        def prune(M, ks):
            # shared policy implementation; plen/tlen/ks are already in
            # keep_mask's 2-D convention ([BP, 1] / [BP, Kc])
            keep = keep_mask(heur, M, plen, tlen, ks)
            if keep is None:
                return M, None
            return jnp.where(keep, M, NEG), keep

        def store_row(ring, row, val):
            ring[pl.ds(row, 1)] = val[None]

        neg_kc = jnp.full((BP, Kc), NEG, jnp.int32)

        def load_row(ring, s, delta, off):
            row = lax.rem(jnp.maximum(s - delta, 0), W)
            val = ring[pl.ds(row, 1)][0]
            if band:
                # realign the stored window to the current offset: pad both
                # sides with NEG and slide by the offset delta (no gather)
                shift = jnp.clip(off - off_ref[row, 0], -Kc, Kc)
                padded = jnp.concatenate([neg_kc, val, neg_kc], axis=1)
                val = lax.dynamic_slice_in_dim(padded, Kc + shift, Kc,
                                               axis=1)
            return jnp.where(s >= delta, val, NEG)

        def scatter_full(code, off):
            """Place a compact [BP, Kc] plane at absolute k (width k_pad)."""
            if not band:
                return code
            full = jnp.zeros((BP, k_pad), jnp.int32)
            return lax.dynamic_update_slice(full, code, (0, off))

        def pack_code(bt_ref, s, code, off):
            """OR the 2-bit code plane into word s//16 of bt_ref."""
            w = s // TRACE_CELLS_PER_WORD
            sh = 2 * lax.rem(s, TRACE_CELLS_PER_WORD)
            cur = bt_ref[pl.ds(w, 1)]
            full = scatter_full(code, off)
            bt_ref[pl.ds(w, 1)] = cur | jnp.left_shift(full, sh)[None]

        # s = 0
        if trace:
            # out buffers are uninitialized; codes are OR-accumulated
            for bt in bt_refs:
                bt[...] = jnp.zeros_like(bt)
        if band:
            off0 = min(max(kc_full - Kc // 2, 0), k_pad - Kc)
            off_ref[...] = jnp.full(off_ref.shape, off0, jnp.int32)
        else:
            off0 = 0
        ks0 = ks_of(off0)
        M0 = extend(jnp.where(ks0 == 0, 0, NEG), ks0)
        store_row(m_ring, 0, M0)
        if affine:
            store_row(i_ring, 0, neg_kc)
            store_row(d_ring, 0, neg_kc)
        score0 = jnp.where(reached(M0, ks0), 0, -1)

        neg_col = jnp.full((BP, 1), NEG, jnp.int32)
        sh_r = lambda w: jnp.concatenate([neg_col, w[:, :-1]], axis=1)
        sh_l = lambda w: jnp.concatenate([w[:, 1:], neg_col], axis=1)

        def recenter(s):
            """New window offset from the previous row's live lanes."""
            if not band:
                return 0
            prow = lax.rem(s - 1, W)
            live = m_ring[pl.ds(prow, 1)][0] > _THRESH
            if affine:
                # I/D fronts can outrun M between prunes; use the union
                live = (live | (i_ring[pl.ds(prow, 1)][0] > _THRESH)
                        | (d_ring[pl.ds(prow, 1)][0] > _THRESH))
            poff = off_ref[prow, 0]
            lo = jnp.min(jnp.where(live, jidx, Kc))
            hi = jnp.max(jnp.where(live, jidx, -1))
            new = jnp.clip(poff + (lo + hi) // 2 - Kc // 2, 0, k_pad - Kc)
            return jnp.where(hi >= lo, new, poff)

        def body(carry):
            s, score = carry
            off = recenter(s)
            ks = ks_of(off)
            m_x = load_row(m_ring, s, x, off)
            if affine:
                m_owe = load_row(m_ring, s, o + e, off)
                i_e = load_row(i_ring, s, e, off)
                d_e = load_row(d_ring, s, e, off)
                i_open, i_ext = sh_r(m_owe), sh_r(i_e)
                i_src = jnp.maximum(i_open, i_ext)
                d_open, d_ext = sh_l(m_owe), sh_l(d_e)
                d_src = jnp.maximum(d_open, d_ext)
            else:
                m_e = m_x if x == e else load_row(m_ring, s, e, off)
                i_src = sh_r(m_e)
                d_src = sh_l(m_e)

            I_new = jnp.where((i_src > _THRESH) & (i_src + 1 <= tlen),
                              i_src + 1, NEG)
            D_new = jnp.where((d_src > _THRESH) & (d_src - ks <= plen),
                              d_src, NEG)
            X_new = jnp.where((m_x > _THRESH) & (m_x + 1 <= tlen)
                              & (m_x + 1 - ks <= plen), m_x + 1, NEG)
            M_pre = jnp.maximum(jnp.maximum(X_new, I_new), D_new)
            M_new = extend(M_pre, ks)

            if trace:
                # codes from the PRE-prune fronts — bit-identical to
                # wfa_scores_packed even on lanes a heuristic then kills
                # (those codes are unreachable in traceback either way)
                code_m = jnp.where(
                    M_pre > _THRESH,
                    jnp.where(M_pre == X_new, BT_M_FROM_X,
                              jnp.where(M_pre == I_new, BT_M_FROM_I,
                                        BT_M_FROM_D)), 0)
                pack_code(bt_refs[0], s, code_m, off)
                if affine:
                    code_i = jnp.where(
                        I_new > _THRESH,
                        jnp.where(i_ext >= i_open, BT_GAP_EXT,
                                  BT_GAP_OPEN), 0)
                    code_d = jnp.where(
                        D_new > _THRESH,
                        jnp.where(d_ext >= d_open, BT_GAP_EXT,
                                  BT_GAP_OPEN), 0)
                    pack_code(bt_refs[1], s, code_i, off)
                    pack_code(bt_refs[2], s, code_d, off)

            score = jnp.where((score < 0) & reached(M_new, ks), s, score)
            M_new, keep = prune(M_new, ks)
            if affine and keep is not None:
                I_new = jnp.where(keep, I_new, NEG)
                D_new = jnp.where(keep, D_new, NEG)

            row = lax.rem(s, W)
            store_row(m_ring, row, M_new)
            if affine:
                store_row(i_ring, row, I_new)
                store_row(d_ring, row, D_new)
            if band:
                off_ref[row, 0] = off
            return s + 1, score

        def cond(carry):
            s, score = carry
            return (s <= s_max) & jnp.any(score < 0)

        s_end, score = lax.while_loop(cond, body, (jnp.int32(1), score0))
        out_ref[...] = score
        steps_ref[...] = jnp.broadcast_to(s_end, steps_ref.shape)

    return kernel, W, affine


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_pad",
                                             "block_pairs", "interpret",
                                             "trace", "heur", "gather",
                                             "ext_stride", "band_cap"))
def wfa_pallas(pattern, text, plen, tlen, *, pen, s_max: int,
               k_pad: int, block_pairs: int = 8, interpret: bool = True,
               trace: bool = False, heur=None, gather=None,
               ext_stride: int = 1, band_cap=None):
    """pattern/text [B, L*] int32 (B % block_pairs == 0, L* % 128 == 0),
    plen/tlen [B, 1] int32, k_pad % 128 == 0 is the padded diagonal count.
    -> (score [B, 1] int32, steps [B, 1] int32); with ``trace`` additionally
    the [n_words, B, k_pad] int32 packed provenance arrays (three for
    affine models, one for linear).

    ``gather`` (``"index"``/``"onehot"``; None = index under interpret,
    onehot compiled), ``ext_stride`` (chars fetched per extend trip, index
    mode) and ``band_cap`` (compact ring width, lane-aligned by the ops
    wrapper; None = full width) are static — see the module docstring.
    """
    B, Lp = pattern.shape
    Lt = text.shape[1]
    BP = block_pairs
    assert B % BP == 0, (B, BP)
    model = scoring.as_model(pen)
    heur = scoring.as_heuristic(heur)
    if gather is None:
        gather = "index" if interpret else "onehot"
    band = band_cap is not None and band_cap < k_pad
    Kc = band_cap if band else k_pad
    kernel, W, affine = _make_kernel(model, heur, s_max, k_pad, trace,
                                     gather, max(int(ext_stride), 1), band)
    grid = (B // BP,)
    n_rings = 3 if affine else 1

    spec2 = lambda L: pl.BlockSpec((BP, L), lambda i: (i, 0))
    out_specs = [spec2(1), spec2(1)]
    out_shape = [jax.ShapeDtypeStruct((B, 1), jnp.int32),
                 jax.ShapeDtypeStruct((B, 1), jnp.int32)]
    if trace:
        NW = n_trace_words(s_max)
        n_bt = 3 if affine else 1
        bt_spec = pl.BlockSpec((NW, BP, k_pad), lambda i: (0, i, 0))
        out_specs += [bt_spec] * n_bt
        out_shape += [jax.ShapeDtypeStruct((NW, B, k_pad), jnp.int32)] * n_bt
    scratch = [pltpu.VMEM((W, BP, Kc), jnp.int32)] * n_rings
    if band:
        scratch += [pltpu.SMEM((W, 1), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec2(Lp), spec2(Lt), spec2(1), spec2(1)],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(pattern, text, plen, tlen)


def _make_meet_kernel(model, heur, s_max: int, k_pad: int,
                      begin_state: str, end_state: str):
    """BiWFA meet-in-the-middle as one fused grid program.

    Port of ``core.wavefront.wfa_bidir_meet`` (same candidate classes,
    window ``Wd`` and safety flags — see its docstring for the algorithm):
    forward and reverse fronts step in lockstep inside a single
    ``lax.while_loop``, both sets of rings resident in VMEM scratch, and
    the meet test is fused into the same loop — so one grid program runs
    the whole breakpoint search for its block and exits as soon as *its*
    pairs have met (the jnp solver's early exit spans the whole batch).
    Index-gather only (the per-pair ring reads and complement-diagonal
    gathers are real gathers): interpret mode / CPU, the validation target.
    """
    x, o, e = model.x, model.o, model.e
    affine = model.kind == "affine"
    oend = (o if affine else 0) if end_state != "M" else 0
    maxop = max(x, o + e) if affine else max(x, e)
    Wd = max(model.window, 2 * maxop + 2)
    kc = k_pad // 2
    n_rings = 7 if affine else 3

    def kernel(p_ref, t_ref, pr_ref, tr_ref, pl_ref, tl_ref, st_ref,
               score_ref, steps_ref, state_ref, a_ref, b_ref, kk_ref,
               h_ref, safe_ref, *rings):
        if affine:
            fm, fmp, fi, fd, rm, ri, rd = rings
        else:
            fm, fmp, rm = rings
        BP = p_ref.shape[0]
        K = fm.shape[-1]

        pat, txt = p_ref[...], t_ref[...]
        patr, txtr = pr_ref[...], tr_ref[...]
        plen, tlen = pl_ref[...], tl_ref[...]          # [BP, 1]
        starget = st_ref[...]
        jidx = lax.broadcasted_iota(jnp.int32, (BP, K), 1)
        ks = jidx - kc

        def extend(M, p2, t2):
            def trip(st):
                M, _ = st
                v = M - ks
                can = ((M > _THRESH) & (M >= 0) & (M < tlen)
                       & (v >= 0) & (v < plen))
                tc = _gather_chars(t2, M, "index")
                pc = _gather_chars(p2, v, "index")
                adv = (can & (tc == pc)).astype(jnp.int32)
                return M + adv, jnp.any(adv == 1)

            st = trip((M, jnp.bool_(True)))
            M, _ = lax.while_loop(lambda st: st[1], trip, st)
            return M

        def store(ring, row, val):
            ring[pl.ds(row, 1)] = val[None]

        def load(ring, s, delta):
            row = lax.rem(jnp.maximum(s - delta, 0), Wd)
            val = ring[pl.ds(row, 1)][0]
            return jnp.where(s >= delta, val, NEG)

        neg_col = jnp.full((BP, 1), NEG, jnp.int32)
        sh_r = lambda w: jnp.concatenate([neg_col, w[:, :-1]], axis=1)
        sh_l = lambda w: jnp.concatenate([w[:, 1:], neg_col], axis=1)

        def step(mring, iring, dring, s, p2, t2):
            """One affine/linear score step from the given rings.

            Returns (M_new, I_new, D_new, M_pre); I/D are None for
            linear models (their sources fold into M directly)."""
            m_x = load(mring, s, x)
            if affine:
                m_owe = load(mring, s, o + e)
                i_src = jnp.maximum(sh_r(m_owe), sh_r(load(iring, s, e)))
                d_src = jnp.maximum(sh_l(m_owe), sh_l(load(dring, s, e)))
            else:
                m_e = m_x if x == e else load(mring, s, e)
                i_src, d_src = sh_r(m_e), sh_l(m_e)
            I_new = jnp.where((i_src > _THRESH) & (i_src + 1 <= tlen),
                              i_src + 1, NEG)
            D_new = jnp.where((d_src > _THRESH) & (d_src - ks <= plen),
                              d_src, NEG)
            X_new = jnp.where((m_x > _THRESH) & (m_x + 1 <= tlen)
                              & (m_x + 1 - ks <= plen), m_x + 1, NEG)
            M_pre = jnp.maximum(jnp.maximum(X_new, I_new), D_new)
            return extend(M_pre, p2, t2), I_new, D_new, M_pre

        def prune(*fronts):
            keep = keep_mask(heur, fronts[0], plen, tlen, ks)
            if keep is None:
                return fronts
            return tuple(jnp.where(keep, w, NEG) for w in fronts)

        # s = 0 seeds (fwd M + begin-state gap; rev M + end-state gap —
        # the reversed problem's *leading* gap, hence the oend shift)
        seed = jnp.where(ks == 0, 0, NEG)
        negK = jnp.full((BP, K), NEG, jnp.int32)
        store(fm, 0, extend(seed, pat, txt))
        store(fmp, 0, seed)
        store(rm, 0, extend(seed, patr, txtr))
        if affine:
            store(fi, 0, seed if begin_state == "I" else negK)
            store(fd, 0, seed if begin_state == "D" else negK)
            store(ri, 0, seed if end_state == "I" else negK)
            store(rd, 0, seed if end_state == "D" else negK)

        # complement-diagonal gather: rev K-index addressing the same cell
        jprime = (tlen - plen) + 2 * kc - jidx
        jpok = (jprime >= 0) & (jprime < K)
        jpc = jnp.clip(jprime, 0, K - 1)

        def comp(arr):
            return jnp.where(jpok, jnp.take_along_axis(arr, jpc, axis=1),
                             NEG)

        def at(ring, c):
            """Ring row at per-pair cost c [BP, 1] (NEG outside window)."""
            ok = (c >= 0) & (c <= s_cur[0]) & (c > s_cur[0] - Wd)
            rows = lax.rem(jnp.maximum(c[:, 0], 0), Wd)
            all_rows = ring[...]
            sel = jnp.take_along_axis(
                all_rows, jnp.broadcast_to(rows[None, :, None], (1, BP, K)),
                axis=0)[0]
            return jnp.where(ok, sel, NEG)

        m2 = tlen
        low = jnp.maximum(ks, 0)
        met0 = (plen == 0) & (tlen == 0)       # padded rows: free the exit

        # mutable closure cell for the current step (at() needs it)
        s_cur = [jnp.int32(0)]

        def body(carry):
            s, met, jst, ja, jb, jk, jh, jsf = carry
            s_cur[0] = s
            Mf, If, Df, Mfp = step(fm, fi if affine else None,
                                   fd if affine else None, s, pat, txt)
            Mr, Ir, Dr, _ = step(rm, ri if affine else None,
                                 rd if affine else None, s, patr, txtr)
            if affine:
                Mf, If, Df, Mfp = prune(Mf, If, Df, Mfp)
                Mr, Ir, Dr = prune(Mr, Ir, Dr)
            else:
                Mf, Mfp = prune(Mf, Mfp)
                (Mr,) = prune(Mr)
            row = lax.rem(s, Wd)
            store(fm, row, Mf)
            store(fmp, row, Mfp)
            store(rm, row, Mr)
            if affine:
                store(fi, row, If)
                store(fd, row, Df)
                store(ri, row, Ir)
                store(rd, row, Dr)

            def orient(a_m, a_g, b_m, b_g):
                """Candidate classes for prefix costs a_*, suffix costs
                b_* (see wfa_bidir_meet.orient)."""
                fa_m, fa_mp = at(fm, a_m), at(fmp, a_m)
                rb_m = comp(at(rm, b_m))
                vmm = (fa_m > _THRESH) & (rb_m > _THRESH)
                cov = vmm & (fa_m + rb_m >= m2)
                h_mm = jnp.clip(m2 - rb_m, low, jnp.maximum(fa_m, low))
                out = {"mm_safe": (cov & (fa_mp + rb_m <= m2), 0, a_m, b_m,
                                   h_mm, 1),
                       "mm_cov": (cov, 0, a_m, b_m, h_mm, 0)}
                if affine:
                    fa_i, rb_i = at(fi, a_g), comp(at(ri, b_g))
                    fa_d, rb_d = at(fd, a_g), comp(at(rd, b_g))
                    vii = (fa_i > _THRESH) & (rb_i > _THRESH)
                    vdd = (fa_d > _THRESH) & (rb_d > _THRESH)
                    out["ii0"] = (vii & (fa_i + rb_i == m2), 1, a_g, b_g,
                                  fa_i, 1)
                    out["dd0"] = (vdd & (fa_d + rb_d == m2), 2, a_g, b_g,
                                  fa_d, 1)
                    out["ii_cov"] = (vii & (fa_i + rb_i >= m2), 1, a_g,
                                     b_g, fa_i, 0)
                    out["dd_cov"] = (vdd & (fa_d + rb_d >= m2), 2, a_g,
                                     b_g, fa_d, 0)
                return out

            sb = jnp.full((BP, 1), 0, jnp.int32) + s
            st2 = starget - oend
            A = orient(sb, sb, st2 - s, st2 + (o if affine else 0) - s)
            Bo = orient(st2 - s, st2 + (o if affine else 0) - s, sb, sb)
            names = ["mm_safe"] + (["ii0", "dd0"] if affine else []) \
                + ["mm_cov"] + (["ii_cov", "dd_cov"] if affine else [])
            for name in names:
                for side in (A, Bo):
                    mask2d, stc, a_arr, b_arr, hplane, sf = side[name]
                    anyk = jnp.any(mask2d, axis=1, keepdims=True)
                    kidx = jnp.argmax(mask2d, axis=1).astype(
                        jnp.int32)[:, None]
                    hsel = jnp.take_along_axis(hplane, kidx, axis=1)
                    take = (~met) & anyk
                    met = met | take
                    jst = jnp.where(take, stc, jst)
                    ja = jnp.where(take, a_arr, ja)
                    jb = jnp.where(take, b_arr, jb)
                    jk = jnp.where(take, kidx - kc, jk)
                    jh = jnp.where(take, hsel, jh)
                    jsf = jnp.where(take, sf, jsf)
            return s + 1, met, jst, ja, jb, jk, jh, jsf

        def cond(carry):
            s, met = carry[0], carry[1]
            return (s <= s_max) & ~jnp.all(met)

        z = jnp.zeros((BP, 1), jnp.int32)
        s_end, met, jst, ja, jb, jk, jh, jsf = lax.while_loop(
            cond, body, (jnp.int32(1), met0, z - 1, z, z, z, z, z))
        hit = met & ~met0                      # padded rows report unmet
        score_ref[...] = jnp.where(hit, starget, -1)
        steps_ref[...] = jnp.broadcast_to(s_end, (BP, 1))
        state_ref[...] = jnp.where(hit, jst, -1)
        a_ref[...] = ja
        b_ref[...] = jb
        kk_ref[...] = jk
        h_ref[...] = jh
        safe_ref[...] = jsf

    return kernel, Wd, n_rings


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_pad",
                                             "block_pairs", "interpret",
                                             "heur", "begin_state",
                                             "end_state"))
def wfa_meet_pallas(pattern, text, pat_rev, txt_rev, plen, tlen, starget, *,
                    pen, s_max: int, k_pad: int, block_pairs: int = 8,
                    interpret: bool = True, heur=None,
                    begin_state: str = "M", end_state: str = "M"):
    """Fused BiWFA meet search: same input contract as :func:`wfa_pallas`
    plus per-row-reversed sequences (computed by the ops wrapper — cheaper
    batched on the host side of the grid) and ``starget`` [B, 1].
    Returns 8 ``[B, 1]`` int32 arrays: score, steps, meet_state, meet_a,
    meet_b, meet_k, meet_h, meet_safe (``BidirMeetResult`` fields)."""
    B, Lp = pattern.shape
    BP = block_pairs
    assert B % BP == 0, (B, BP)
    model = scoring.as_model(pen)
    heur = scoring.as_heuristic(heur)
    kernel, Wd, n_rings = _make_meet_kernel(model, heur, s_max, k_pad,
                                            begin_state, end_state)
    grid = (B // BP,)
    spec2 = lambda L: pl.BlockSpec((BP, L), lambda i: (i, 0))
    Lt = text.shape[1]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec2(Lp), spec2(Lt), spec2(Lp), spec2(Lt),
                  spec2(1), spec2(1), spec2(1)],
        out_specs=[spec2(1)] * 8,
        out_shape=[jax.ShapeDtypeStruct((B, 1), jnp.int32)] * 8,
        scratch_shapes=[pltpu.VMEM((Wd, BP, k_pad), jnp.int32)] * n_rings,
        interpret=interpret,
    )(pattern, text, pat_rev, txt_rev, plen, tlen, starget)
