"""Pure-jnp oracle for the Pallas WFA kernel.

Delegates to ``core.wavefront.wfa_scores`` — the same rolling-window,
score-only formulation the kernel implements, written in plain jnp with no
Pallas constructs.  The kernel test sweeps shapes/dtypes and asserts exact
equality (scores are integers; there is no tolerance to pick).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.penalties import Penalties
from repro.core.wavefront import wfa_scores


def ref_scores(pattern, text, plen, tlen, *, pen: Penalties, s_max: int,
               k_max: int, heur=None, band_cap=None):
    """[B] int32 alignment costs (-1 where > s_max)."""
    res = wfa_scores(jnp.asarray(pattern), jnp.asarray(text),
                     jnp.asarray(plen).reshape(-1),
                     jnp.asarray(tlen).reshape(-1),
                     pen=pen, s_max=s_max, k_max=k_max, heur=heur,
                     band_cap=band_cap)
    return res.score
