"""DNA alphabet helpers — reverse complement, 2-bit packing, N handling.

The mapping subsystem (``repro.mapping``) works on nucleotides, not on the
engine's opaque integer codes: minimizer seeding needs 2-bit packed k-mers
and strand canonicalization needs reverse complements.  These helpers are
the single home for that alphabet logic, shared by the index, the chainers
and the synthetic ground-truth read sampler.

Conventions:

* Sequences travel as ASCII uint8 arrays (what ``data.io`` parses and
  ``core.engine.encode`` produces for strings); ``str`` in, ``str`` out.
* 2-bit codes: A=0, C=1, G=2, T=3 (case-insensitive).  Any other byte —
  N and the rest of the IUPAC ambiguity codes — maps to :data:`NCODE`
  (4), a sentinel outside the 2-bit range.  A k-mer window containing a
  sentinel can never become a minimizer (the index masks it), so N runs
  simply produce no seeds instead of seeding false matches.
* Reverse complement keeps ambiguity: A<->T, C<->G (either case; output
  upper), everything else becomes ``N`` — never a silent A.
"""
from __future__ import annotations

from typing import Union

import numpy as np

__all__ = ["NCODE", "as_ascii", "encode_2bit", "decode_2bit", "revcomp",
           "comp_2bit", "random_reference"]

NCODE = 4          # sentinel 2-bit code for N / ambiguity bytes

# ASCII byte -> 2-bit code (everything unmapped -> NCODE).
_TO_2BIT = np.full(256, NCODE, np.uint8)
for _i, _b in enumerate(b"ACGT"):
    _TO_2BIT[_b] = _i
    _TO_2BIT[_b + 32] = _i          # lowercase

_FROM_2BIT = np.frombuffer(b"ACGTN", dtype=np.uint8)

# ASCII byte -> complement ASCII byte (unmapped -> 'N').
_COMP = np.full(256, ord("N"), np.uint8)
for _a, _b in zip(b"ACGTacgt", b"TGCATGCA"):
    _COMP[_a] = _b


def as_ascii(seq: Union[str, bytes, np.ndarray]) -> np.ndarray:
    """Normalize str / bytes / integer arrays to an ASCII uint8 array."""
    if isinstance(seq, str):
        return np.frombuffer(seq.encode("ascii"), dtype=np.uint8)
    if isinstance(seq, bytes):
        return np.frombuffer(seq, dtype=np.uint8)
    return np.asarray(seq).astype(np.uint8)


def encode_2bit(seq: Union[str, bytes, np.ndarray]) -> np.ndarray:
    """ASCII/str sequence -> [L] uint8 2-bit codes (N etc. -> NCODE)."""
    return _TO_2BIT[as_ascii(seq)]


def decode_2bit(codes: np.ndarray, as_str: bool = True):
    """[L] 2-bit codes -> sequence string (or ASCII array).

    Codes outside {0..3} decode to ``N`` — decode(encode(s)) round-trips
    exactly for upper-case ACGTN sequences.
    """
    codes = np.asarray(codes)
    out = _FROM_2BIT[np.minimum(codes, NCODE)]
    return out.tobytes().decode("ascii") if as_str else out


def comp_2bit(codes: np.ndarray) -> np.ndarray:
    """Complement 2-bit codes (3 - c); the NCODE sentinel stays NCODE."""
    codes = np.asarray(codes)
    return np.where(codes >= NCODE, codes, 3 - codes).astype(codes.dtype)


def revcomp(seq: Union[str, bytes, np.ndarray]):
    """Reverse complement.  str -> str; array/bytes -> ASCII uint8 array."""
    arr = _COMP[as_ascii(seq)][::-1].copy()
    return arr.tobytes().decode("ascii") if isinstance(seq, str) else arr


def random_reference(length: int, seed: int = 0) -> np.ndarray:
    """Uniform-random ACGT reference as an ASCII uint8 array.

    Deterministic per seed — the synthetic genome under the mapping
    ground-truth sampler (``data.reads.sample_from_reference``) and the
    mapping benchmark.
    """
    rng = np.random.default_rng(seed)
    bases = np.frombuffer(b"ACGT", dtype=np.uint8)
    return bases[rng.integers(0, 4, size=int(length))]
