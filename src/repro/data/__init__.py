from repro.data.reads import ReadPairSpec, generate_pairs, generate_shard  # noqa: F401
from repro.data.io import iter_seqs, load_pair_files, read_seqs  # noqa: F401
from repro.data.tokens import TokenStreamSpec, batch_for_step  # noqa: F401
