from repro.data.reads import (ReadPairSpec, SampledRead, generate_pairs,  # noqa: F401
                              generate_shard, sample_from_reference)
from repro.data.io import iter_seqs, load_pair_files, read_seqs  # noqa: F401
from repro.data.dna import (NCODE, as_ascii, decode_2bit, encode_2bit,  # noqa: F401
                            random_reference, revcomp)
from repro.data.tokens import TokenStreamSpec, batch_for_step  # noqa: F401
