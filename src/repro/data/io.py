"""FASTA / FASTQ read-pair input — real read files for the aligner.

The synthetic generator (``data.reads``) reproduces the paper's workload;
this module feeds the same pipeline from real sequence files so
``launch/align.py --reads/--refs`` aligns actual data.  Plain and
gzip-compressed files are both accepted (sniffed by magic bytes, so a
``.fastq`` that is secretly gzipped still opens); the format is sniffed
from the first record character (``>`` FASTA, ``@`` FASTQ), not the file
extension.

Parsing is deliberately minimal and strict about *structure* (record
markers, FASTQ 4-line groups, +-line separator) but permissive about
*content* (any ASCII sequence alphabet; the aligner compares integer
codes, so IUPAC ambiguity codes and lowercase just work).  Sequences come
back as raw ASCII-uint8 arrays, the exact dtype ``core.engine.encode``
produces for strings.
"""
from __future__ import annotations

import gzip
import io
import itertools
from typing import IO, Iterator, List, Tuple

import numpy as np

__all__ = ["read_seqs", "iter_seqs", "load_pair_files"]

_GZIP_MAGIC = b"\x1f\x8b"


def _open_text(path: str) -> IO[str]:
    """Open ``path`` as text, transparently gunzipping (magic-byte sniff)."""
    with open(path, "rb") as probe:
        magic = probe.read(2)
    if magic == _GZIP_MAGIC:
        return io.TextIOWrapper(gzip.open(path, "rb"), encoding="ascii")
    return open(path, "r", encoding="ascii")


def iter_seqs(path: str) -> Iterator[Tuple[str, np.ndarray]]:
    """Yield ``(name, sequence)`` records from a FASTA or FASTQ file.

    ``sequence`` is a 1-D uint8 array of ASCII codes (what
    ``core.engine.encode`` produces for a str).  FASTA sequences may span
    multiple lines; FASTQ records must be the standard 4-line form
    (quality lines are skipped — alignment does not use them).
    """
    with _open_text(path) as f:
        first = f.read(1)
        if first == "":
            return
        if first == ">":
            yield from _iter_fasta(f)
        elif first == "@":
            yield from _iter_fastq(f)
        else:
            raise ValueError(
                f"{path}: not FASTA or FASTQ (first record starts with "
                f"{first!r}, expected '>' or '@')")


def _encode(parts: List[str]) -> np.ndarray:
    seq = "".join(parts)
    return np.frombuffer(seq.encode("ascii"), dtype=np.uint8)


def _name_of(header: str) -> str:
    fields = header.strip().split()
    return fields[0] if fields else ""


def _iter_fasta(f: IO[str]) -> Iterator[Tuple[str, np.ndarray]]:
    # caller consumed the leading '>' of the first header
    name = _name_of(f.readline())
    parts: List[str] = []
    for line in f:
        line = line.strip()
        if not line:
            continue
        if line.startswith(">"):
            yield name, _encode(parts)
            name = _name_of(line[1:])
            parts = []
        else:
            parts.append(line)
    yield name, _encode(parts)


def _iter_fastq(f: IO[str]) -> Iterator[Tuple[str, np.ndarray]]:
    # caller consumed the leading '@' of the first header
    header = f.readline().strip()
    while True:
        seq = f.readline()
        plus = f.readline()
        qual = f.readline()
        if not qual:
            raise ValueError("truncated FASTQ record "
                             f"(header {_name_of(header)!r})")
        if not plus.startswith("+"):
            raise ValueError("malformed FASTQ record: expected '+' line, got "
                             f"{plus.strip()!r}")
        yield _name_of(header), _encode([seq.strip()])
        nxt = f.readline()
        if not nxt:
            return
        if not nxt.startswith("@"):
            raise ValueError("malformed FASTQ record: expected '@' header, "
                             f"got {nxt.strip()!r}")
        header = nxt[1:].strip()


def read_seqs(path: str) -> Tuple[List[str], List[np.ndarray]]:
    """Read a whole FASTA/FASTQ(.gz) file -> (names, uint8 sequences)."""
    names: List[str] = []
    seqs: List[np.ndarray] = []
    for name, seq in iter_seqs(path):
        names.append(name)
        seqs.append(seq)
    return names, seqs


def load_pair_files(reads_path: str, refs_path: str,
                    limit: int = 0) -> Tuple[np.ndarray, np.ndarray,
                                             np.ndarray, np.ndarray]:
    """Load two FASTA/FASTQ(.gz) files as aligner-ready packed pairs.

    Record *i* of ``refs_path`` is the pattern aligned against record *i*
    of ``reads_path`` (the text), matching the synthetic generator's
    (reference, mate) convention.  ``limit`` caps the pair count (0 =
    all) and is applied while streaming, so only the first ``limit``
    records of each file are ever parsed or held in memory.
    -> ``(patterns [N, Lp], plens [N], texts [N, Lt], tlens [N])`` int32,
    zero-padded exactly like ``data.reads.generate_pairs``.
    """
    stop = limit if limit else None
    refs = [s for _, s in itertools.islice(iter_seqs(refs_path), stop)]
    reads = [s for _, s in itertools.islice(iter_seqs(reads_path), stop)]
    if len(refs) != len(reads):
        raise ValueError(
            f"pair files disagree: {len(refs)} records in {refs_path} vs "
            f"{len(reads)} in {reads_path}"
            + (f" (within the first {limit} records)" if limit else ""))
    if not refs:
        raise ValueError(f"no records in {refs_path}")
    Lp = max(len(p) for p in refs)
    Lt = max(len(t) for t in reads)
    n = len(refs)
    P = np.zeros((n, max(Lp, 1)), np.int32)
    T = np.zeros((n, max(Lt, 1)), np.int32)
    plen = np.empty((n,), np.int32)
    tlen = np.empty((n,), np.int32)
    for i, (p, t) in enumerate(zip(refs, reads)):
        P[i, : len(p)] = p
        T[i, : len(t)] = t
        plen[i] = len(p)
        tlen[i] = len(t)
    return P, plen, T, tlen
