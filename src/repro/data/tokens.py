"""Deterministic synthetic LM token pipeline.

Every batch is a pure function of (seed, step, shard) — the property the
fault-tolerance story relies on: a restarted worker, or a healthy worker
taking over a straggler's shard, regenerates byte-identical data, so
training continues without divergence and without a data-journal service.

The stream is a Zipf-ish unigram mix with short repeated motifs so the loss
actually decreases during the examples' few-hundred-step runs (pure uniform
tokens would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64


def _rng_for(spec: TokenStreamSpec, step: int, shard: int):
    return np.random.default_rng(
        (spec.seed * 1_000_003 + step) * 65_537 + shard)


def _motifs(spec: TokenStreamSpec) -> np.ndarray:
    rng = np.random.default_rng(spec.seed ^ 0x5EED)
    return rng.integers(0, spec.vocab_size,
                        size=(spec.n_motifs, spec.motif_len), dtype=np.int32)


def batch_for_step(spec: TokenStreamSpec, step: int, *, shard: int = 0,
                   n_shards: int = 1) -> dict:
    """-> {"tokens": [b, S], "targets": [b, S]} for this worker's shard."""
    assert spec.global_batch % n_shards == 0, (spec.global_batch, n_shards)
    b = spec.global_batch // n_shards
    rng = _rng_for(spec, step, shard)
    motifs = _motifs(spec)
    n_blocks = spec.seq_len // spec.motif_len + 1
    ids = rng.integers(0, spec.n_motifs, size=(b, n_blocks))
    toks = motifs[ids].reshape(b, -1)[:, : spec.seq_len].astype(np.int32)
    # sprinkle noise so the task is not purely memorizable
    noise = rng.random((b, spec.seq_len)) < 0.05
    toks = np.where(noise,
                    rng.integers(0, spec.vocab_size, size=(b, spec.seq_len),
                                 dtype=np.int32),
                    toks)
    targets = np.concatenate([toks[:, 1:], np.full((b, 1), -1, np.int32)],
                             axis=1)
    return {"tokens": toks, "targets": targets}
