"""Synthetic read-pair generator — the paper's workload.

The paper aligns 5M pairs of 100bp reads whose divergence is bounded by an
edit-distance threshold E (2% / 4%).  We reproduce that regime: a reference
read is sampled uniformly over {A,C,G,T}; its mate is the read mutated with
at most ``ceil(E*L)`` edits (substitutions / 1-base insertions / deletions,
mixed like real sequencing error profiles).  Deterministic per (seed, index)
so restarts and shards regenerate identical data (fault-tolerance contract).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

BASES = np.frombuffer(b"ACGT", dtype=np.uint8)


@dataclasses.dataclass(frozen=True)
class ReadPairSpec:
    n_pairs: int = 1000
    read_len: int = 100
    edit_frac: float = 0.02        # the paper's E
    sub_prob: float = 0.6          # edit mix: substitution vs indel
    ins_prob: float = 0.2
    seed: int = 0


def _mutate(rng: np.random.Generator, read: np.ndarray, n_edits: int,
            sub_prob: float, ins_prob: float) -> np.ndarray:
    seq = list(read)
    for _ in range(n_edits):
        r = rng.random()
        pos = int(rng.integers(0, max(1, len(seq))))
        if r < sub_prob and seq:
            old = seq[pos]
            choices = [b for b in BASES if b != old]
            seq[pos] = choices[int(rng.integers(0, 3))]
        elif r < sub_prob + ins_prob:
            seq.insert(pos, int(BASES[int(rng.integers(0, 4))]))
        elif seq:
            del seq[pos]
    return np.asarray(seq, np.uint8)


def generate_pairs(spec: ReadPairSpec) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray, np.ndarray]:
    """-> (patterns [N, Lp], plens [N], texts [N, Lt], tlens [N]) int32.

    Mates can differ in length by up to ceil(E*L) (indels), so arrays are
    padded to the batch max; padding is never read by the aligner.
    """
    rng = np.random.default_rng(spec.seed)
    L = spec.read_len
    n_err = int(np.ceil(spec.edit_frac * L))
    pats, texts = [], []
    for i in range(spec.n_pairs):
        ref = BASES[rng.integers(0, 4, size=L)]
        n_edits = int(rng.integers(0, n_err + 1))
        mate = _mutate(rng, ref, n_edits, spec.sub_prob, spec.ins_prob)
        pats.append(ref)
        texts.append(mate)
    Lp = max(len(p) for p in pats)
    Lt = max(len(t) for t in texts)
    P = np.zeros((spec.n_pairs, Lp), np.int32)
    T = np.zeros((spec.n_pairs, Lt), np.int32)
    plen = np.empty((spec.n_pairs,), np.int32)
    tlen = np.empty((spec.n_pairs,), np.int32)
    for i, (p, t) in enumerate(zip(pats, texts)):
        P[i, : len(p)] = p
        T[i, : len(t)] = t
        plen[i] = len(p)
        tlen[i] = len(t)
    return P, plen, T, tlen


def generate_shard(spec: ReadPairSpec, shard: int, n_shards: int):
    """Deterministic shard: pairs [shard::n_shards] regenerate identically
    regardless of worker count — the restart/straggler-skip contract."""
    sub = dataclasses.replace(
        spec,
        n_pairs=(spec.n_pairs - shard + n_shards - 1) // n_shards,
        seed=spec.seed * 1_000_003 + shard,
    )
    return generate_pairs(sub)


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """An open-loop serving workload: request payloads + Poisson arrivals.

    ``n_requests`` independent requests of ``pairs_per_request`` read
    pairs each, drawn from the paper's E-bounded mutation model, arriving
    as a Poisson process (i.i.d. exponential inter-arrival gaps) whose
    rate is set at replay time — the trace stores payloads and *unit-rate*
    arrival offsets so one trace serves every offered-load point.
    Deterministic per seed (the restart/shard contract of
    :func:`generate_pairs` extends to serving traces).
    """
    n_requests: int = 256
    pairs_per_request: int = 8
    read_len: int = 100
    edit_frac: float = 0.02
    sub_prob: float = 0.6
    ins_prob: float = 0.2
    seed: int = 0


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """[n] sorted arrival offsets (seconds from trace start) of a Poisson
    process with ``rate`` requests/s — i.i.d. exponential gaps, the
    open-loop benchmark's arrival law.  Deterministic per seed."""
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate, size=int(n))
    return np.cumsum(gaps)


def generate_trace(spec: ArrivalSpec):
    """-> (payloads, unit_arrivals): per-request packed pairs + unit-rate
    Poisson offsets.

    ``payloads[i]`` is ``(P, plen, T, tlen)`` for request ``i`` (views
    into one shared batch — no per-request copies); divide
    ``unit_arrivals`` by the offered rate (requests/s) at replay time.
    """
    P, plen, T, tlen = generate_pairs(ReadPairSpec(
        n_pairs=spec.n_requests * spec.pairs_per_request,
        read_len=spec.read_len, edit_frac=spec.edit_frac,
        sub_prob=spec.sub_prob, ins_prob=spec.ins_prob, seed=spec.seed))
    k = spec.pairs_per_request
    payloads = [(P[i * k:(i + 1) * k], plen[i * k:(i + 1) * k],
                 T[i * k:(i + 1) * k], tlen[i * k:(i + 1) * k])
                for i in range(spec.n_requests)]
    return payloads, poisson_arrivals(spec.n_requests, 1.0,
                                      seed=spec.seed + 1)


@dataclasses.dataclass(frozen=True)
class SampledRead:
    """One ground-truth read: where it came from and how mutated it is.

    ``pos`` is the 0-based start of the sampled window on the *forward*
    reference strand; ``strand`` is 1 when the read is the reverse
    complement of that window (mutations applied after the flip);
    ``win_len`` is the window's length on the reference (== the read
    length before mutation — per-read under ``length_dist``).
    """
    read: np.ndarray            # ASCII uint8 sequence as a mapper sees it
    pos: int
    strand: int                 # 0 = forward, 1 = reverse complement
    n_edits: int
    win_len: int = -1


# named error mixes: (sub_prob, ins_prob); deletions take the remainder.
# "ont" is the nanopore-like profile — indel-dominated (~40/30/30
# sub/ins/del), vs the paper's short-read default (~60/20/20).
ERROR_PROFILES = {"ont": (0.4, 0.3)}


def sample_from_reference(ref, n_reads: int, *, read_len: int = 100,
                          edit_frac: float = 0.02, rc_frac: float = 0.5,
                          sub_prob: float = 0.6, ins_prob: float = 0.2,
                          length_dist: str | None = None,
                          length_sigma: float = 0.35,
                          error_profile: str | None = None,
                          seed: int = 0):
    """Draw reads from a reference at known positions/strands -> ground truth.

    The mapping-recall oracle: each read is a uniform window of ``ref``
    (ASCII uint8 array or str), reverse-complemented with probability
    ``rc_frac``, then mutated with at most ``ceil(edit_frac * win_len)``
    edits under the paper's mutation model (same substitution/indel mix as
    :func:`generate_pairs`).  Deterministic per seed, so recall/precision
    numbers are reproducible.  Returns a list of :class:`SampledRead`.

    Long-read extensions (the BiWFA workload):

    * ``length_dist="lognormal"`` draws each window length from an
      ONT-like lognormal with median ``read_len`` and shape
      ``length_sigma`` (clamped to ``[16, len(ref)]``) instead of the
      fixed short-read length;
    * ``error_profile="ont"`` switches the edit mix to the
      indel-dominated nanopore profile (~40/30/30 sub/ins/del),
      overriding ``sub_prob``/``ins_prob``.
    """
    from repro.data.dna import as_ascii, revcomp
    ref = as_ascii(ref)
    if len(ref) < read_len:
        raise ValueError(f"reference ({len(ref)}bp) shorter than "
                         f"read_len ({read_len})")
    if length_dist not in (None, "lognormal"):
        raise ValueError(f"unknown length_dist: {length_dist!r}")
    if error_profile is not None:
        try:
            sub_prob, ins_prob = ERROR_PROFILES[error_profile]
        except KeyError:
            raise ValueError(f"unknown error_profile: {error_profile!r} "
                             f"(have {sorted(ERROR_PROFILES)})") from None
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(int(n_reads)):
        if length_dist == "lognormal":
            wlen = int(round(read_len * np.exp(
                rng.normal(0.0, length_sigma))))
            wlen = max(16, min(wlen, len(ref)))
        else:
            wlen = read_len
        pos = int(rng.integers(0, len(ref) - wlen + 1))
        strand = int(rng.random() < rc_frac)
        window = ref[pos: pos + wlen]
        if strand:
            window = revcomp(window)
        n_err = int(np.ceil(edit_frac * wlen))
        n_edits = int(rng.integers(0, n_err + 1))
        read = _mutate(rng, window, n_edits, sub_prob, ins_prob)
        out.append(SampledRead(read=read.astype(np.uint8), pos=pos,
                               strand=strand, n_edits=n_edits,
                               win_len=wlen))
    return out
