"""AdamW with decoupled weight decay, global-norm clipping and a linear
warmup + cosine schedule.  Implemented from scratch on pytrees (no optax);
optimizer moments shard exactly like their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step,
                 grad_transform=None):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    if grad_transform is not None:
        grads = grad_transform(grads)
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * (g * g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + decay)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
