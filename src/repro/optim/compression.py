"""Gradient compression for cross-pod reduction: bf16 cast and int8
quantization with error feedback.

On a real multi-pod system the data-parallel gradient all-reduce crosses the
(slow) inter-pod links; compressing the payload trades a little fidelity for
up to 4x less inter-pod traffic.  Here the compressors are exact pytree
transforms (validated by unit tests); `train.py` applies them between backward
and the optimizer, and the error-feedback residual rides along in the train
state so restarts are exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def _q8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_int8(grads):
    """-> pytree of (int8 values, fp32 scale) pairs."""
    return jax.tree.map(_q8, grads)


def decompress_int8(comp):
    return jax.tree.map(lambda qs: qs[0].astype(jnp.float32) * qs[1], comp,
                        is_leaf=lambda x: isinstance(x, tuple))


def error_feedback_int8(grads, residual):
    """Quantize (grads + residual); return (dequantized grads, new residual).

    The residual keeps what quantization dropped, so the *accumulated* update
    is unbiased — the standard EF-SGD construction.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _q8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
