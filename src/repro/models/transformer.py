"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

Layers are parameter-stacked and driven by ``lax.scan`` so HLO size and
compile time are O(1) in depth; the scanned block is wrapped in
``jax.checkpoint`` with a configurable policy; ``train_step`` accumulates
gradients over microbatches (scan) to bound live activation memory.

Hybrid (zamba2) layers run as static *segments*: scan over `hybrid_attn_every`
mamba layers, then the shared attention block, repeated — no lax.cond, so HLO
FLOP counts are exact and shared-attn KV caches index statically.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ann, constrain
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update

# --------------------------------------------------------------------------
# Init


def _is_ann(x):
    return (isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "ndim")
            and isinstance(x[1], tuple))


def _stack(trees):
    """Stack per-layer (array, logical-axes) trees along a new leading dim."""
    def one(*xs):
        if _is_ann(xs[0]):
            return (jnp.stack([x[0] for x in xs], axis=0),
                    (None,) + xs[0][1])
        return jnp.stack(xs, axis=0)

    return jax.tree.map(one, *trees, is_leaf=_is_ann)


def _tree_slice(tree, a, b):
    return jax.tree.map(lambda x: x[a:b], tree)


def _init_block(cfg: ModelConfig, key, layer_idx: int):
    ks = jax.random.split(key, 4)
    if cfg.family in ("ssm", "hybrid"):
        return {
            "norm": L.init_rmsnorm(cfg, cfg.d_model),
            "ssm": SSM.init_ssm(cfg, ks[0]),
        }
    blk = {
        "ln1": L.init_rmsnorm(cfg, cfg.d_model),
        "ln2": L.init_rmsnorm(cfg, cfg.d_model),
    }
    if cfg.attn_kind == "mla":
        blk["attn"] = L.init_mla(cfg, ks[0])
    else:
        blk["attn"] = L.init_gqa(cfg, ks[0])
    if cfg.is_moe and layer_idx >= cfg.first_k_dense:
        blk["moe"] = MOE.init_moe(cfg, ks[1])
    else:
        ff = cfg.dense_layer_ff if (cfg.is_moe and cfg.dense_layer_ff) else cfg.d_ff
        blk["mlp"] = L.init_mlp(cfg, ks[1], d_ff=ff)
    return blk


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 4)
    V, D = cfg.vocab_padded, cfg.d_model
    params: Dict[str, Any] = {
        "embed": {"w": ann(
            jax.random.normal(ks[-1], (V, D), jnp.float32).astype(cfg.pdtype()) * 0.02,
            "vocab", None)},
        "final_norm": L.init_rmsnorm(cfg, D),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": ann(
            jax.random.normal(ks[-2], (D, V), jnp.float32).astype(cfg.pdtype()) * 0.02,
            None, "vocab")}

    first = cfg.first_k_dense if cfg.is_moe else 0
    if first:
        params["head_layers"] = [_init_block(cfg, ks[i], i) for i in range(first)]
    params["layers"] = _stack([
        _init_block(cfg, ks[first + i], first + i)
        for i in range(cfg.n_layers - first)])

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = {
            "ln1": L.init_rmsnorm(cfg, D),
            "ln2": L.init_rmsnorm(cfg, D),
            "attn": L.init_gqa(cfg, ks[-3]),
            "mlp": L.init_mlp(cfg, ks[-4]),
        }
    return params


def n_shared_apps(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid" or not cfg.hybrid_attn_every:
        return 0
    return cfg.n_layers // cfg.hybrid_attn_every


def _hybrid_segments(cfg: ModelConfig):
    """[(start, end, apply_shared_after)] covering all stacked layers."""
    every, n = cfg.hybrid_attn_every, cfg.n_layers
    segs = []
    a = 0
    while a < n:
        b = min(a + every, n)
        segs.append((a, b, b - a == every))
        a = b
    return segs


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if cfg.remat_policy == "everything":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


# --------------------------------------------------------------------------
# Blocks (full-sequence path)


def _block_fwd(cfg: ModelConfig, blk, h, pos, mrope_pos, is_moe_layer):
    """One block, full sequence. Returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        h = h + SSM.ssm_forward(blk["ssm"], L.rmsnorm(blk["norm"], h, cfg.rms_eps), cfg)
        return h, aux
    a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
    if cfg.attn_kind == "mla":
        h = h + L.mla_forward(blk["attn"], a, cfg, pos)
    else:
        h = h + L.gqa_forward(blk["attn"], a, cfg, pos, mrope_pos=mrope_pos)
    m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
    if is_moe_layer:
        y, aux = MOE.moe_forward(blk["moe"], m, cfg)
        h = h + y
    else:
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
    return h, aux


def _shared_block_fwd(cfg: ModelConfig, sp, h, pos, *, return_kv=False):
    a = L.rmsnorm(sp["ln1"], h, cfg.rms_eps)
    if return_kv:
        y, kv = L.gqa_forward(sp["attn"], a, cfg, pos, return_kv=True)
    else:
        y = L.gqa_forward(sp["attn"], a, cfg, pos)
    h = h + y
    m = L.rmsnorm(sp["ln2"], h, cfg.rms_eps)
    h = h + L.mlp_forward(sp["mlp"], m, cfg)
    return (h, kv) if return_kv else h


def _logits(params, cfg: ModelConfig, h):
    c = cfg.cdtype()
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"].astype(c))
    return jnp.einsum("bsd,dv->bsv", h, params["unembed"]["w"].astype(c))


def _hc(cfg: ModelConfig, h):
    """Between-layer activation constraint; seq axis shards under §Perf's
    sequence-parallel experiment (cfg.seq_shard)."""
    return constrain(h, "batch", "seq" if cfg.seq_shard else None, None)


def _embed(params, cfg: ModelConfig, tokens, patch_embeds):
    c = cfg.cdtype()
    h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(c)
    if cfg.family == "vlm" and patch_embeds is not None:
        P = patch_embeds.shape[1]
        h = jnp.concatenate([patch_embeds.astype(c), h[:, P:, :]], axis=1)
    return _hc(cfg, h)


def forward(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
            mrope_pos=None):
    """Full forward. tokens [B,S] -> (logits [B,S,Vp] compute dtype, moe aux)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = _embed(params, cfg, tokens, patch_embeds)
    aux_total = jnp.zeros((), jnp.float32)

    for blk in params.get("head_layers", []):
        h, aux = _block_fwd(cfg, blk, h, pos, mrope_pos, is_moe_layer=False)
        aux_total = aux_total + aux

    def body(carry, xs):
        h, aux_acc = carry
        h, aux = _block_fwd(cfg, xs, h, pos, mrope_pos, is_moe_layer=cfg.is_moe)
        h = _hc(cfg, h)
        return (h, aux_acc + aux), None

    body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)

    if cfg.family == "hybrid" and "shared_attn" in params:
        shared_r = jax.checkpoint(
            lambda hh: _shared_block_fwd(cfg, params["shared_attn"], hh, pos),
            policy=_remat_policy(cfg), prevent_cse=False)
        for a, b, app in _hybrid_segments(cfg):
            (h, aux_total), _ = lax.scan(
                body_r, (h, aux_total), _tree_slice(params["layers"], a, b),
                unroll=cfg.scan_unroll)
            if app:
                h = shared_r(h)
                h = _hc(cfg, h)
    else:
        (h, aux_total), _ = lax.scan(body_r, (h, aux_total), params["layers"],
                                     unroll=cfg.scan_unroll)

    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    return _logits(params, cfg, h), aux_total


# --------------------------------------------------------------------------
# Loss / train step


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"],
                          patch_embeds=batch.get("patch_embeds"),
                          mrope_pos=batch.get("mrope_pos"))
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.is_moe:
        loss = loss + cfg.router_aux_coef * aux / max(1, cfg.n_layers)
    return loss


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, n_micro: int,
                    grad_transform=None, loss=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``loss`` defaults to the decoder LM loss; encoder-decoder passes its own.
    """
    loss = loss or loss_fn

    def train_step(state, batch):
        params = state["params"]

        if n_micro == 1:
            loss_, grads = jax.value_and_grad(loss)(params, cfg, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss)(params, cfg, mb)
                gsum = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                                 params)
            mb = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch)
            (grads, loss_), _ = lax.scan(micro, (zeros, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss_ = loss_ / n_micro

        new_params, new_opt, om = adamw_update(
            opt_cfg, params, grads, state["opt"], state["step"],
            grad_transform=grad_transform)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss_, **om}

    return train_step


def init_train_state(cfg: ModelConfig, key, init=None):
    from repro.distributed.sharding import split_annotations
    from repro.optim.adamw import adamw_init
    tree = (init or init_params)(cfg, key)
    params, axes = split_annotations(tree)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    state_axes = {"params": axes, "opt": {"m": axes, "v": axes}, "step": ()}
    return state, state_axes


# --------------------------------------------------------------------------
# KV / state caches


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    cd = jnp.dtype(cfg.cache_dtype)
    Ls = cfg.n_layers
    xbc = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    if cfg.family == "ssm":
        return {
            "ssm": jnp.zeros((Ls, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((Ls, batch, cfg.ssm_conv - 1, xbc), cd),
        }
    if cfg.family == "hybrid":
        napp = n_shared_apps(cfg)
        return {
            "ssm": jnp.zeros((Ls, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((Ls, batch, cfg.ssm_conv - 1, xbc), cd),
            "attn_k": jnp.zeros((napp, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
            "attn_v": jnp.zeros((napp, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
        }
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((Ls, batch, max_seq, cfg.kv_lora_rank), cd),
            "kr": jnp.zeros((Ls, batch, max_seq, cfg.qk_rope_dim), cd),
        }
    return {
        "k": jnp.zeros((Ls, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
        "v": jnp.zeros((Ls, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
    }


def cache_logical_axes(cfg: ModelConfig):
    if cfg.family == "ssm":
        return {"ssm": (None, "batch", "heads", None, None),
                "conv": (None, "batch", None, "ff")}
    if cfg.family == "hybrid":
        return {"ssm": (None, "batch", "heads", None, None),
                "conv": (None, "batch", None, "ff"),
                "attn_k": (None, "batch", "kv_seq", None, None),
                "attn_v": (None, "batch", "kv_seq", None, None)}
    if cfg.attn_kind == "mla":
        return {"ckv": (None, "batch", "kv_seq", None),
                "kr": (None, "batch", "kv_seq", None)}
    return {"k": (None, "batch", "kv_seq", None, None),
            "v": (None, "batch", "kv_seq", None, None)}


# --------------------------------------------------------------------------
# Decode (serve_step) — one token against the cache.


def _block_decode(cfg: ModelConfig, blk, h, sl, cache_len, mrope_pos,
                  is_moe_layer):
    if cfg.family in ("ssm", "hybrid"):
        a = L.rmsnorm(blk["norm"], h, cfg.rms_eps)
        y, (s_new, c_new) = SSM.ssm_decode(blk["ssm"], a, cfg, sl["ssm"], sl["conv"])
        return h + y, {"ssm": s_new, "conv": c_new}
    a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
    if cfg.attn_kind == "mla":
        y, ckv, kr = L.mla_decode(blk["attn"], a, cfg, sl["ckv"], sl["kr"], cache_len)
        new_cache = {"ckv": ckv, "kr": kr}
    else:
        y, k, v = L.gqa_decode(blk["attn"], a, cfg, sl["k"], sl["v"], cache_len,
                               mrope_pos=mrope_pos)
        new_cache = {"k": k, "v": v}
    h = h + y
    m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
    if is_moe_layer:
        y2, _ = MOE.moe_forward(blk["moe"], m, cfg)
        h = h + y2
    else:
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
    return h, new_cache


def serve_step(params, cfg: ModelConfig, cache, token, cache_len, *,
               mrope_pos=None):
    """token [B] int32; cache_len scalar int32 -> (logits [B,Vp] fp32, cache)."""
    c = cfg.cdtype()
    h = jnp.take(params["embed"]["w"], token[:, None], axis=0).astype(c)
    h = constrain(h, "batch", None, None)
    new_cache = dict(cache)

    n_head = len(params.get("head_layers", []))
    if n_head:
        keys = [kk for kk in ("ckv", "kr", "k", "v") if kk in cache]
        for i, blk in enumerate(params["head_layers"]):
            sl = {kk: cache[kk][i] for kk in keys}
            h, nc = _block_decode(cfg, blk, h, sl, cache_len, mrope_pos,
                                  is_moe_layer=False)
            for kk in keys:
                new_cache[kk] = new_cache[kk].at[i].set(nc[kk])

    if cfg.family == "hybrid" and "shared_attn" in params:
        sp = params["shared_attn"]
        ak, av = cache["attn_k"], cache["attn_v"]
        ssm_out, conv_out = [], []
        app_idx = 0

        def body(h, xs):
            blk, s_ssm, s_conv = xs
            h, nc = _block_decode(cfg, blk, h, {"ssm": s_ssm, "conv": s_conv},
                                  cache_len, mrope_pos, is_moe_layer=False)
            return h, (nc["ssm"], nc["conv"])

        for a, b, app in _hybrid_segments(cfg):
            sub = _tree_slice(params["layers"], a, b)
            h, (s_s, c_s) = lax.scan(body, h, (sub, cache["ssm"][a:b],
                                               cache["conv"][a:b]),
                                     unroll=cfg.scan_unroll)
            ssm_out.append(s_s)
            conv_out.append(c_s)
            if app:
                aa = L.rmsnorm(sp["ln1"], h, cfg.rms_eps)
                y, nk, nv = L.gqa_decode(sp["attn"], aa, cfg, ak[app_idx],
                                         av[app_idx], cache_len)
                h = h + y
                m = L.rmsnorm(sp["ln2"], h, cfg.rms_eps)
                h = h + L.mlp_forward(sp["mlp"], m, cfg)
                ak = ak.at[app_idx].set(nk)
                av = av.at[app_idx].set(nv)
                app_idx += 1
        new_cache = {"ssm": jnp.concatenate(ssm_out, axis=0),
                     "conv": jnp.concatenate(conv_out, axis=0),
                     "attn_k": ak, "attn_v": av}
    else:
        keys = [kk for kk in ("ckv", "kr", "k", "v", "ssm", "conv") if kk in cache]

        def body(h, xs):
            blk = xs[0]
            sl = dict(zip(keys, xs[1:]))
            h, nc = _block_decode(cfg, blk, h, sl, cache_len, mrope_pos,
                                  is_moe_layer=cfg.is_moe)
            return h, tuple(nc[kk] for kk in keys)

        stacked = tuple(cache[kk][n_head:] if n_head else cache[kk] for kk in keys)
        h, new_stacked = lax.scan(body, h, (params["layers"],) + stacked,
                                  unroll=cfg.scan_unroll)
        for i, kk in enumerate(keys):
            if n_head:
                new_cache[kk] = lax.dynamic_update_slice_in_dim(
                    new_cache[kk], new_stacked[i], n_head, axis=0)
            else:
                new_cache[kk] = new_stacked[i]

    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    logits = _logits(params, cfg, h)
    return logits[:, 0].astype(jnp.float32), new_cache


# --------------------------------------------------------------------------
# Prefill: full forward that also emits the filled cache.


def prefill(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
            mrope_pos=None):
    """tokens [B,S] -> (next-token logits [B,Vp] fp32, cache filled to S)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = _embed(params, cfg, tokens, patch_embeds)

    head_caches = []
    for blk in params.get("head_layers", []):
        a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
        if cfg.attn_kind == "mla":
            y, kv = L.mla_forward(blk["attn"], a, cfg, pos, return_kv=True)
        else:
            y, kv = L.gqa_forward(blk["attn"], a, cfg, pos,
                                  mrope_pos=mrope_pos, return_kv=True)
        h = h + y
        m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
        head_caches.append(kv)

    def body(h, blk):
        if cfg.family in ("ssm", "hybrid"):
            a = L.rmsnorm(blk["norm"], h, cfg.rms_eps)
            y, (s_state, c_state) = SSM.ssm_forward(blk["ssm"], a, cfg,
                                                    return_state=True)
            h = h + y
            ys = (s_state, c_state)
        else:
            a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
            if cfg.attn_kind == "mla":
                y, kv = L.mla_forward(blk["attn"], a, cfg, pos, return_kv=True)
            else:
                y, kv = L.gqa_forward(blk["attn"], a, cfg, pos,
                                      mrope_pos=mrope_pos, return_kv=True)
            h = h + y
            m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
            if cfg.is_moe:
                y2, _ = MOE.moe_forward(blk["moe"], m, cfg)
                h = h + y2
            else:
                h = h + L.mlp_forward(blk["mlp"], m, cfg)
            ys = kv
        return _hc(cfg, h), ys

    body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)

    if cfg.family == "hybrid" and "shared_attn" in params:
        sp = params["shared_attn"]
        ssm_s, conv_s, shk, shv = [], [], [], []
        for a, b, app in _hybrid_segments(cfg):
            h, (s_s, c_s) = lax.scan(body_r, h, _tree_slice(params["layers"], a, b),
                                     unroll=cfg.scan_unroll)
            ssm_s.append(s_s)
            conv_s.append(c_s)
            if app:
                h, kv = _shared_block_fwd(cfg, sp, h, pos, return_kv=True)
                h = _hc(cfg, h)
                shk.append(kv[0])
                shv.append(kv[1])
        cache = {"ssm": jnp.concatenate(ssm_s, axis=0),
                 "conv": jnp.concatenate(conv_s, axis=0),
                 "attn_k": jnp.stack(shk), "attn_v": jnp.stack(shv)}
    else:
        h, ys = lax.scan(body_r, h, params["layers"], unroll=cfg.scan_unroll)
        if cfg.family == "ssm":
            cache = {"ssm": ys[0], "conv": ys[1]}
        elif cfg.attn_kind == "mla":
            cache = {"ckv": ys[0], "kr": ys[1]}
            if head_caches:
                hc = jnp.stack([kv[0] for kv in head_caches])
                hr = jnp.stack([kv[1] for kv in head_caches])
                cache = {"ckv": jnp.concatenate([hc, cache["ckv"]], axis=0),
                         "kr": jnp.concatenate([hr, cache["kr"]], axis=0)}
        else:
            cache = {"k": ys[0], "v": ys[1]}
            if head_caches:
                hk = jnp.stack([kv[0] for kv in head_caches])
                hv = jnp.stack([kv[1] for kv in head_caches])
                cache = {"k": jnp.concatenate([hk, cache["k"]], axis=0),
                         "v": jnp.concatenate([hv, cache["v"]], axis=0)}

    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    logits = _logits(params, cfg, h[:, -1:, :])
    return logits[:, 0].astype(jnp.float32), cache
