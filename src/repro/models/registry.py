"""Architecture registry: one uniform interface over every model family.

``get_model_fns(cfg)`` returns the family's functions with uniform
signatures; ``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins
for every input of the step that the shape exercises (train_step for
``train_*``, prefill for ``prefill_*``, serve_step for ``decode_*`` /
``long_*``) — weak-type-correct, shardable, no device allocation.
``abstract_train_state`` / ``abstract_cache`` build the matching abstract
state trees plus their logical-axes trees for NamedSharding construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ShapeSpec
from repro.models import encdec as ENCDEC
from repro.models import transformer as TFM
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init_params: Callable
    loss_fn: Callable
    prefill: Callable
    serve_step: Callable
    init_cache: Callable
    cache_logical_axes: Callable
    forward: Optional[Callable] = None

    def make_train_step(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                        n_micro: int, grad_transform=None):
        return TFM.make_train_step(cfg, opt_cfg, n_micro,
                                   grad_transform=grad_transform,
                                   loss=self.loss_fn)

    def init_train_state(self, cfg: ModelConfig, key):
        return TFM.init_train_state(cfg, key, init=self.init_params)


def get_model_fns(cfg: ModelConfig) -> ModelFns:
    if cfg.family == "encdec":
        return ModelFns(
            init_params=ENCDEC.init_params,
            loss_fn=ENCDEC.loss_fn,
            prefill=ENCDEC.prefill,
            serve_step=ENCDEC.serve_step,
            init_cache=ENCDEC.init_cache,
            cache_logical_axes=ENCDEC.cache_logical_axes,
            forward=ENCDEC.forward,
        )
    return ModelFns(
        init_params=TFM.init_params,
        loss_fn=TFM.loss_fn,
        prefill=TFM.prefill,
        serve_step=TFM.serve_step,
        init_cache=TFM.init_cache,
        cache_logical_axes=TFM.cache_logical_axes,
        forward=TFM.forward,
    )


def build_model(cfg: ModelConfig) -> ModelFns:  # back-compat alias
    return get_model_fns(cfg)


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct) per shape.


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Inputs of loss/train for ``train_*`` or of prefill for ``prefill_*``."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["targets"] = _sds((B, S), jnp.int32)
    if cfg.family == "encdec":
        specs["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), cfg.cdtype())
    if cfg.family == "vlm":
        specs["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                     cfg.cdtype())
        specs["mrope_pos"] = _sds((B, S, 3), jnp.int32)
    return specs


def batch_logical_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    axes: Dict[str, Any] = {"tokens": ("batch", None)}
    if shape.kind == "train":
        axes["targets"] = ("batch", None)
    if cfg.family == "encdec":
        axes["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        axes["patch_embeds"] = ("batch", None, None)
        axes["mrope_pos"] = ("batch", None, None)
    return axes


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(cache_sds, token_sds, cache_len_sds [, mrope]) for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    fns = get_model_fns(cfg)
    cache = jax.eval_shape(lambda: fns.init_cache(cfg, B, S))
    out = {"cache": cache, "token": _sds((B,), jnp.int32),
           "cache_len": _sds((), jnp.int32)}
    if cfg.family == "vlm":
        out["mrope_pos"] = _sds((B, 1, 3), jnp.int32)
    return out


def decode_logical_axes(cfg: ModelConfig):
    fns = get_model_fns(cfg)
    out = {"cache": fns.cache_logical_axes(cfg), "token": ("batch",),
           "cache_len": ()}
    if cfg.family == "vlm":
        out["mrope_pos"] = ("batch", None, None)
    return out


def abstract_train_state(cfg: ModelConfig, seed: int = 0):
    """(state ShapeDtypeStruct tree, logical-axes tree) — no allocation."""
    fns = get_model_fns(cfg)
    captured: Dict[str, Any] = {}

    def init(key):
        state, axes = fns.init_train_state(cfg, key)
        captured["axes"] = axes
        return state

    state_sds = jax.eval_shape(init, jax.random.key(seed))
    return state_sds, captured["axes"]


def synth_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Concrete synthetic batch matching batch_specs (for smoke/train runs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    B = batch_override or shape.global_batch
    S = shape.seq_len
    V = cfg.vocab_size
    toks = rng.integers(0, V, size=(B, S), dtype=np.int32)
    batch: Dict[str, Any] = {"tokens": toks}
    if shape.kind == "train":
        batch["targets"] = np.concatenate(
            [toks[:, 1:], np.full((B, 1), -1, np.int32)], axis=1)
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.enc_frames, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32)
        pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                              (B, S, 3))
        batch["mrope_pos"] = np.ascontiguousarray(pos)
    return batch
