"""Mamba2 (state-space duality) block: chunked train/prefill + O(1) decode.

Chunked SSD: within a chunk of length Q the output is a masked quadratic
("attention-like") term; across chunks a recurrent state [B,H,P,N] is carried
by a lax.scan.  Decode is a single recurrent state update.  Group count = 1.

Projections are split (z / xBC / dt) instead of one fused in_proj so each
output axis shards cleanly on the mesh `model` axis (see DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ann
from repro.models.common import ModelConfig
from repro.models.layers import _init

NEG = -1e30


def init_ssm(cfg: ModelConfig, key):
    D = cfg.d_model
    di = cfg.d_inner
    N, H, W = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    gN = cfg.ssm_groups * N
    xbc = di + 2 * gN
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(D)
    a_init = jnp.log(jnp.linspace(1.0, 16.0, H))
    p = {
        "w_z": ann(_init(ks[0], (D, di), s, cfg.pdtype()), None, "ff"),
        "w_dt": ann(_init(ks[2], (D, H), s, cfg.pdtype()), None, "heads"),
        "A_log": ann(a_init.astype(cfg.pdtype()), "heads"),
        "D": ann(jnp.ones((H,), cfg.pdtype()), "heads"),
        "dt_bias": ann(jnp.full((H,), -4.6, cfg.pdtype()), "heads"),
        "norm_w": ann(jnp.ones((di,), cfg.pdtype()), "ff"),
        "w_out": ann(_init(ks[4], (di, D), 1.0 / math.sqrt(di), cfg.pdtype()),
                     "ff", None),
    }
    if cfg.ssm_split_proj:
        # TP-clean split projections: x shards on 'ff'; the small per-group
        # B/C tensors stay replicated (no mid-channel slicing of a sharded
        # axis -> no per-layer resharding; §Perf cell 2)
        p.update({
            "w_x": ann(_init(ks[1], (D, di), s, cfg.pdtype()), None, "ff"),
            "w_B": ann(_init(ks[5], (D, gN), s, cfg.pdtype()), None, None),
            "w_C": ann(_init(ks[6], (D, gN), s, cfg.pdtype()), None, None),
            "conv_w_x": ann(_init(ks[3], (W, di), 0.5, cfg.pdtype()), None, "ff"),
            "conv_b_x": ann(jnp.zeros((di,), cfg.pdtype()), "ff"),
            "conv_w_bc": ann(_init(ks[7], (W, 2 * gN), 0.5, cfg.pdtype()),
                             None, None),
            "conv_b_bc": ann(jnp.zeros((2 * gN,), cfg.pdtype()), None),
        })
    else:
        p.update({
            "w_xbc": ann(_init(ks[1], (D, xbc), s, cfg.pdtype()), None, "ff"),
            "conv_w": ann(_init(ks[3], (W, xbc), 0.5, cfg.pdtype()), None, "ff"),
            "conv_b": ann(jnp.zeros((xbc,), cfg.pdtype()), "ff"),
        })
    return p


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv. xbc [B,S,C]; w [W,C]; returns (y, new_state)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)          # [B, S+W-1, C]
    y = sum(full[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
            for i in range(W))
    new_state = full[:, -(W - 1):, :]
    return jax.nn.silu(y + b[None, None, :]), new_state


def _split_xbc(xbc, cfg: ModelConfig):
    di, N = cfg.d_inner, cfg.ssm_state
    x = xbc[..., :di]
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:di + 2 * N]
    B, S = x.shape[:2]
    x = x.reshape(B, S, cfg.ssm_heads, cfg.ssm_head_dim)
    return x, Bm, Cm


def _gated_norm(y, z, w, eps):
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(y.dtype)


def _project_xbc(p, h, cfg: ModelConfig, c, conv_state=None):
    """-> (x [B,S,H,P], Bm, Cm [B,S,gN], new_conv_state [B,W-1,xbc]).

    Split path: three clean projections + per-part depthwise convs (weights
    partitioned exactly like the fused conv, so the math is identical).
    Fused path (legacy baseline): one projection, conv, then channel slices.
    """
    if "w_x" in p:
        gN = cfg.ssm_groups * cfg.ssm_state
        x = jnp.einsum("bsd,de->bse", h, p["w_x"].astype(c))
        bc = jnp.concatenate(
            [jnp.einsum("bsd,de->bse", h, p["w_B"].astype(c)),
             jnp.einsum("bsd,de->bse", h, p["w_C"].astype(c))], axis=-1)
        st_x = st_bc = None
        if conv_state is not None:
            st_x = conv_state[..., : cfg.d_inner]
            st_bc = conv_state[..., cfg.d_inner:]
        x, st_x = _causal_conv(x, p["conv_w_x"].astype(c),
                               p["conv_b_x"].astype(c), st_x)
        bc, st_bc = _causal_conv(bc, p["conv_w_bc"].astype(c),
                                 p["conv_b_bc"].astype(c), st_bc)
        B, S = x.shape[:2]
        x = x.reshape(B, S, cfg.ssm_heads, cfg.ssm_head_dim)
        new_state = jnp.concatenate([st_x, st_bc], axis=-1)
        return x, bc[..., :gN], bc[..., gN:], new_state
    xbc = jnp.einsum("bsd,de->bse", h, p["w_xbc"].astype(c))
    xbc, new_state = _causal_conv(xbc, p["conv_w"].astype(c),
                                  p["conv_b"].astype(c), conv_state)
    x, Bm, Cm = _split_xbc(xbc, cfg)
    return x, Bm, Cm, new_state


def ssm_forward(p, h, cfg: ModelConfig, *, initial_state=None, return_state=False):
    """h [B,S,D] -> y [B,S,D] (+ (ssm_state, conv_state) if return_state).

    All FLOP-heavy SSD terms (intra-chunk quadratic, chunk-state outer
    products, inter-chunk readout) are *batched over chunks* — big MXU-shaped
    einsums, and exact under XLA cost accounting.  Only the O(B*H*P*N)
    elementwise state recurrence is sequential (lax.scan over chunks).
    """
    c = cfg.cdtype()
    B, S, D = h.shape
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = jnp.einsum("bsd,de->bse", h, p["w_z"].astype(c))
    x, Bm, Cm, conv_state = _project_xbc(p, h, cfg, c)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(c)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                        # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # [H] < 0
    a = dt * A                                                     # [B,S,H] <= 0

    # chunk views: [B, nC, Q, ...]
    def chunk(t):
        return t.reshape(B, nC, Q, *t.shape[2:])

    xc = chunk(x).astype(jnp.float32)          # [B,C,Q,H,P]
    Bc = chunk(Bm).astype(jnp.float32)         # [B,C,Q,N]
    Cc = chunk(Cm).astype(jnp.float32)         # [B,C,Q,N]
    ac = chunk(a)                              # [B,C,Q,H]
    dtc = chunk(dt)                            # [B,C,Q,H]

    if initial_state is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)
    else:
        state0 = initial_state.astype(jnp.float32)

    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]

    cum = jnp.cumsum(ac, axis=2)               # [B,C,Q,H]
    ci = cum.transpose(0, 1, 3, 2)             # [B,C,H,Q]
    # intra-chunk quadratic term, batched over all chunks
    dec = jnp.exp(jnp.where(causal[None, None, None],
                            ci[..., :, None] - ci[..., None, :], NEG))
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    y_intra = jnp.einsum("bchqk,bcqk,bckh,bckhp->bcqhp", dec, cb, dtc, xc)
    # per-chunk input states + decays, batched
    decay_to_end = jnp.exp(ci[..., -1:].transpose(0, 1, 3, 2) - cum)  # [B,C,Q,H]
    s_chunk = jnp.einsum("bckh,bckn,bckhp->bchpn",
                         dtc * decay_to_end, Bc, xc)                  # [B,C,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # [B,C,H]

    # sequential part: state_in[c+1] = chunk_decay[c] * state_in[c] + s_chunk[c]
    def step(state, xs):
        dcy, s_new = xs                         # [B,H], [B,H,P,N]
        nxt = dcy[:, :, None, None] * state + s_new
        return nxt, state                       # emit the INCOMING state

    state, states_in = lax.scan(
        step, state0, (chunk_decay.swapaxes(0, 1), s_chunk.swapaxes(0, 1)))
    states_in = states_in.swapaxes(0, 1)        # [B,C,H,P,N]

    # inter-chunk readout, batched over chunks
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         Cc, states_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.astype(c).reshape(B, S, H * P)
    y = _gated_norm(y, z, p["norm_w"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(c))
    if return_state:
        return out, (state.astype(jnp.float32), conv_state.astype(c))
    return out


def ssm_decode(p, h, cfg: ModelConfig, ssm_state, conv_state):
    """One-token recurrent step. h [B,1,D]; ssm_state [B,H,P,N] fp32;
    conv_state [B,W-1,C]."""
    c = cfg.cdtype()
    B = h.shape[0]
    z = jnp.einsum("bsd,de->bse", h, p["w_z"].astype(c))
    x, Bm, Cm, conv_state = _project_xbc(p, h, cfg, c, conv_state)  # [B,1,...]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(c)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))[:, 0]          # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                # [B,H]
    xs = x[:, 0].astype(jnp.float32)                       # [B,H,P]
    Bs = Bm[:, 0].astype(jnp.float32)                      # [B,N]
    Cs = Cm[:, 0].astype(jnp.float32)
    new_state = (decay[:, :, None, None] * ssm_state
                 + jnp.einsum("bh,bn,bhp->bhpn", dt, Bs, xs))
    y = jnp.einsum("bn,bhpn->bhp", Cs, new_state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.astype(c).reshape(B, 1, -1)
    y = _gated_norm(y, z, p["norm_w"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(c))
    return out, (new_state, conv_state.astype(c))
