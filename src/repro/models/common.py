"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    qk_norm: bool = False
    mlp_gated: bool = True           # SwiGLU vs plain GELU MLP
    rope_theta: float = 1e4
    attn_kind: str = "gqa"           # gqa | mla
    tie_embeddings: bool = False
    rms_eps: float = 1e-6

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    mla_absorb: bool = False         # absorbed decode matmuls (perf iteration)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0
    first_k_dense: int = 0           # leading dense layers (deepseek: 1)
    dense_layer_ff: int = 0          # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # expert-parallel dispatch (shard_map + all_to_all) instead of the pjit
    # global-scatter dispatch.  The global scatter forces SPMD to all-reduce
    # the full [E*C, D] fp32 expert buffer every MoE layer (§Perf cell 3);
    # EP moves only the routed tokens (all-to-all), the standard MoE pattern.
    moe_ep: bool = False

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_groups: int = 1
    # split x/B/C projections (TP-clean: slicing the fused xBC output at
    # non-shard-aligned channel boundaries forces per-layer resharding —
    # §Perf cell 2).  False = legacy fused in_proj (the recorded baseline).
    ssm_split_proj: bool = True

    # hybrid (zamba2): a shared attention+MLP block applied every k-th layer
    hybrid_attn_every: int = 0

    # encoder-decoder (whisper): frontend is a stub; encoder sees frame embeds
    enc_layers: int = 0
    enc_frames: int = 1500

    # VLM (qwen2-vl): M-RoPE + stubbed patch embeddings
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    n_patches: int = 256

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"

    # training
    remat_policy: str = "nothing"    # nothing | dots | everything
    microbatch_tokens: int = 8192    # target per-device tokens per microbatch
    max_microbatches: int = 16

    # lowering mode (dry-run roofline pass flips these; see DESIGN.md §7):
    # scan bodies are counted ONCE by XLA cost_analysis, so the roofline pass
    # unrolls the layer scan and disables attention q-chunking to make the
    # compiled FLOP/collective counts exact; the memory pass keeps production
    # scan + microbatching so memory_analysis proves the step fits.
    unroll_layers: bool = False
    q_chunk: int = 4096

    # perf experiment (§Perf): shard the sequence axis of between-layer
    # activations over the mesh 'model' axis (Megatron-style sequence
    # parallelism) instead of replicating them across it.
    seq_shard: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def head_dim(self) -> int:
        return self.d_head

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for archs with sub-quadratic decode state."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def scan_unroll(self):
        """unroll= for layer-stack scans (True = exact HLO flop counts)."""
        return True if self.unroll_layers else 1

    # ---------------- parameter count (for 6ND roofline bookkeeping) --------
    def param_count(self) -> int:
        tree = None
        # analytic count, no allocation
        D, H, KV, dh, F, V = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.d_head, self.d_ff, self.vocab_padded)
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D

        def attn_params():
            if self.attn_kind == "mla":
                r, rd, nd, vd = (self.kv_lora_rank, self.qk_rope_dim,
                                 self.qk_nope_dim, self.v_head_dim)
                return (D * H * (nd + rd) + D * (r + rd)
                        + r * H * (nd + vd) + H * vd * D)
            return D * H * dh + 2 * D * KV * dh + H * dh * D

        def mlp_params(ff):
            return (3 if self.mlp_gated else 2) * D * ff

        def moe_params():
            n = D * self.n_experts
            n += self.n_experts * mlp_params(self.d_expert) // 1
            n += self.n_shared_experts * mlp_params(self.d_expert)
            return n

        def ssm_params():
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            xbc = di + 2 * self.ssm_groups * N
            return (D * di + D * xbc + D * Hs + self.ssm_conv * xbc
                    + 3 * Hs + di + di * D)

        for li in range(self.n_layers):
            if self.family == "ssm":
                n += ssm_params() + D
            elif self.family == "hybrid":
                n += ssm_params() + D
            elif self.family in ("dense", "vlm", "encdec"):
                n += attn_params() + mlp_params(F) + 2 * D
            elif self.family == "moe":
                if li < self.first_k_dense:
                    n += attn_params() + mlp_params(self.dense_layer_ff) + 2 * D
                else:
                    n += attn_params() + moe_params() + 2 * D
        if self.family == "hybrid" and self.hybrid_attn_every:
            n += attn_params() + mlp_params(F) + 2 * D  # one shared block
        if self.family == "encdec":
            for _ in range(self.enc_layers):
                n += attn_params() + mlp_params(F) + 2 * D
            n += self.n_layers * (attn_params() + D)  # cross-attn
        del tree
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        D = self.d_model
        per_expert = (3 if self.mlp_gated else 2) * D * self.d_expert
        inactive = (self.n_experts - self.top_k) * per_expert
        return full - (self.n_layers - self.first_k_dense) * inactive


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D with N = active params, D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def num_microbatches(cfg: ModelConfig, shape: ShapeSpec, n_data_shards: int) -> int:
    if shape.kind != "train":
        return 1
    per_dev_batch = max(1, shape.global_batch // max(1, n_data_shards))
    per_dev_tokens = per_dev_batch * shape.seq_len
    n = max(1, per_dev_tokens // cfg.microbatch_tokens)
    n = min(n, cfg.max_microbatches, per_dev_batch)
    while shape.global_batch % n or (shape.global_batch // n) % 1:
        n -= 1
    return max(1, n)
