"""Transformer building blocks: norms, RoPE/M-RoPE, GQA/MLA attention, MLPs.

All functions are pure; params are nested dicts whose leaves were created with
``sharding.ann`` (array + logical axes).  Compute runs in ``cfg.compute_dtype``;
normalizations and softmax accumulate in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ann, constrain
from repro.models.common import ModelConfig

NEG_INF = -1e30


def _init(key, shape, scale, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype) * scale


# --------------------------------------------------------------------------
# Norms


def init_rmsnorm(cfg: ModelConfig, d: int):
    return {"w": ann(jnp.ones((d,), cfg.pdtype()), None)}


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(w, x, eps):
    """Per-head RMSNorm (qwen3 qk_norm): x [..., dh], w [dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE


def rope_cos_sin(pos, dim, theta, dtype):
    """pos [..., ] int -> cos/sin [..., dim//2]."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def mrope_cos_sin(pos3, dim, theta, sections, dtype):
    """M-RoPE (qwen2-vl): pos3 [..., 3] -> cos/sin [..., dim//2].

    Frequency slots are partitioned into (temporal, height, width) sections;
    slot i draws its position from the section it belongs to.
    """
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    pos_per_slot = jnp.take_along_axis(
        pos3.astype(jnp.float32),
        jnp.broadcast_to(sec_id, pos3.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )
    ang = pos_per_slot * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x [B,S,H,dh]; cos/sin [B,S,dh//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# GQA attention


def init_gqa(cfg: ModelConfig, key):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "wq": ann(_init(ks[0], (D, H, dh), s, cfg.pdtype()), None, "heads", None),
        "wk": ann(_init(ks[1], (D, KV, dh), s, cfg.pdtype()), None, "heads", None),
        "wv": ann(_init(ks[2], (D, KV, dh), s, cfg.pdtype()), None, "heads", None),
        "wo": ann(_init(ks[3], (H, dh, D), 1.0 / math.sqrt(H * dh), cfg.pdtype()),
                  "heads", None, None),
    }
    if cfg.qk_norm:
        p["q_norm"] = ann(jnp.ones((dh,), cfg.pdtype()), None)
        p["k_norm"] = ann(jnp.ones((dh,), cfg.pdtype()), None)
    return p


def _qkv(p, h, cfg: ModelConfig, rope):
    c = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(c))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(c))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(c))
    if cfg.qk_norm:
        q = head_rmsnorm(p["q_norm"], q, cfg.rms_eps)
        k = head_rmsnorm(p["k_norm"], k, cfg.rms_eps)
    if rope is not None:  # whisper: absolute sinusoidal positions, no RoPE
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q [B,Sq,H,dh], k/v [B,Sk,KV,dh], mask broadcastable to [B,1,1,Sq,Sk]."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / math.sqrt(dh)
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, dh)


def gqa_forward(p, h, cfg: ModelConfig, pos, *, causal=True, mrope_pos=None,
                q_chunk: int = 0, return_kv=False):
    """Full-sequence attention (train / prefill). h [B,S,D], pos [B,S]."""
    c = cfg.cdtype()
    B, S, _ = h.shape
    q_chunk = q_chunk or cfg.q_chunk
    if cfg.rope_theta == 0:
        rope = None
    elif cfg.mrope and mrope_pos is not None:
        rope = mrope_cos_sin(mrope_pos, cfg.d_head, cfg.rope_theta,
                             cfg.mrope_sections, c)
    else:
        rope = rope_cos_sin(pos, cfg.d_head, cfg.rope_theta, c)
    q, k, v = _qkv(p, h, cfg, rope)

    if S <= q_chunk:
        if causal:
            mask = (pos[:, None, None, :, None] >= pos[:, None, None, None, :])
        else:
            mask = jnp.ones((1, 1, 1, S, S), dtype=bool)
        out = _sdpa(q, k, v, mask, cfg)
    else:
        # Chunked ("flash-style") query scan: bounds the score matrix to
        # [B, H, q_chunk, S] per step.  Backward recomputes per chunk under
        # the block remat policy.
        n = S // q_chunk
        assert S % q_chunk == 0, (S, q_chunk)
        qc = q.reshape(B, n, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = pos.reshape(B, n, q_chunk).transpose(1, 0, 2)

        def step(_, xs):
            qi, pi = xs
            if causal:
                m = (pi[:, None, None, :, None] >= pos[:, None, None, None, :])
            else:
                m = jnp.ones((1, 1, 1, q_chunk, S), dtype=bool)
            return None, _sdpa(qi, k, v, m, cfg)

        _, oc = lax.scan(step, None, (qc, pc))
        out = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.n_heads, cfg.d_head)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    if return_kv:
        return y, (k.astype(jnp.dtype(cfg.cache_dtype)),
                   v.astype(jnp.dtype(cfg.cache_dtype)))
    return y


def gqa_decode(p, h, cfg: ModelConfig, cache_k, cache_v, cache_len, *,
               mrope_pos=None):
    """One-token decode. h [B,1,D]; cache_[kv] [B,Smax,KV,dh]; cache_len scalar."""
    c = cfg.cdtype()
    B = h.shape[0]
    pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    if cfg.rope_theta == 0:
        rope = None
    elif cfg.mrope and mrope_pos is not None:
        rope = mrope_cos_sin(mrope_pos, cfg.d_head, cfg.rope_theta,
                             cfg.mrope_sections, c)
    else:
        rope = rope_cos_sin(pos, cfg.d_head, cfg.rope_theta, c)
    q, k, v = _qkv(p, h, cfg, rope)
    cd = jnp.dtype(cfg.cache_dtype)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cd), cache_len, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cd), cache_len, axis=1)
    cache_k = constrain(cache_k, "batch", "kv_seq", None, None)
    cache_v = constrain(cache_v, "batch", "kv_seq", None, None)
    Smax = cache_k.shape[1]
    valid = (jnp.arange(Smax) <= cache_len)[None, None, None, None, :]
    out = _sdpa(q, cache_k.astype(c), cache_v.astype(c), valid, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    return y, cache_k, cache_v


def cross_attn_forward(p, h, cfg: ModelConfig, enc_k, enc_v):
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    c = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(c))
    Sk = enc_k.shape[1]
    mask = jnp.ones((1, 1, 1, h.shape[1], Sk), dtype=bool)
    out = _sdpa(q, enc_k.astype(c), enc_v.astype(c), mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))


def encode_kv(p, enc_h, cfg: ModelConfig):
    c = cfg.cdtype()
    k = jnp.einsum("bsd,dhk->bshk", enc_h, p["wk"].astype(c))
    v = jnp.einsum("bsd,dhk->bshk", enc_h, p["wv"].astype(c))
    return k, v


# --------------------------------------------------------------------------
# MLA attention (deepseek-v2): the compressed latent IS the KV cache.


def init_mla(cfg: ModelConfig, key):
    D, H = cfg.d_model, cfg.n_heads
    r, rd, nd, vd = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(D)
    return {
        "wq": ann(_init(ks[0], (D, H, nd + rd), s, cfg.pdtype()), None, "heads", None),
        "w_dkv": ann(_init(ks[1], (D, r), s, cfg.pdtype()), None, None),
        "w_kr": ann(_init(ks[2], (D, rd), s, cfg.pdtype()), None, None),
        "kv_norm": ann(jnp.ones((r,), cfg.pdtype()), None),
        "w_uk": ann(_init(ks[3], (r, H, nd), 1.0 / math.sqrt(r), cfg.pdtype()),
                    None, "heads", None),
        "w_uv": ann(_init(ks[4], (r, H, vd), 1.0 / math.sqrt(r), cfg.pdtype()),
                    None, "heads", None),
        "wo": ann(_init(ks[5], (H, vd, D), 1.0 / math.sqrt(H * vd), cfg.pdtype()),
                  "heads", None, None),
    }


def _mla_latent(p, h, cfg: ModelConfig, pos):
    c = cfg.cdtype()
    c_kv = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"].astype(c))
    c_kv = rmsnorm({"w": p["kv_norm"]}, c_kv, cfg.rms_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", h, p["w_kr"].astype(c))
    cos, sin = rope_cos_sin(pos, cfg.qk_rope_dim, cfg.rope_theta, c)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def _mla_q(p, h, cfg: ModelConfig, pos):
    c = cfg.cdtype()
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(c))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_cos_sin(pos, rd, cfg.rope_theta, c)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_forward(p, h, cfg: ModelConfig, pos, *, q_chunk: int = 0,
                return_kv=False):
    """Full-sequence MLA (naive / paper-formula path)."""
    c = cfg.cdtype()
    B, S, _ = h.shape
    q_chunk = q_chunk or cfg.q_chunk
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    c_kv, k_rope = _mla_latent(p, h, cfg, pos)
    q_nope, q_rope = _mla_q(p, h, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(c))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(c))

    # scores: per-head nope part + shared rope key
    def scores_fn(qn, qr, qpos):
        sc = jnp.einsum("bqhk,bshk->bhqs", qn, k_nope)
        sc = sc + jnp.einsum("bqhk,bsk->bhqs", qr, k_rope)
        sc = sc * scale
        mask = (qpos[:, None, :, None] >= pos[:, None, None, :])
        sc = jnp.where(mask, sc.astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(sc, axis=-1).astype(c)
        return jnp.einsum("bhqs,bshk->bqhk", w, v)

    if S <= q_chunk:
        out = scores_fn(q_nope, q_rope, pos)
    else:
        n = S // q_chunk
        qn = q_nope.reshape(B, n, q_chunk, *q_nope.shape[2:]).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, n, q_chunk, *q_rope.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = pos.reshape(B, n, q_chunk).transpose(1, 0, 2)
        _, oc = lax.scan(lambda _, xs: (None, scores_fn(*xs)), None, (qn, qr, pc))
        out = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.n_heads, cfg.v_head_dim)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    if return_kv:
        cd = jnp.dtype(cfg.cache_dtype)
        return y, (c_kv.astype(cd), k_rope.astype(cd))
    return y


def mla_decode(p, h, cfg: ModelConfig, cache_ckv, cache_kr, cache_len):
    """One-token MLA decode.

    cfg.mla_absorb=False: naive path — re-expand k_nope/v from the latent cache
    (faithful to the published formulas; memory-heavy).
    cfg.mla_absorb=True: absorbed path — fold w_uk into the query and w_uv into
    the output so attention runs directly in the latent space (perf iteration).
    """
    c = cfg.cdtype()
    B = h.shape[0]
    pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    c_kv_new, k_rope_new = _mla_latent(p, h, cfg, pos)
    q_nope, q_rope = _mla_q(p, h, cfg, pos)

    cd = jnp.dtype(cfg.cache_dtype)
    cache_ckv = lax.dynamic_update_slice_in_dim(cache_ckv, c_kv_new.astype(cd),
                                                cache_len, axis=1)
    cache_kr = lax.dynamic_update_slice_in_dim(cache_kr, k_rope_new.astype(cd),
                                               cache_len, axis=1)
    cache_ckv = constrain(cache_ckv, "batch", "kv_seq", None)
    cache_kr = constrain(cache_kr, "batch", "kv_seq", None)
    Smax = cache_ckv.shape[1]
    valid = (jnp.arange(Smax) <= cache_len)[None, None, None, :]
    ckv = cache_ckv.astype(c)
    kr = cache_kr.astype(c)

    if cfg.mla_absorb:
        qa = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"].astype(c))
        sc = jnp.einsum("bqhr,bsr->bhqs", qa, ckv)
        sc = sc + jnp.einsum("bqhk,bsk->bhqs", q_rope, kr)
        sc = jnp.where(valid, sc.astype(jnp.float32) * scale, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1).astype(c)
        ol = jnp.einsum("bhqs,bsr->bqhr", w, ckv)
        out = jnp.einsum("bqhr,rhk->bqhk", ol, p["w_uv"].astype(c))
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(c))
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(c))
        sc = jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
        sc = sc + jnp.einsum("bqhk,bsk->bhqs", q_rope, kr)
        sc = jnp.where(valid, sc.astype(jnp.float32) * scale, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1).astype(c)
        out = jnp.einsum("bhqs,bshk->bqhk", w, v)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    return y, cache_ckv, cache_kr


# --------------------------------------------------------------------------
# MLPs


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    if cfg.mlp_gated:
        return {
            "w1": ann(_init(ks[0], (D, F), s_in, cfg.pdtype()), None, "ff"),
            "w3": ann(_init(ks[1], (D, F), s_in, cfg.pdtype()), None, "ff"),
            "w2": ann(_init(ks[2], (F, D), s_out, cfg.pdtype()), "ff", None),
        }
    return {
        "w_in": ann(_init(ks[0], (D, F), s_in, cfg.pdtype()), None, "ff"),
        "w_out": ann(_init(ks[1], (F, D), s_out, cfg.pdtype()), "ff", None),
    }


def mlp_forward(p, x, cfg: ModelConfig):
    c = cfg.cdtype()
    if "w1" in p:
        g = jnp.einsum("...d,df->...f", x, p["w1"].astype(c))
        u = jnp.einsum("...d,df->...f", x, p["w3"].astype(c))
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, p["w2"].astype(c))
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_in"].astype(c)))
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(c))
