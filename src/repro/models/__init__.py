from repro.models.common import ModelConfig, SHAPES, ShapeSpec, model_flops  # noqa: F401
from repro.models.registry import (ModelFns, abstract_train_state,  # noqa: F401
                                   batch_logical_axes, batch_specs,
                                   build_model, decode_logical_axes,
                                   decode_specs, get_model_fns, synth_batch)
