"""Mixture-of-Experts: top-k router + capacity-buffer dispatch.

The dispatch path is the GShard-style capacity formulation: tokens are
scattered into a per-expert buffer ``[E, C, D]`` (positions assigned by a
running count per expert), experts run as a single batched einsum with the
expert axis sharded on the mesh ``model`` axis (expert parallelism), and
outputs are gathered back with the router weights.  Tokens beyond capacity are
dropped (contribute zero), standard for capacity-based MoE.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ann, constrain
from repro.models.common import ModelConfig
from repro.models.layers import _init, mlp_forward, init_mlp


def init_moe(cfg: ModelConfig, key):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    p = {
        "router": ann(_init(ks[0], (D, E), s_in, cfg.pdtype()), None, None),
        "w1": ann(_init(ks[1], (E, D, F), s_in, cfg.pdtype()), "expert", None, None),
        "w3": ann(_init(ks[2], (E, D, F), s_in, cfg.pdtype()), "expert", None, None),
        "w2": ann(_init(ks[3], (E, F, D), s_out, cfg.pdtype()), "expert", None, None),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=cfg.n_shared_experts * cfg.d_expert)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts))
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(p, x, cfg: ModelConfig):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    if cfg.moe_ep:
        from repro.distributed.sharding import _mesh
        mesh = _mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and mesh.shape["model"] > 1 \
                and cfg.n_experts % mesh.shape["model"] == 0:
            return moe_forward_ep(p, x, cfg, mesh)
    return _moe_forward_pjit(p, x, cfg)


def _moe_forward_pjit(p, x, cfg: ModelConfig):
    """Baseline pjit global-scatter dispatch (recorded §Perf baseline)."""
    c = cfg.cdtype()
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(c)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = lax.top_k(probs, K)                       # [T, K]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch/GShard form).
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_e, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    C = capacity(cfg, T)
    ef = gate_e.reshape(-1)                                    # [T*K]
    wf = gate_w.reshape(-1).astype(c)
    onehot = jax.nn.one_hot(ef, E, dtype=jnp.int32)            # [T*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot             # position before me
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                  # [T*K]
    keep = pos < C
    tok = jnp.repeat(jnp.arange(T), K)

    flat_idx = jnp.where(keep, ef * C + jnp.minimum(pos, C - 1), E * C)  # drop slot
    buf = jnp.zeros((E * C + 1, D), dtype=c)
    buf = buf.at[flat_idx].add(xt[tok].astype(c))
    buf = buf[:-1].reshape(E, C, D)
    buf = constrain(buf, "expert", None, None)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(c))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(c))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(c))
    out = constrain(out, "expert", None, None)

    out_flat = out.reshape(E * C, D)
    picked = jnp.where(keep[:, None],
                       out_flat[jnp.minimum(flat_idx, E * C - 1)], 0.0)
    y = jnp.sum((picked * wf[:, None]).reshape(T, K, D), axis=1)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], xt, cfg)
    return y.reshape(B, S, D), aux.astype(jnp.float32)


def _dispatch_local(xt, gate_e, gate_w, E, C, D, c):
    """Scatter local tokens into a local [E, C, D] buffer (no comm).
    -> (buf, flat_idx, keep, wf, tok) for the matching combine."""
    T = xt.shape[0]
    K = gate_e.shape[1]
    ef = gate_e.reshape(-1)
    wf = gate_w.reshape(-1).astype(c)
    onehot = jax.nn.one_hot(ef, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = pos < C
    tok = jnp.repeat(jnp.arange(T), K)
    flat_idx = jnp.where(keep, ef * C + jnp.minimum(pos, C - 1), E * C)
    buf = jnp.zeros((E * C + 1, D), dtype=c)
    buf = buf.at[flat_idx].add(xt[tok].astype(c))
    return buf[:-1].reshape(E, C, D), flat_idx, keep, wf


def moe_forward_ep(p, x, cfg: ModelConfig, mesh):
    """Expert-parallel MoE: shard_map over (batch x experts).

    Per (data, model) shard: route the LOCAL tokens, build a LOCAL capacity
    buffer over all E experts, then all_to_all over the 'model' axis so each
    shard receives, for its OWN E/MP experts, the slots contributed by every
    token shard; expert matmuls run on local weights; the inverse all_to_all
    returns expert outputs to the token owners for the weighted combine.
    Wire cost: 2 x (routed token slots), vs the baseline's per-layer fp32
    all-reduce of the whole [E*C, D] buffer.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    c = cfg.cdtype()
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    MP = mesh.shape["model"]
    E_loc = E // MP
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_tok_shards = 1
    for a in batch_axes:
        n_tok_shards *= mesh.shape[a]
    tok_axes = batch_axes if len(batch_axes) > 1 else (batch_axes[0]
                                                       if batch_axes else None)

    x_spec = P(tok_axes, None, None) if tok_axes else P(None, None, None)
    w_row = {"router": P(None, None), "w1": P("model", None, None),
             "w3": P("model", None, None), "w2": P("model", None, None)}
    p_specs = {k: w_row[k] for k in ("router", "w1", "w3", "w2")}
    if "shared" in p:
        p_specs["shared"] = jax.tree.map(
            lambda _: P(None, None), p["shared"])

    def body(xs, ps):
        Bl, Sl, _ = xs.shape
        T = Bl * Sl
        assert T % MP == 0, (T, MP)
        T_m = T // MP
        xt = xs.reshape(T, D)
        # x is replicated along 'model': each expert shard routes its OWN
        # 1/MP slice of the local tokens (token axis splits over data x model)
        m_idx = lax.axis_index("model")
        xt_m = lax.dynamic_slice_in_dim(xt, m_idx * T_m, T_m)

        logits = jnp.einsum("td,de->te", xt_m,
                            ps["router"].astype(c)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_e = lax.top_k(probs, K)
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_e, E, dtype=jnp.float32),
                              axis=1), axis=0)
        for ax in batch_axes + ("model",):
            me = lax.pmean(me, ax)
            ce = lax.pmean(ce, ax)
        aux = E * jnp.sum(me * ce)

        C = capacity(cfg, T_m)
        buf, flat_idx, keep, wf = _dispatch_local(xt_m, gate_e, gate_w, E, C,
                                                  D, c)
        # [E, C, D] -> [MP, E_loc, C, D]; all_to_all sends slice m' to expert
        # shard m'; received axis 0 indexes the contributing token sub-shard.
        buf = buf.reshape(MP, E_loc, C, D)
        buf = lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                             tiled=False)
        # expert compute on local weights over every contributor's slots
        g = jnp.einsum("mecd,edf->mecf", buf, ps["w1"].astype(c))
        u = jnp.einsum("mecd,edf->mecf", buf, ps["w3"].astype(c))
        h = jax.nn.silu(g) * u
        out = jnp.einsum("mecf,efd->mecd", h, ps["w2"].astype(c))
        # return slots to their token owners
        out = lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                             tiled=False)
        out_flat = out.reshape(E * C, D)         # expert-major, = flat_idx space
        picked = jnp.where(keep[:, None],
                           out_flat[jnp.minimum(flat_idx, E * C - 1)], 0.0)
        y_m = jnp.sum((picked * wf[:, None]).reshape(T_m, K, D), axis=1)
        if "shared" in ps:
            y_m = y_m + mlp_forward(ps["shared"], xt_m, cfg)
        # reassemble the token block (replicated along 'model' again)
        y = lax.all_gather(y_m, "model", axis=0, tiled=True)
        return y.reshape(Bl, Sl, D), aux.astype(jnp.float32)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(x_spec, p_specs),
                   out_specs=(x_spec, P()),
                   check_rep=False)
    ps = {k: p[k] for k in ("router", "w1", "w3", "w2")}
    if "shared" in p:
        ps["shared"] = p["shared"]
    return fn(x, ps)
