"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, enc_frames, d_model].  Positions are absolute
sinusoidal (rope_theta=0 in the config disables RoPE inside attention).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ann, constrain
from repro.models import layers as L
from repro.models.common import ModelConfig
from repro.models.transformer import _remat_policy, _stack


def _sinusoid(pos, d):
    """pos [...,] -> [..., d] sinusoidal embedding (whisper layout)."""
    half = d // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (jnp.log(10000.0) / max(1, half - 1)))
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(cfg, key):
    ks = jax.random.split(key, 2)
    return {"ln1": L.init_rmsnorm(cfg, cfg.d_model),
            "ln2": L.init_rmsnorm(cfg, cfg.d_model),
            "attn": L.init_gqa(cfg, ks[0]),
            "mlp": L.init_mlp(cfg, ks[1])}


def _init_dec_block(cfg, key):
    ks = jax.random.split(key, 3)
    return {"ln1": L.init_rmsnorm(cfg, cfg.d_model),
            "lnx": L.init_rmsnorm(cfg, cfg.d_model),
            "ln2": L.init_rmsnorm(cfg, cfg.d_model),
            "attn": L.init_gqa(cfg, ks[0]),
            "xattn": L.init_gqa(cfg, ks[1]),
            "mlp": L.init_mlp(cfg, ks[2])}


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 2)
    V, D = cfg.vocab_padded, cfg.d_model
    return {
        "embed": {"w": ann(
            jax.random.normal(ks[-1], (V, D), jnp.float32).astype(cfg.pdtype()) * 0.02,
            "vocab", None)},
        "enc_layers": _stack([_init_enc_block(cfg, ks[i])
                              for i in range(cfg.enc_layers)]),
        "enc_norm": L.init_rmsnorm(cfg, D),
        "layers": _stack([_init_dec_block(cfg, ks[cfg.enc_layers + i])
                          for i in range(cfg.n_layers)]),
        "final_norm": L.init_rmsnorm(cfg, D),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames [B, T, D] (stubbed frontend output) -> encoder states."""
    c = cfg.cdtype()
    B, T, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    h = frames.astype(c) + _sinusoid(pos, cfg.d_model).astype(c)
    h = constrain(h, "batch", None, None)

    def body(h, blk):
        a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
        h = h + L.gqa_forward(blk["attn"], a, cfg, pos, causal=False)
        m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
        return constrain(h, "batch", None, None), None

    body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
    h, _ = lax.scan(body_r, h, params["enc_layers"], unroll=cfg.scan_unroll)
    return L.rmsnorm(params["enc_norm"], h, cfg.rms_eps)


def forward(params, cfg: ModelConfig, tokens, frames):
    """Teacher-forced decoder. -> (logits [B,S,Vp], aux=0)."""
    c = cfg.cdtype()
    enc_h = encode(params, cfg, frames)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(c)
    h = h + _sinusoid(pos, cfg.d_model).astype(c)
    h = constrain(h, "batch", None, None)

    def body(h, blk):
        a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
        h = h + L.gqa_forward(blk["attn"], a, cfg, pos, causal=True)
        x = L.rmsnorm(blk["lnx"], h, cfg.rms_eps)
        ek, ev = L.encode_kv(blk["xattn"], enc_h, cfg)
        h = h + L.cross_attn_forward(blk["xattn"], x, cfg, ek, ev)
        m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
        return constrain(h, "batch", None, None), None

    body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
    h, _ = lax.scan(body_r, h, params["layers"], unroll=cfg.scan_unroll)
    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"].astype(c))
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch["tokens"], batch["frames"])
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    cd = jnp.dtype(cfg.cache_dtype)
    Ls = cfg.n_layers
    return {
        "k": jnp.zeros((Ls, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
        "v": jnp.zeros((Ls, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cd),
        "ck": jnp.zeros((Ls, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.d_head), cd),
        "cv": jnp.zeros((Ls, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.d_head), cd),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {"k": (None, "batch", "kv_seq", None, None),
            "v": (None, "batch", "kv_seq", None, None),
            "ck": (None, "batch", "kv_seq", None, None),
            "cv": (None, "batch", "kv_seq", None, None)}


def prefill(params, cfg: ModelConfig, tokens, frames):
    """Encode + teacher-forced pass emitting decoder self & cross caches."""
    c = cfg.cdtype()
    enc_h = encode(params, cfg, frames)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(c)
    h = h + _sinusoid(pos, cfg.d_model).astype(c)
    h = constrain(h, "batch", None, None)
    cd = jnp.dtype(cfg.cache_dtype)

    def body(h, blk):
        a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
        y, (k, v) = L.gqa_forward(blk["attn"], a, cfg, pos, causal=True,
                                  return_kv=True)
        h = h + y
        x = L.rmsnorm(blk["lnx"], h, cfg.rms_eps)
        ek, ev = L.encode_kv(blk["xattn"], enc_h, cfg)
        h = h + L.cross_attn_forward(blk["xattn"], x, cfg, ek, ev)
        m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
        h = constrain(h, "batch", None, None)
        return h, (k, v, ek.astype(cd), ev.astype(cd))

    body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
    h, (ks, vs, cks, cvs) = lax.scan(body_r, h, params["layers"],
                                     unroll=cfg.scan_unroll)
    h = L.rmsnorm(params["final_norm"], h[:, -1:, :], cfg.rms_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"].astype(c))
    return logits[:, 0].astype(jnp.float32), {"k": ks, "v": vs, "ck": cks, "cv": cvs}


def serve_step(params, cfg: ModelConfig, cache, token, cache_len):
    c = cfg.cdtype()
    h = jnp.take(params["embed"]["w"], token[:, None], axis=0).astype(c)
    B = token.shape[0]
    pos1 = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    h = h + _sinusoid(pos1, cfg.d_model).astype(c)
    h = constrain(h, "batch", None, None)

    def body(h, xs):
        blk, ck_, cv_, xk, xv = xs
        a = L.rmsnorm(blk["ln1"], h, cfg.rms_eps)
        y, nk, nv = L.gqa_decode(blk["attn"], a, cfg, ck_, cv_, cache_len)
        h = h + y
        x = L.rmsnorm(blk["lnx"], h, cfg.rms_eps)
        h = h + L.cross_attn_forward(blk["xattn"], x, cfg, xk, xv)
        m = L.rmsnorm(blk["ln2"], h, cfg.rms_eps)
        h = h + L.mlp_forward(blk["mlp"], m, cfg)
        return h, (nk, nv)

    h, (ks, vs) = lax.scan(body, h, (params["layers"], cache["k"], cache["v"],
                                     cache["ck"], cache["cv"]), unroll=cfg.scan_unroll)
    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"].astype(c))
    return logits[:, 0].astype(jnp.float32), {"k": ks, "v": vs,
                                              "ck": cache["ck"], "cv": cache["cv"]}
