from repro.checkpoint.manager import (AsyncCheckpointer, all_steps,  # noqa: F401
                                      latest_step, restore, save)
