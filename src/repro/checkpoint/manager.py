"""Checkpointing: atomic, sharded-logical, keep-k, async, elastic-restore.

Layout:
    <dir>/step_<N>/manifest.json       tree paths, shapes, dtypes, metadata
    <dir>/step_<N>/arrays.npz          one entry per leaf (host numpy)
    <dir>/LATEST                       text file with the newest step

Writes go to ``step_<N>.tmp`` and are renamed into place (atomic on POSIX),
so a crash mid-write never corrupts the latest checkpoint.  Restore takes a
*template* tree (abstract state from the registry) and optional shardings:
because the manifest stores logical shapes only, the same checkpoint restores
onto a different mesh / device count — the elastic-restart path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(directory: str, step: int, state: Any, *, keep: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    host = {k: np.asarray(v) for k, v in _flatten(jax.device_get(state)).items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
        "meta": extra_meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if os.path.exists(path):
        with open(path) as f:
            s = int(f.read().strip())
        if os.path.isdir(os.path.join(directory, f"step_{s:08d}")):
            return s
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template`` (abstract or concrete tree).

    ``shardings`` (optional pytree of NamedSharding, same structure) places
    each leaf directly onto the *current* mesh — which may differ from the
    mesh that wrote the checkpoint (elastic restart).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        data = {k: npz[k] for k in npz.files}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kpath, leaf in flat:
        key = jax.tree_util.keystr(kpath)
        if key not in data:
            raise KeyError(f"checkpoint {path} missing leaf {key}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {want_shape}")
        want_dtype = jax.numpy.dtype(leaf.dtype)
        leaves.append(arr.astype(want_dtype, copy=False))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
    return state, step


class AsyncCheckpointer:
    """Background writer: snapshot to host, save on a thread, never blocks
    the step loop for longer than the device->host copy."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Any, extra_meta: Optional[dict] = None):
        self.wait()
        host_state = jax.device_get(state)  # snapshot before mutation

        def work():
            try:
                save(self.directory, step, host_state, keep=self.keep,
                     extra_meta=extra_meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
