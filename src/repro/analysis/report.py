"""Regenerate EXPERIMENTS.md's generated tables from results/*.jsonl.

    PYTHONPATH=src python -m repro.analysis.report

Replaces the <!-- ROOFLINE_TABLE --> and <!-- PERF_TABLE --> markers (the
narrative text around them is hand-written and untouched).
"""
from __future__ import annotations

import json
import os
import re
import sys

from repro.analysis.roofline import (ICI_BW, HBM_BW, PEAK_FLOPS, fit_table,
                                     load, markdown, terms)

PERF_ROWS = [
    # (label, experiment key)
    ("cell 1 — wfa-paper E2% · pjit baseline (lock-step)", "wfa_pjit_baseline"),
    ("cell 1 — wfa-paper E2% · shard_map (per-shard term.)", "wfa_shardmap"),
    ("cell 1 — multi-pod · pjit", "wfa_pjit_multipod"),
    ("cell 1 — multi-pod · shard_map", "wfa_shardmap_multipod"),
    ("cell 2 — zamba2 train · fused xBC (baseline)", "zamba2_train_fusedproj"),
    ("cell 2 — zamba2 train · split x/B/C (refuted lever)", "zamba2_train_splitproj"),
    ("cell 2 — zamba2 train · split + seq-parallel", "zamba2_train_seqshard"),
    ("cell 3 — deepseek train · pjit scatter (baseline)", "deepseek_train_baseline"),
    ("cell 3 — deepseek train · EP (shard_map+all_to_all)", "deepseek_train_ep"),
    ("extra — phi3.5-moe train · EP dispatch", "phi35_train_ep"),
    ("extra — qwen3-32b prefill · baseline", "qwen3_32b_prefill_baseline"),
    ("extra — qwen3-32b prefill · seq-parallel", "qwen3_32b_prefill_seqshard"),
    ("extra — granite-8b train · baseline", "granite8b_train_baseline"),
    ("extra — granite-8b train · seq-parallel", "granite8b_train_seqshard"),
    ("extra — qwen3-32b train · seq-parallel", "qwen3_32b_train_seqshard"),
    ("extra — deepseek decode · naive MLA", "deepseek_decode_baseline"),
    ("extra — deepseek decode · absorbed MLA", "deepseek_decode_absorb"),
]

MEM_ROWS = [
    ("qwen3-32b train · TP-only state (baseline)", "qwen3_32b_train_nozero_mem"),
    ("qwen3-32b train · ZeRO 2-D state", "qwen3_32b_train_zero_mem"),
    ("qwen3-32b train · ZeRO + remat nothing", "qwen3_32b_train_remat_nothing_mem"),
    ("qwen3-32b train · ZeRO + 2k-token microbatch", "qwen3_32b_train_micro2k_mem"),
    ("granite-8b train · ZeRO + seq-parallel", "granite8b_train_seqshard_mem"),
    ("qwen3-32b train · ZeRO + remat-nothing + seq-par", "qwen3_32b_train_fit_combo_mem"),
    ("granite-34b train · ZeRO + remat-nothing + seq-par", "granite34b_train_fit_combo_mem"),
    ("qwen2-vl-7b train · ZeRO + remat-nothing + seq-par", "qwen2vl_train_fit_combo_mem"),
    ("zamba2-7b train · ZeRO + remat-nothing + seq-par", "zamba2_train_fit_combo_mem"),
    ("zamba2-7b train · ZeRO + seq-par + chunk64", "zamba2_train_fit_dots_mem"),
    ("phi3.5-moe train (2-pod) · ZeRO + EP + remat + seq-par", "phi35_train_fit_combo_mem"),
]


def perf_table(path="results/perf/experiments.jsonl") -> str:
    recs = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") == "ok":
                    recs[r["experiment"]] = r
    out = ["| experiment | compute | memory | collective | MFU bound |",
           "|---|---|---|---|---|"]
    for label, key in PERF_ROWS:
        r = recs.get(key)
        if r is None:
            out.append(f"| {label} | — | — | — | (pending) |")
            continue
        tc = r["flops_per_device"] / PEAK_FLOPS
        tm = r["bytes_per_device"] / HBM_BW
        tx = r["collectives"]["total"] / ICI_BW
        mf = r.get("model_flops") or 0.0
        mfu = (mf / r["n_devices"] / PEAK_FLOPS / max(tc, tm, tx)) if mf else 0
        f = lambda x: (f"{x*1e6:.1f}µs" if x < 1e-3 else
                       f"{x*1e3:.2f}ms" if x < 1 else f"{x:.2f}s")
        out.append(f"| {label} | {f(tc)} | {f(tm)} | {f(tx)} | "
                   f"{mfu:.1%} |" if mf else
                   f"| {label} | {f(tc)} | {f(tm)} | {f(tx)} | n/a |")

    out += ["", "Memory-fit iterations (per-device, memory pass):", "",
            "| experiment | args | temps | net | fits 16GB? |",
            "|---|---|---|---|---|"]
    for label, key in MEM_ROWS:
        r = recs.get(key)
        if r is None:
            out.append(f"| {label} | — | — | — | (pending) |")
            continue
        a = r.get("mem_argument_size_in_bytes", 0)
        t = r.get("mem_temp_size_in_bytes", 0)
        net = a + t - r.get("mem_alias_size_in_bytes", 0) \
            + r.get("mem_output_size_in_bytes", 0)
        ok = "YES" if net < 16e9 else "**NO**"
        out.append(f"| {label} | {a/1e9:.2f}GB | {t/1e9:.2f}GB "
                   f"| {net/1e9:.2f}GB | {ok} |")
    return "\n".join(out)


def patch(md_path="EXPERIMENTS.md"):
    with open(md_path) as f:
        text = f.read()
    recs = load("results/dryrun/cells.jsonl")
    roof = markdown(recs) + "\n\n**Per-device memory fit (memory pass):**\n\n" \
        + fit_table(recs)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->(?:.*?<!-- /ROOFLINE_TABLE -->)?",
                  "<!-- ROOFLINE_TABLE -->\n" + roof + "\n<!-- /ROOFLINE_TABLE -->",
                  text, flags=re.S)
    text = re.sub(r"<!-- PERF_TABLE -->(?:.*?<!-- /PERF_TABLE -->)?",
                  "<!-- PERF_TABLE -->\n" + perf_table() + "\n<!-- /PERF_TABLE -->",
                  text, flags=re.S)
    with open(md_path, "w") as f:
        f.write(text)
    print(f"patched {md_path}")


if __name__ == "__main__":
    patch(sys.argv[1] if len(sys.argv) > 1 else "EXPERIMENTS.md")
