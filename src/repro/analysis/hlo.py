"""Post-SPMD HLO inspection: collective bytes-on-wire for the roofline.

``compiled.cost_analysis()`` has FLOPs and memory traffic but no collective
accounting, so we parse the compiled HLO text and sum, per collective kind,
the wire bytes implied by its result shape and participant count:

    all-reduce          2 * bytes * (N-1)/N        (ring: reduce-scatter+all-gather)
    all-gather          bytes_out * (N-1)/N
    reduce-scatter      bytes_out * (N-1)          (each rank sends (N-1) shards)
    all-to-all          bytes * (N-1)/N
    collective-permute  bytes * 1

Bytes are per participating chip on its slowest link, the quantity the
roofline's collective term divides by link bandwidth.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g. "%all-gather.5 = bf16[4,128]{...} all-gather(" — capture shapes + op
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\b")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,N] iota form: G groups of size N
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """-> {op_kind: per-chip wire bytes} + {"total": ...} (+ "count_<op>")."""
    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        shape_text, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = _shape_bytes(shape_text)
        N = max(2, _group_size(line, n_devices))
        if op == "all-reduce":
            wire = 2.0 * nbytes * (N - 1) / N
        elif op == "all-gather":
            wire = nbytes * (N - 1) / N
        elif op == "reduce-scatter":
            wire = nbytes * (N - 1)
        elif op == "all-to-all":
            wire = nbytes * (N - 1) / N
        else:  # collective-permute
            wire = float(nbytes)
        out[op] += wire
        counts[op] += 1
    result = dict(out)
    result["total"] = float(sum(out.values()))
    for op, c in counts.items():
        result[f"count_{op}"] = c
    return result


def hlo_op_histogram(hlo_text: str, top: int = 20) -> Dict[str, int]:
    """Crude op-name histogram (remat/redundancy forensics)."""
    hist: Dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z][a-z0-9-]*)\(",
                         hlo_text):
        hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
