"""Three-term roofline from the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_wire_bytes_per_chip / link_bw

Terms come from the *roofline pass* records (scan-unrolled lowering — exact
HLO counts; see DESIGN.md §7).  The memory-pass records supply the fit proof
(memory_analysis sizes).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI (one link assumed busy; a 2-D torus can spread
traffic over more links, so the collective term is conservative).

MFU bound = model_flops / (chips * peak) / max(terms): the best MFU this
lowering could reach if everything else overlapped perfectly — the quantity
the §Perf loop pushes up by attacking the dominant term.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "fig1_e2", "fig1_e4"]


def load(path: str) -> Dict:
    """Latest record per (arch, shape, mesh, pass)."""
    recs: Dict = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r["arch"], r["shape"], r["mesh"], r.get("pass", "memory"))
            recs[key] = r
    return recs


def attn_s2_traffic(arch: str, shape_name: str, n_devices: int) -> float:
    """Per-device HBM bytes of the materialized S^2 attention intermediates
    that a fused (flash) attention kernel keeps in VMEM.

    XLA cannot fuse matmul->softmax->matmul on TPU, so the unfused lowering
    round-trips, per layer: scores bf16 (write+read), the fp32 masked copy
    (write+read by softmax), softmax output fp32 (write) + bf16 cast (read+
    write), and the same again on the A@V side, plus one recompute under
    remat and the bwd chain for train — ~6 S^2-sized fp32-equivalent
    round-trips fwd-only, ~3x that for train.  The flash-corrected memory
    term subtracts this traffic (the Pallas flash kernel in
    kernels/flash_attention is the mechanism; validated in interpret mode).
    """
    from repro.configs import get_config
    from repro.models.common import SHAPES
    try:
        cfg = get_config(arch)
    except KeyError:
        return 0.0
    if cfg.is_attn_free:
        return 0.0
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0                       # one-token scores are not S^2
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(1, cfg.hybrid_attn_every)
    else:
        n_attn = cfg.n_layers
    heads = cfg.n_heads
    s2 = float(B) * heads * float(S) * float(S)
    per_layer = 6.0 * 4.0 * s2           # ~6 fp32-equivalent round-trips
    total = per_layer * n_attn
    if cfg.family == "encdec":
        total += per_layer * cfg.enc_layers * (cfg.enc_frames / S) ** 2
    if shape.kind == "train":
        total *= 3.0                     # bwd + remat recompute chains
    return total / n_devices


def terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["bytes_per_device"] / HBM_BW
    t_x = rec["collectives"]["total"] / ICI_BW
    flash_bytes = max(0.0, rec["bytes_per_device"]
                      - attn_s2_traffic(rec["arch"], rec["shape"], n))
    t_mf = flash_bytes / HBM_BW
    dom = max((t_c, "compute"), (t_mf, "memory"), (t_x, "collective"))
    mf = rec.get("model_flops") or 0.0
    hlo_global = rec["flops_per_device"] * n
    out = {
        "compute_s": t_c, "memory_s": t_m, "memory_flash_s": t_mf,
        "collective_s": t_x,
        "dominant": dom[1], "bound_s": dom[0],
        "model_flops": mf,
        "model_over_hlo": (mf / hlo_global) if hlo_global > 0 else 0.0,
        "mfu_bound": (mf / n / PEAK_FLOPS / dom[0]) if mf and dom[0] > 0 else 0.0,
        "n_devices": n,
    }
    return out


def lever_sentence(rec: dict, t: dict) -> str:
    kind = rec.get("meta_kind", "?")
    dom = t["dominant"]
    if dom == "collective":
        if kind == "align":
            return ("per-shard termination (shard_map) removes the lock-step "
                    "any() all-reduce")
        return ("reshard to cut the per-layer TP collective volume, or "
                "overlap it with the next layer's compute")
    if dom == "memory":
        if kind == "decode":
            return ("KV/state cache traffic bound: quantize the cache or "
                    "raise decode batch to amortize weight reads")
        return ("HBM-bound: fuse elementwise chains and raise arithmetic "
                "intensity (bigger per-chip tiles)")
    if t["model_over_hlo"] < 0.5 and kind == "train":
        return ("compute-bound with low useful-FLOP ratio: cut remat "
                "recompute and attention-waste first, then scale batch")
    return "compute-bound near roofline: scale batch/chips or quantize"


def fmt_seconds(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def markdown(recs: Dict, mesh: str = "pod1-16x16") -> str:
    lines = [
        "| arch | shape | compute | memory(raw) | memory(flash) | collective "
        "| dominant | MODEL/HLO | MFU bound | lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in recs})
    for arch in archs:
        shapes = sorted({k[1] for k in recs if k[0] == arch},
                        key=lambda s: SHAPE_ORDER.index(s)
                        if s in SHAPE_ORDER else 99)
        for shape in shapes:
            r = recs.get((arch, shape, mesh, "roofline")) or \
                recs.get((arch, shape, mesh, "memory"))
            if r is None:
                continue
            if r.get("status") == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | skipped "
                             f"| — | — | {r.get('reason', '')[:60]} |")
                continue
            t = terms(r)
            if t is None:
                lines.append(f"| {arch} | {shape} | — | — | — | — | ERROR | —"
                             f" | — | see dry-run log |")
                continue
            ratio = f"{t['model_over_hlo']:.2f}" if t["model_flops"] else "n/a"
            mfu = f"{t['mfu_bound']:.1%}" if t["model_flops"] else "n/a"
            lines.append(
                f"| {arch} | {shape} | {fmt_seconds(t['compute_s'])} "
                f"| {fmt_seconds(t['memory_s'])} "
                f"| {fmt_seconds(t['memory_flash_s'])} "
                f"| {fmt_seconds(t['collective_s'])} | {t['dominant']} "
                f"| {ratio} | {mfu} | {lever_sentence(r, t)} |")
    return "\n".join(lines)


def fit_table(recs: Dict) -> str:
    """Memory-pass per-device sizes vs the 16 GB v5e HBM budget."""
    lines = [
        "| arch | shape | mesh | args/dev | temps/dev | total/dev | fits 16GB? |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh, p), r in sorted(recs.items()):
        if p != "memory" or r.get("status") != "ok":
            continue
        arg = r.get("mem_argument_size_in_bytes", 0)
        tmp = r.get("mem_temp_size_in_bytes", 0)
        alias = r.get("mem_alias_size_in_bytes", 0)
        tot = arg + tmp - alias + r.get("mem_output_size_in_bytes", 0)
        ok = "YES" if tot < 16e9 else "**NO**"
        lines.append(f"| {arch} | {shape} | {mesh} | {arg / 1e9:.2f}GB "
                     f"| {tmp / 1e9:.2f}GB | {tot / 1e9:.2f}GB | {ok} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun/cells.jsonl")
    ap.add_argument("--mesh", default="pod1-16x16")
    ap.add_argument("--fit", action="store_true",
                    help="emit the memory-fit table instead")
    args = ap.parse_args(argv)
    recs = load(args.inp)
    print(fit_table(recs) if args.fit else markdown(recs, args.mesh))
    return 0


if __name__ == "__main__":
    sys.exit(main())
