"""Host-side BiWFA recursion: breakpoint waves -> split -> stitch.

One :class:`BidirDriver` owns one ``trace_variant="bidir"`` CIGAR ticket.
It never aligns anything itself — every sub-problem is resubmitted through
the *same* :class:`~repro.core.session.AlignmentSession` as an internal
ticket, so recursion children batch with live traffic, share the engine's
executable cache, and retire through the ordinary wave pipeline:

1. **score pass** — one internal ``output="score"`` ticket over the whole
   batch resolves each pair's cost ``s`` (the meet solver needs the target
   to anchor its split detection, and score-only waves are the cheapest
   way to get it).
2. **recurse** — each pair becomes a segment tree.  A segment whose
   ``s * (plen + tlen)`` fits the trace budget base-cases to the packed
   backtrace (an ``output="cigar"`` child capped at its known cost);
   anything larger dispatches a breakpoint wave
   (:func:`~repro.core.wavefront.wfa_bidir_meet` via the engine-level
   ``"bidir_meet"`` output) and splits at the returned (diagonal, offset),
   with the affine open/extend joint handled by boundary states: a split
   inside a gap run pins the left child's end and the right child's begin
   to ``"I"``/``"D"`` so the open is charged exactly once.
3. **stitch + verify** — children's op arrays concatenate in tree order;
   every stitched root is re-scored host-side (``gotoh.score_cigar``)
   against the phase-1 cost.  Any mismatch (the meet detector accepts some
   coverage overshoots opportunistically) falls back to one packed-trace
   re-run of the offending segment, so exactness never rests on the
   detector; fallbacks are counted in ``stats.n_bidir_fallback``.

Trace memory: the meet waves keep O(s)-deep rolling windows and the only
materialized backtraces are budget-capped base cases — O(s) resident trace
bytes total vs the packed path's O(s^2) (``stats.peak_trace_bytes``).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import gotoh
from repro.core.engine import _round_up, pack_batch
from repro.obs import metrics as obs_metrics
from repro.obs import record as obs_record
from repro.obs import trace as obs_trace

__all__ = ["BidirDriver", "DEFAULT_TRACE_BUDGET"]

_OP_I, _OP_D = 2, 3

# Base-case threshold on s*(plen+tlen): ~4M cells keeps 1 kb pairs on the
# direct packed path (no recursion overhead in the short-read regime) while
# 10 kb+ noisy pairs recurse until their backtraces are a few hundred kB.
DEFAULT_TRACE_BUDGET = 1 << 22


class _Seg:
    """One node of a pair's recursion tree (half-open slices into the
    parent ticket's packed rows)."""
    __slots__ = ("row", "p_lo", "p_hi", "t_lo", "t_hi", "cost", "begin",
                 "end", "parent", "left", "right", "ops", "pending",
                 "fallback", "done", "depth")

    def __init__(self, row, p_lo, p_hi, t_lo, t_hi, cost, begin, end,
                 parent=None):
        self.row = row
        self.p_lo, self.p_hi = p_lo, p_hi
        self.t_lo, self.t_hi = t_lo, t_hi
        self.cost = cost          # forward-convention cost of this segment
        self.begin, self.end = begin, end
        self.parent = parent
        self.depth = 0 if parent is None else parent.depth + 1
        self.left = self.right = None
        self.ops: Optional[np.ndarray] = None
        self.pending = 0          # unresolved children (0 or 2)
        self.fallback = False     # already re-run via packed trace once
        self.done = False         # roots only: row finished


class BidirDriver:
    """Meet-in-the-middle traceback driver for one bidir CIGAR ticket."""

    def __init__(self, session, ticket, trace_budget: Optional[int] = None):
        self.sess = session
        self.ticket = ticket
        eng = session.engine
        budget = eng.trace_budget if trace_budget is None else trace_budget
        self.budget = DEFAULT_TRACE_BUDGET if budget is None else int(budget)
        pen = ticket.pen
        affine = pen.kind == "affine"
        self.o = pen.o if affine else 0
        maxop = max(pen.x, pen.o + pen.e) if affine else max(pen.x, pen.e)
        # detection window of the meet solver (see wfa_bidir_meet): the
        # lockstep loop needs ~(T+o)/2 + wd steps to cover every split
        self.wd = max(pen.window, 2 * maxop + 2)
        # own references: the parent ticket's packed arrays are nulled at
        # finalize, but stitching outlives retirement
        self._p, self._t = ticket._p, ticket._t
        self._plen, self._tlen = ticket._plen, ticket._tlen
        self._groups: dict = {}   # (kind, begin, end) -> [_Seg]

    # -- phases --------------------------------------------------------------

    def start(self) -> None:
        """Phase 1: resolve every pair's cost with a score-only ticket."""
        t = self.ticket
        self.sess.submit_packed(
            self._p, self._plen, self._t, self._tlen, output="score",
            penalties=t.pen, heuristic=t.heur, trace_variant="packed",
            _internal=True, _on_done=self._phase0_done, _flows=t.flows)

    def _merge_stats(self, child) -> None:
        """Fold an internal child ticket's telemetry into the parent's, so
        the bidir result reports the full cost (and the trace-memory
        high-water mark) of its whole recursion.  ``count_pairs=False``:
        the children's rows re-process pairs the parent already counted."""
        self.ticket.stats.merge(child.stats, count_pairs=False)

    def _phase0_done(self, st) -> None:
        self._merge_stats(st)
        for r in range(self.ticket.n_pairs):
            sc = int(st._scores[r])
            root = _Seg(r, 0, int(self._plen[r]), 0, int(self._tlen[r]),
                        sc, "M", "M")
            if sc < 0:             # unresolved even by the score pass
                self._finish_row(root, failed=True)
            else:
                self._classify(root)
        self._flush()

    # -- segment routing -----------------------------------------------------

    def _classify(self, seg: _Seg) -> None:
        """Resolve trivially, base-case to packed, or queue a meet wave."""
        n, m = seg.p_hi - seg.p_lo, seg.t_hi - seg.t_lo
        if n == 0:
            self._resolve(seg, np.full(m, _OP_I, np.int32))
        elif m == 0:
            self._resolve(seg, np.full(n, _OP_D, np.int32))
        elif (seg.cost == 0 and n == m and seg.begin == "M"
                and seg.end == "M"):
            self._resolve(seg, np.zeros(n, np.int32))     # pure match run
        elif (seg.fallback or seg.cost * (n + m) <= self.budget
                or seg.cost <= 2 * self.wd):
            self._groups.setdefault(("cigar", seg.begin, seg.end),
                                    []).append(seg)
        else:
            self._groups.setdefault(("meet", seg.begin, seg.end),
                                    []).append(seg)

    def _flush(self) -> None:
        """Dispatch queued segments, one internal ticket per (kind, states)
        group (boundary states are executable-static)."""
        groups, self._groups = self._groups, {}
        t = self.ticket
        for (kind, b, e), segs in groups.items():
            p, plen = pack_batch([self._p[s.row, s.p_lo:s.p_hi]
                                  for s in segs])
            tx, tlen = pack_batch([self._t[s.row, s.t_lo:s.t_hi]
                                   for s in segs])
            costs = np.asarray([s.cost for s in segs], np.int32)
            if kind == "cigar":
                # children run at their known cost, not the bucket worst
                # case (quantized for executable-cache reuse)
                cap = _round_up(max(int(costs.max(initial=0)), 1), 32)
                self.sess.submit_packed(
                    p, plen, tx, tlen, output="cigar", penalties=t.pen,
                    heuristic=t.heur, trace_variant="packed", meta=segs,
                    _s_cap=cap, _states=(b, e), _internal=True,
                    _on_done=self._cigar_done, _flows=t.flows)
            else:
                cap = _round_up((int(costs.max(initial=0)) + self.o) // 2
                                + self.wd + 2, 32)
                self.sess.submit_packed(
                    p, plen, tx, tlen, penalties=t.pen, heuristic=t.heur,
                    meta=segs, _starget=costs, _s_cap=cap, _states=(b, e),
                    _internal=True, _on_done=self._meet_done,
                    _flows=t.flows)

    # -- child completions ---------------------------------------------------

    def _meet_done(self, mt) -> None:
        self._merge_stats(mt)
        segs: List[_Seg] = mt.meta
        with obs_trace.span("bidir.split", cat="biwfa",
                            args={"segments": len(segs)}
                            if obs_trace.enabled() else None):
            self._split_segs(mt, segs)
        self._flush()

    def _split_segs(self, mt, segs: List[_Seg]) -> None:
        for i, seg in enumerate(segs):
            state = int(mt._meet[i, 0])
            a = int(mt._meet[i, 1])
            k, h = int(mt._meet[i, 3]), int(mt._meet[i, 4])
            n, m = seg.p_hi - seg.p_lo, seg.t_hi - seg.t_lo
            v = h - k
            if (int(mt._scores[i]) < 0 or state < 0
                    or not (0 <= v <= n and 0 <= h <= m)
                    or (v == 0 and h == 0) or (v == n and h == m)
                    or not (0 <= a <= seg.cost)):
                # fronts never joined (or a degenerate no-progress split):
                # this segment goes back through the packed path
                self._fallback(seg)
                continue
            jst = ("M", "I", "D")[state]
            left = _Seg(seg.row, seg.p_lo, seg.p_lo + v,
                        seg.t_lo, seg.t_lo + h, a, seg.begin, jst,
                        parent=seg)
            right = _Seg(seg.row, seg.p_lo + v, seg.p_hi,
                         seg.t_lo + h, seg.t_hi, seg.cost - a, jst,
                         seg.end, parent=seg)
            seg.left, seg.right = left, right
            seg.pending = 2
            obs_metrics.counter("bidir_splits_total",
                                "BiWFA segments split at a meet "
                                "breakpoint").inc()
            obs_trace.counter("bidir_recursion_depth", left.depth,
                              cat="biwfa")
            self._classify(left)
            self._classify(right)

    def _cigar_done(self, ct) -> None:
        self._merge_stats(ct)
        segs: List[_Seg] = ct.meta
        with obs_trace.span("bidir.stitch", cat="biwfa",
                            args={"segments": len(segs)}
                            if obs_trace.enabled() else None):
            for i, seg in enumerate(segs):
                if int(ct._scores[i]) < 0:
                    self._fallback(seg)
                    continue
                self._resolve(seg, ct._cigars[i])
        self._flush()

    def _fallback(self, seg: _Seg) -> None:
        for st in (self.ticket.stats, self.sess.stats):
            st.n_bidir_fallback += 1
        obs_record.dump("bidir_fallback",
                        {"row": seg.row, "depth": seg.depth,
                         "cost": int(seg.cost)})
        if seg.fallback:
            # the packed path itself came back unresolved: give up on the
            # row (same -1 contract as the packed trace under a pinned
            # s_max or a pruning heuristic)
            self._fail_row(seg)
            return
        seg.fallback = True
        seg.left = seg.right = None
        seg.pending = 0
        self._classify(seg)

    # -- stitching -----------------------------------------------------------

    def _resolve(self, seg: _Seg, ops: np.ndarray) -> None:
        """Record one segment's ops and propagate completed joins upward."""
        seg.ops = ops
        while seg.parent is not None:
            par = seg.parent
            par.pending -= 1
            if par.pending > 0:
                return
            if par.left.ops is None or par.right.ops is None:
                return            # sibling died and the row already failed
            par.ops = np.concatenate([par.left.ops, par.right.ops])
            par.left = par.right = None
            seg = par
        self._root_done(seg)

    def _root_done(self, root: _Seg) -> None:
        if root.done:
            return
        r = root.row
        pat = self._p[r, :int(self._plen[r])]
        txt = self._t[r, :int(self._tlen[r])]
        cost, ci, cj, ok = gotoh.score_cigar(root.ops, pat, txt,
                                             self.ticket.pen)
        exact = self.ticket.heur.exact
        good = (ok and ci == len(pat) and cj == len(txt)
                and (cost == root.cost or not exact))
        if not good and not root.fallback:
            # opportunistic breakpoint landed wrong: one whole-pair packed
            # re-run (the O(s^2) escape hatch) keeps the exactness contract
            for st in (self.ticket.stats, self.sess.stats):
                st.n_bidir_fallback += 1
            root.fallback = True
            root.ops = None
            self._classify(root)
            return
        if not good:
            self._finish_row(root, failed=True)
        else:
            # heuristic mode reports the realized (re-scored) cost, which
            # may beat the pruned score pass's bound
            self._finish_row(root, score=cost if not exact else root.cost)

    def _fail_row(self, seg: _Seg) -> None:
        while seg.parent is not None:
            seg = seg.parent
        self._finish_row(seg, failed=True)

    def _finish_row(self, root: _Seg, failed: bool = False,
                    score: Optional[int] = None) -> None:
        if root.done:
            return
        root.done = True
        t = self.ticket
        t._scores[root.row] = -1 if failed else int(score)
        t._cigars[root.row] = (np.zeros(0, np.int32) if failed
                               else root.ops)
        t._outstanding -= 1
        self.sess._maybe_finish(t)
