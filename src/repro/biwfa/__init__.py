"""Bidirectional WFA (BiWFA) traceback — exact CIGARs in O(s) trace memory.

The packed 2-bit backtrace stores O(s^2 / 16) provenance words per pair,
which is fine for short reads but blows past any trace budget on noisy
long reads (ONT/PacBio: L >= 10 kb, s in the thousands).  This package
implements the meet-in-the-middle alternative (Marco-Sola et al.'s BiWFA,
BIMSA's distance-based PIM variant): run a forward and a reverse wavefront
toward each other keeping only O(s)-deep rolling windows, find the
breakpoint where they join, and recurse on the two halves until each
sub-problem is small enough for the packed traceback.

Selected per call / per submit via ``trace_variant="bidir"`` (the same
seam as ``output=`` / ``penalties=`` / ``heuristic=``)::

    eng = AlignmentEngine(backend="ring")
    res = eng.align(ps, ts, output="cigar", trace_variant="bidir")

The host-side recursion lives in :mod:`repro.biwfa.recurse`; the batched
breakpoint solver is :func:`repro.core.wavefront.wfa_bidir_meet`.
"""
from repro.biwfa.recurse import BidirDriver, DEFAULT_TRACE_BUDGET

__all__ = ["BidirDriver", "DEFAULT_TRACE_BUDGET"]
