"""Candidate generation: anchors from the index + colinear chaining.

A read's minimizers are looked up in the :class:`~repro.mapping.index.
MinimizerIndex`; every (read position, reference position) seed hit is an
**anchor**.  Anchors of one (reference, strand) group that lie near a
common diagonal are merged by the classic colinear chaining DP (minimap2
§2.1 shape): anchors are sorted by reference position and scored

    score[i] = max(k, max_j  score[j] + gain(i, j) - gap(i, j))

over a bounded predecessor window, where ``gain`` is the number of new
bases anchor *i* covers (<= k, less when overlapping *j*) and ``gap``
penalizes the diagonal drift ``|dr - dq|``.  The window bound makes the
whole pass O(n log n) in the anchor count (sort dominates); read-scale
anchor lists are tiny, so this is pure numpy/python with no device work.

Strand handling: for reverse-strand anchors the read coordinate is
flipped to the reverse-complemented read (``qpos' = read_len - k -
qpos``), which makes reverse matches colinear in exactly the same
(ref, query) plane — the chain's coordinates then directly describe the
revcomp(read) that the extension stage aligns.

Output: ranked :class:`Chain` candidates (best first) with the
(reference, strand, span) the extension stage needs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data.dna import as_ascii
from repro.mapping.index import MinimizerIndex, extract_minimizers

__all__ = ["Anchor", "Chain", "read_anchors", "chain_anchors", "candidates"]


@dataclasses.dataclass(frozen=True)
class Anchor:
    """One seed hit: read k-mer == reference k-mer (strand-adjusted)."""
    ref_id: int
    rpos: int          # k-mer start on the reference (forward strand)
    qpos: int          # k-mer start on the strand-adjusted read
    strand: int        # 0 = read forward, 1 = read reverse-complemented


@dataclasses.dataclass(frozen=True)
class Chain:
    """One ranked candidate locus: a colinear run of anchors."""
    ref_id: int
    strand: int
    score: float       # chaining score (covered bases minus gap penalty)
    n_anchors: int
    qstart: int        # [qstart, qend) on the strand-adjusted read
    qend: int
    rstart: int        # [rstart, rend) on the forward reference
    rend: int

    @property
    def diag(self) -> int:
        """Approximate read-start diagonal: ref position of read base 0."""
        return self.rstart - self.qstart


def read_anchors(index: MinimizerIndex, read) -> Tuple[np.ndarray, np.ndarray,
                                                       np.ndarray, np.ndarray]:
    """-> (ref_id, rpos, qpos, strand) int32 anchor arrays for one read.

    ``qpos`` is already flipped onto the reverse-complemented read for
    strand-1 anchors (see module docstring); seeds over the index's
    occurrence cap contribute nothing.
    """
    read = as_ascii(read)
    seeds, qpos, qstrand = extract_minimizers(read, index.k, index.w)
    empty = (np.empty(0, np.int32),) * 4
    if seeds.size == 0:
        return empty
    start, count = index.lookup(seeds)
    hit = count > 0
    if not hit.any():
        return empty
    # expand (start, count) slices into flat occurrence indices
    reps = count[hit].astype(np.int64)
    occ_idx = np.repeat(start[hit], reps) + _ranges(reps)
    q = np.repeat(qpos[hit], reps).astype(np.int64)
    qs = np.repeat(qstrand[hit], reps)
    strand = (qs ^ index.occ_strand[occ_idx]).astype(np.int32)
    # reverse-strand anchors: read coordinate on the revcomp'd read
    q = np.where(strand == 1, len(read) - index.k - q, q)
    return (index.occ_ref[occ_idx].astype(np.int32),
            index.occ_pos[occ_idx].astype(np.int32),
            q.astype(np.int32), strand)


def _ranges(counts: np.ndarray) -> np.ndarray:
    """[3, 2] -> [0, 1, 2, 0, 1]: per-slice offsets for np.repeat starts."""
    total = int(counts.sum())
    out = np.arange(total, dtype=np.int64)
    ends = np.cumsum(counts) - counts
    return out - np.repeat(ends, counts)


def chain_anchors(ref_id: np.ndarray, rpos: np.ndarray, qpos: np.ndarray,
                  strand: np.ndarray, k: int, *, max_gap: int = 200,
                  max_pred: int = 32, gap_scale: float = 0.5,
                  min_score: float = 0.0,
                  max_chains: int = 16) -> List[Chain]:
    """Colinear chaining DP over anchor arrays -> ranked chains.

    Works per (ref_id, strand) group.  ``max_gap`` bounds both the
    reference and query jump between chained anchors, ``max_pred`` the DP
    predecessor window (the O(n log n) bound), ``gap_scale`` the cost per
    base of diagonal drift.  Returns at most ``max_chains`` chains with
    ``score > min_score``, best first; each anchor belongs to one chain
    (greedy primary-chain extraction in score order).
    """
    n = len(rpos)
    if n == 0:
        return []
    ref_id = np.asarray(ref_id, np.int64)
    rpos = np.asarray(rpos, np.int64)
    qpos = np.asarray(qpos, np.int64)
    strand = np.asarray(strand, np.int64)
    # one sort over (group, ref position, query position); groups are then
    # contiguous runs and the DP below never crosses a group boundary
    group = ref_id * 2 + strand
    order = np.lexsort((qpos, rpos, group))
    g, r, q = group[order], rpos[order], qpos[order]

    # plain python lists in the DP: the anchor lists are tiny and numpy
    # scalar indexing costs ~10x a list index in this loop
    gl, rl, ql = g.tolist(), r.tolist(), q.tolist()
    score = [float(k)] * n
    parent = [-1] * n
    for i in range(n):
        lo = max(0, i - max_pred)
        gi, ri, qi, si = gl[i], rl[i], ql[i], score[i]
        pi = -1
        for j in range(i - 1, lo - 1, -1):
            if gl[j] != gi:
                break
            dr = ri - rl[j]
            dq = qi - ql[j]
            if dr <= 0 or dq <= 0 or dr > max_gap or dq > max_gap:
                continue
            cand = score[j] + min(k, dr, dq) - gap_scale * abs(dr - dq)
            if cand > si:
                si = cand
                pi = j
        score[i] = si
        parent[i] = pi

    score = np.asarray(score)
    chains: List[Chain] = []
    used = np.zeros(n, bool)
    for i in np.argsort(-score, kind="stable"):
        if used[i] or score[i] <= min_score:
            continue
        members = []
        j = int(i)
        while j >= 0 and not used[j]:
            members.append(j)
            used[j] = True
            j = int(parent[j])
        m = np.asarray(members[::-1])
        # a backtrack truncated at an already-used anchor is a branch off
        # an earlier chain: re-base its score to the kept members only
        # (score is a prefix sum along the parent chain), else the stub
        # would inherit the primary's full score and outrank genuine
        # secondary loci
        adj = float(score[i] - score[m[0]]) + k
        if adj <= min_score:
            continue
        oi = order[i]
        chains.append(Chain(
            ref_id=int(ref_id[oi]), strand=int(strand[oi]),
            score=adj, n_anchors=len(m),
            qstart=int(q[m[0]]), qend=int(q[m[-1]]) + k,
            rstart=int(r[m[0]]), rend=int(r[m[-1]]) + k))
        if len(chains) >= max_chains:
            break
    chains.sort(key=lambda c: -c.score)
    return chains


def candidates(index: MinimizerIndex, read, *, top_n: int = 2,
               max_gap: int = 200, min_score: float = 0.0) -> List[Chain]:
    """Ranked candidate loci for one read: anchors + chaining, best first."""
    ref, rpos, qpos, strand = read_anchors(index, read)
    chains = chain_anchors(ref, rpos, qpos, strand, index.k,
                           max_gap=max_gap, min_score=min_score,
                           max_chains=max(top_n * 4, 8))
    return chains[:top_n]
