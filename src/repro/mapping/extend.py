"""Batched WFA extension of candidate loci through the streaming engine.

The verification stage: each ranked :class:`~repro.mapping.chain.Chain` is
turned into one (reference window, strand-adjusted read) pair and pushed
through ``AlignmentEngine.stream()`` in CIGAR mode — bucketed batching,
executable caching, overflow recovery and out-of-order gather all come
from the session layer for free, and every alignment the mapper reports
went through the same engine as plain pairwise traffic (no second
alignment entry point).

Windows are cut to ``read_len + 2*delta`` around the chain's diagonal
(``delta = ceil(edit_frac * read_len) + extra_pad`` absorbs indel drift
and the diagonal estimate error), so extension problems land in the same
length buckets as the paper's pairwise workload — the mappings/sec vs
pairs/sec benchmark ratio is a like-for-like comparison.  The global
alignment against the slightly-wider window starts and ends with forced
deletion runs; those are trimmed off the CIGAR and their gap cost off the
score, which yields the SAM ``POS`` (window start + leading trim) and a
cost that re-scores exactly against ``ref[POS : POS + ref_span]``.

Ticket metadata carries the per-row ``(read_id, locus, strand)`` records
(the session treats it as opaque), so ``as_completed()`` retires whole
reads out of order: a read whose extensions overflowed into the recovery
queue does not stall reads submitted after it.

MAPQ is the best-vs-second-best gap: with best trimmed cost ``c1`` and
runner-up ``c2`` (across this read's verified candidates),

    MAPQ = 60                                     (single candidate)
    MAPQ = min(60, round(20 * (c2 - c1) / unit))  (otherwise)

where ``unit = pen.unit_cost()`` (the cost of one isolated edit) — 0 when
tied, saturating at 60 once the runner-up is ~3 edits worse.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import scoring
from repro.core.cigar import OP_D, OP_I, OP_M
from repro.core.engine import AlignmentEngine, EngineStats
from repro.data.dna import as_ascii, revcomp
from repro.obs import trace as obs_trace
from repro.mapping.chain import Chain, candidates
from repro.mapping.index import MinimizerIndex

__all__ = ["Mapping", "MapperStats", "ReadMapper", "suggested_edit_frac"]


@dataclasses.dataclass
class Mapping:
    """One reported alignment of a read onto the reference set.

    ``ref_id == -1`` means unmapped (no candidate locus, or none of the
    candidates produced an alignment).  ``pos`` is the 0-based leftmost
    reference position (:mod:`repro.mapping.sam` adds SAM's +1);
    ``ops`` the trimmed CIGAR op array (``core.cigar`` codes) of the
    strand-adjusted read against the forward reference; ``score`` its
    alignment cost, which re-scores exactly against
    ``ref[pos : pos + ref_span]``.
    """
    read_id: int
    ref_id: int = -1
    pos: int = -1
    strand: int = 0
    mapq: int = 0
    score: int = -1
    ops: Optional[np.ndarray] = None
    chain_score: float = 0.0
    n_candidates: int = 0
    secondary: bool = False
    approximate: bool = False

    @property
    def mapped(self) -> bool:
        return self.ref_id >= 0

    def ref_span(self) -> int:
        """Reference bases consumed (M/X/D ops) — the SAM span."""
        if self.ops is None:
            return 0
        return int((self.ops != OP_I).sum())     # M/X/D all consume ref


@dataclasses.dataclass
class MapperStats:
    """Telemetry for one ``map_stream``/``map`` pass."""
    n_reads: int = 0
    n_mapped: int = 0
    n_candidates: int = 0      # chains submitted for extension
    n_unresolved: int = 0      # extensions that came back score == -1
    n_tickets: int = 0
    # engine-side telemetry aggregated across every extension ticket
    # (EngineStats.merge per retirement — scatter/kernel/gather time,
    # cache behaviour, overflow recovery for the whole pass)
    engine: EngineStats = dataclasses.field(default_factory=EngineStats)

    @property
    def n_extensions(self) -> int:
        """Pairs through the engine — one per candidate, by construction."""
        return self.n_candidates

    @property
    def candidates_per_read(self) -> float:
        return self.n_candidates / max(self.n_reads, 1)


def suggested_edit_frac(pen, edit_frac: float, read_len: int,
                        extra_pad: int = 1) -> float:
    """Engine ``edit_frac`` sizing the optimistic pass for extension pairs.

    An extension problem costs up to ``ceil(E*L)`` read edits *plus* two
    forced end-deletion runs into the padded window (up to ``2*delta``
    trimmed bases total).  This returns the smallest E' whose engine-side
    score bound covers that, so the common case resolves in pass 1 and
    only genuinely divergent candidates hit the recovery queue.
    """
    pen = scoring.as_model(pen)
    delta = int(math.ceil(edit_frac * read_len)) + extra_pad
    need = (int(math.ceil(edit_frac * read_len)) * pen.unit_cost()
            + 2 * pen.gap_cost(2 * delta))
    # engine bound at length lmax >= wlen: n*(unit + e) + o + slack,
    # n = ceil(E' * lmax); solve for n at the tightest lmax
    n = max(1, math.ceil((need - pen.o) / (pen.unit_cost() + pen.e)))
    return n / max(read_len + 2 * delta, 1)


@dataclasses.dataclass(frozen=True)
class _Cand:
    """Per-row ticket metadata: which read/locus/strand this row verifies."""
    read_id: int
    chain: Chain
    wstart: int                # window start on the forward reference
    wlen: int                  # window length (re-slices the reference)
    text: np.ndarray           # strand-adjusted read (ASCII uint8)


class ReadMapper:
    """Seed-chain-extend mapper over one index + one alignment engine.

    Parameters
    ----------
    index : the shared :class:`MinimizerIndex`.
    engine : the :class:`AlignmentEngine` all extensions go through.
        ``None`` builds a ``ring``-backend engine sized for this mapper's
        ``edit_frac``/``read_len`` regime (:func:`suggested_edit_frac`).
    top_n : candidate loci verified per read (primary + secondaries).
    edit_frac : expected read divergence E — sizes windows and (for an
        auto-built engine) the optimistic score bound.
    extra_pad : window slack beyond ``ceil(E*L)`` for the chain's
        diagonal-estimate error.
    batch_reads : reads per session submit (one ticket's worth).
    penalties / heuristic : per-submit scoring seam, forwarded to every
        ``submit()`` (PR-4 semantics; ``None`` = engine defaults).
    trace_variant : traceback seam, forwarded the same way — pass
        ``"bidir"`` for long-read extension (ONT/PacBio windows), where
        the packed backtrace's O(s^2) trace memory is the binding
        constraint; short-read mapping keeps the default packed path.
    min_chain_score / max_gap : chaining thresholds (``None`` -> ``k``).
    """

    def __init__(self, index: MinimizerIndex,
                 engine: Optional[AlignmentEngine] = None, *,
                 top_n: int = 2, edit_frac: float = 0.02,
                 extra_pad: int = 1, read_len: int = 100,
                 batch_reads: int = 256, penalties=None, heuristic=None,
                 trace_variant: Optional[str] = None,
                 min_chain_score: Optional[float] = None,
                 max_gap: int = 200, backend: str = "ring"):
        if top_n < 1:
            raise ValueError(f"need top_n >= 1: {top_n}")
        self.index = index
        self.top_n = int(top_n)
        self.edit_frac = float(edit_frac)
        self.extra_pad = int(extra_pad)
        self.batch_reads = int(batch_reads)
        self.penalties = penalties
        self.heuristic = heuristic
        self.trace_variant = trace_variant
        self.max_gap = int(max_gap)
        self.min_chain_score = (float(index.k) if min_chain_score is None
                                else float(min_chain_score))
        if engine is None:
            pen = scoring.as_model(penalties)
            engine = AlignmentEngine(
                pen, backend=backend,
                edit_frac=suggested_edit_frac(pen, edit_frac, read_len,
                                              extra_pad))
        self.engine = engine
        self.pen = engine.resolve_penalties(penalties)
        self.stats = MapperStats()

    # -- window geometry -----------------------------------------------------

    def _window(self, c: Chain, read_len: int) -> Tuple[np.ndarray, int]:
        """Reference window around the chain's diagonal -> (bases, start)."""
        ref = self.index.seqs[c.ref_id]
        delta = int(math.ceil(self.edit_frac * read_len)) + self.extra_pad
        wstart = max(0, c.diag - delta)
        wend = min(len(ref), c.diag + read_len + delta)
        wstart = min(wstart, max(0, wend - 1))
        return ref[wstart:wend], wstart

    # -- mapping -------------------------------------------------------------

    def map_stream(self, reads: Sequence, *,
                   max_inflight_waves: int = 2) -> Iterator[List[Mapping]]:
        """Map reads, yielding one ``[primary, *secondaries]`` list per read
        **in completion order** (not submission order — ``read_id`` says
        which read a list belongs to).

        Reads without any candidate locus yield an unmapped
        :class:`Mapping` immediately; everything else is submitted in
        ``batch_reads`` chunks and retired as its ticket completes.
        Resets and fills ``self.stats``.
        """
        self.stats = MapperStats()
        stats = self.stats
        eng = self.engine
        with eng.stream(max_inflight_waves=max_inflight_waves) as sess:
            pats: List[np.ndarray] = []
            texts: List[np.ndarray] = []
            metas: List[_Cand] = []
            reads_in_batch = 0

            def flush():
                nonlocal pats, texts, metas, reads_in_batch
                if metas:
                    sess.submit(pats, texts, output="cigar",
                                penalties=self.penalties,
                                heuristic=self.heuristic,
                                trace_variant=self.trace_variant,
                                meta=metas)
                    stats.n_tickets += 1
                pats, texts, metas = [], [], []
                reads_in_batch = 0

            for rid, read in enumerate(reads):
                read = as_ascii(read)
                stats.n_reads += 1
                with obs_trace.span("map.seed_chain", cat="mapping",
                                    args={"read": rid}
                                    if obs_trace.enabled() else None):
                    chains = candidates(self.index, read, top_n=self.top_n,
                                        max_gap=self.max_gap,
                                        min_score=self.min_chain_score)
                if not chains:
                    yield [Mapping(read_id=rid)]
                    continue
                stats.n_candidates += len(chains)
                rc = None
                for c in chains:
                    if c.strand and rc is None:
                        rc = revcomp(read)
                    window, wstart = self._window(c, len(read))
                    text = read if c.strand == 0 else rc
                    pats.append(window)
                    texts.append(text)
                    metas.append(_Cand(read_id=rid, chain=c, wstart=wstart,
                                       wlen=len(window), text=text))
                reads_in_batch += 1
                if reads_in_batch >= self.batch_reads:
                    flush()
            flush()
            for ticket in sess.as_completed():
                yield from self._retire(ticket)

    def map(self, reads: Sequence) -> List[List[Mapping]]:
        """Map reads -> per-read ``[primary, *secondaries]`` lists in input
        order (the blocking convenience wrapper over :meth:`map_stream`)."""
        out: List[Optional[List[Mapping]]] = [None] * len(reads)
        for maps in self.map_stream(reads):
            out[maps[0].read_id] = maps
        return out    # every read yields exactly once

    # -- retirement ----------------------------------------------------------

    def _retire(self, ticket) -> Iterator[List[Mapping]]:
        """Turn one completed ticket into per-read mapping lists."""
        res = ticket.result()
        stats = self.stats
        stats.engine.merge(ticket.stats)
        out: List[List[Mapping]] = []
        # the span closes before anything is yielded: it measures trim /
        # rank / MAPQ work, not the consumer's time between yields
        with obs_trace.span("map.retire", cat="mapping",
                            args={"ticket": ticket.index,
                                  "rows": ticket.n_pairs}
                            if obs_trace.enabled() else None):
            by_read: dict = {}
            for row, cand in enumerate(ticket.meta):
                by_read.setdefault(cand.read_id, []).append((row, cand))
            for rid, rows in by_read.items():
                scored = []
                for row, cand in rows:
                    s = int(res.scores[row])
                    if s < 0:
                        stats.n_unresolved += 1
                        continue
                    ops, lead, trimmed = self._trim(res.cigars[row], cand)
                    scored.append((s - trimmed, cand, ops, lead))
                if not scored:
                    out.append([Mapping(read_id=rid, n_candidates=len(rows))])
                    continue
                scored.sort(key=lambda t: (t[0], -t[1].chain.score))
                second = scored[1][0] if len(scored) > 1 else None
                maps = []
                for rank, (cost, cand, ops, lead) in enumerate(scored):
                    c = cand.chain
                    maps.append(Mapping(
                        read_id=rid, ref_id=c.ref_id,
                        pos=cand.wstart + lead, strand=c.strand,
                        mapq=(self._mapq(cost, second) if rank == 0 else 0),
                        score=cost, ops=ops, chain_score=c.score,
                        n_candidates=len(rows), secondary=rank > 0,
                        approximate=res.approximate))
                stats.n_mapped += 1
                out.append(maps)
        yield from out

    def _trim(self, ops: np.ndarray,
              cand: "_Cand") -> Tuple[np.ndarray, int, int]:
        """Strip forced end-deletion runs -> (ops, lead_len, cost_removed).

        The global alignment against the padded window opens a deletion
        run wherever the read starts/ends inside the window; trimming it
        recovers the local placement (POS) and its gap cost.  Global
        optima are not unique though: when a few read-edge bases happen to
        match the window *before* the forced gap (``2M 6D 98M`` instead of
        ``6D 100M``), the gap lands one run inboard and naive trimming
        would keep paying for it — so end M-runs are first slid across an
        adjacent D-run whenever the matched bases still match at the
        shifted reference position (a pure tie-break: the global cost is
        unchanged, the trimmed cost and POS improve).
        """
        ops = np.asarray(ops)
        ref = self.index.seqs[cand.chain.ref_id]
        window = ref[cand.wstart: cand.wstart + cand.wlen]
        ops = self._slide_ends(ops, window, cand.text)
        non_d = np.flatnonzero(ops != OP_D)
        if non_d.size == 0:
            return ops[:0], len(ops), self.pen.gap_cost(len(ops))
        i0, i1 = int(non_d[0]), int(non_d[-1]) + 1
        removed = (self.pen.gap_cost(i0) + self.pen.gap_cost(len(ops) - i1))
        return ops[i0:i1], i0, removed

    @staticmethod
    def _run_len(ops: np.ndarray, op: int) -> int:
        """Length of the leading run of ``op`` in ``ops``."""
        other = np.flatnonzero(ops != op)
        return int(other[0]) if other.size else len(ops)

    @classmethod
    def _slide_ends(cls, ops: np.ndarray, window: np.ndarray,
                    text: np.ndarray) -> np.ndarray:
        """Rotate end M-runs across the adjacent D-run when bases allow."""
        n = len(ops)
        # left edge: [a M][d D]... -> [d D][a M]... iff text[:a] matches
        # the window at the post-gap position
        a = cls._run_len(ops, OP_M)
        d = cls._run_len(ops[a:], OP_D) if 0 < a < n else 0
        if a and d and np.array_equal(text[:a], window[d: d + a]):
            ops = ops.copy()
            ops[:d] = OP_D
            ops[d: d + a] = OP_M
        # right edge: ...[d D][b M] -> ...[b M][d D] iff the trailing text
        # bases match the window at the pre-gap position
        b = cls._run_len(ops[::-1], OP_M)
        d = cls._run_len(ops[:n - b][::-1], OP_D) if 0 < b < n else 0
        if b and d:
            j = n - b - d
            # window offset of the D run start = ref bases consumed before
            r0 = int((ops[:j] != OP_I).sum())
            if np.array_equal(text[len(text) - b:], window[r0: r0 + b]):
                ops = ops.copy()
                ops[j: j + b] = OP_M
                ops[j + b:] = OP_D
        return ops

    def _mapq(self, best: int, second: Optional[int]) -> int:
        if second is None:
            return 60
        gap = second - best
        if gap <= 0:
            return 0
        return min(60, int(round(20.0 * gap / self.pen.unit_cost())))
