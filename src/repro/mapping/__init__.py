"""Read-mapping subsystem: minimizer index -> chain -> WFA extend -> SAM.

The paper's throughput numbers exist to serve read mapping — millions of
short reads located on reference sequences.  PRs 1-4 built the fast inner
loop (engine, streaming sessions, CIGAR pipeline, scoring models); this
package is the seed-chain-extend pipeline around it:

* :mod:`repro.mapping.index`  — :class:`MinimizerIndex`: 2-bit packed,
  strand-canonical minimizer seeds in an open-addressed hash table.
* :mod:`repro.mapping.chain`  — per-read candidate generation + colinear
  anchor chaining (ranked candidate loci with strand).
* :mod:`repro.mapping.extend` — :class:`ReadMapper`: batched verification
  of candidate windows through ``AlignmentEngine.stream()``.
* :mod:`repro.mapping.sam`    — SAM header/record formatting (the writer
  ``launch/align.py`` and ``launch/map_reads.py`` share).

New candidate filters and seeding schemes land here (see ROADMAP).
"""
from repro.mapping.chain import Anchor, Chain, chain_anchors, read_anchors  # noqa: F401
from repro.mapping.extend import Mapping, ReadMapper  # noqa: F401
from repro.mapping.index import MinimizerIndex  # noqa: F401
from repro.mapping.sam import header_lines, mapping_record, write_sam  # noqa: F401
