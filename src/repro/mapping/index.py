"""Minimizer k-mer index over reference sequences.

Candidate generation is the stage that decides end-to-end read-mapping
throughput (Ben-Hur et al., arXiv:2411.03832), so the index is built the
way the fast mappers build theirs (minimap2 lineage, Roberts et al. 2004
minimizers):

* **2-bit packed seeds** — k-mers are packed into int64 (2 bits/base, so
  k <= 31).  Bytes outside ACGT (N, IUPAC codes) get the
  :data:`~repro.data.dna.NCODE` sentinel and poison every window that
  covers them: N runs produce *no* seeds rather than false ones.
* **strand canonicalization** — each k-mer is stored as
  ``min(fwd, revcomp)`` plus the bit saying which strand won, so one
  index serves both strands and a read's strand falls out of an XOR at
  query time.
* **minimizers** — of every ``w`` consecutive k-mers, only the one with
  the smallest mixed hash is kept (~2/(w+1) sampling) — the classic
  windowed sampling that guarantees any two sequences sharing a
  ``w + k - 1`` exact stretch share a seed.
* **open-addressed hash buckets** — unique seeds live in a power-of-two
  linear-probe table (load factor <= 0.5) mapping seed -> a slice of one
  position-sorted occurrence array.  Both build and lookup are
  *batch-vectorized*: probing advances all unresolved keys one slot per
  round instead of looping per key.
* **occurrence cap** — seeds occurring more than ``occ_cap`` times in the
  reference are dropped at build time (repeats would otherwise flood
  candidate generation; this is minimap2's top-frequency filter in its
  simplest form).

The index is a plain dataclass of numpy arrays — picklable, built once,
shared read-only across queries (:meth:`MinimizerIndex.save` /
:meth:`MinimizerIndex.load`).
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.dna import NCODE, as_ascii, encode_2bit

__all__ = ["MinimizerIndex", "extract_minimizers"]

_EMPTY = np.int64(-1)        # empty hash-table slot


def _mix64(h: np.ndarray) -> np.ndarray:
    """Invertible 64-bit finalizer (splitmix64 flavor) — decorrelates the
    lexicographic k-mer order so minimizer sampling is uniform."""
    h = np.asarray(h, np.uint64).copy()
    h ^= h >> np.uint64(30)
    h *= np.uint64(0xBF58476D1CE4E5B9)
    h ^= h >> np.uint64(27)
    h *= np.uint64(0x94D049BB133111EB)
    h ^= h >> np.uint64(31)
    return h


def _pack_kmers(codes: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """[L] 2-bit codes -> (packed [L-k+1] int64 fwd k-mers, valid mask).

    Vectorized sliding-window matmul: position i packs
    ``codes[i:i+k]`` big-endian (first base in the high bits).  Windows
    touching an NCODE sentinel are invalid.
    """
    L = len(codes)
    n = L - k + 1
    if n <= 0:
        return np.empty(0, np.int64), np.empty(0, bool)
    win = np.lib.stride_tricks.sliding_window_view(codes, k)      # [n, k]
    valid = (win < NCODE).all(axis=1)
    shifts = (2 * np.arange(k - 1, -1, -1)).astype(np.int64)
    # sentinel codes are masked out of the pack so invalid windows still
    # produce an in-range (ignored) value rather than garbage bits
    fwd = ((win.astype(np.int64) & 3) << shifts).sum(axis=1)
    return fwd, valid


def _revcomp_kmers(fwd: np.ndarray, k: int) -> np.ndarray:
    """Packed reverse complements: complement every base (XOR with 11),
    then reverse the base order within the word."""
    v = (~fwd) & ((np.int64(1) << np.int64(2 * k)) - 1)     # complement
    rc = np.zeros_like(v)
    for _ in range(k):
        rc = (rc << 2) | (v & 3)
        v >>= 2
    return rc


def extract_minimizers(seq, k: int, w: int) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray]:
    """-> (seeds int64, positions int32, strands uint8) for one sequence.

    ``seeds`` are canonical packed k-mers (min of forward and reverse
    complement), ``positions`` the k-mer start on the given sequence,
    ``strands`` 1 when the reverse complement was the canonical form.
    Strand-ambiguous k-mers (palindromes: fwd == rc) are dropped, as in
    minimap2 — their strand bit would be meaningless.
    """
    codes = encode_2bit(as_ascii(seq))
    fwd, valid = _pack_kmers(codes, k)
    if fwd.size == 0:
        z = np.empty(0, np.int64)
        return z, np.empty(0, np.int32), np.empty(0, np.uint8)
    rc = _revcomp_kmers(fwd, k)
    strand = (rc < fwd).astype(np.uint8)
    canon = np.where(strand.astype(bool), rc, fwd)
    valid &= fwd != rc                       # drop palindromic k-mers
    # windowed minimizer sampling over the mixed hash; invalid k-mers get
    # the max hash so they can never win a window
    h = _mix64(canon.astype(np.uint64))
    h = np.where(valid, h, np.uint64(0xFFFFFFFFFFFFFFFF))
    if len(h) <= w:
        pick = np.array([int(np.argmin(h))]) if valid.any() else \
            np.empty(0, np.int64)
    else:
        hw = np.lib.stride_tricks.sliding_window_view(h, w)   # [n-w+1, w]
        pick = np.unique(hw.argmin(axis=1) + np.arange(hw.shape[0]))
    if pick.size:
        pick = pick[valid[pick]]             # all-N windows picked nothing
    return (canon[pick].astype(np.int64), pick.astype(np.int32),
            strand[pick])


def _probe_insert(table_key: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Vectorized linear-probe insert of unique ``keys`` -> slot per key.

    Each round resolves, for every still-unplaced key, whether its current
    slot is free; first-come-first-served collisions within a round are
    broken by ``np.unique``.  Rounds are bounded by the longest probe
    cluster (short at load factor <= 0.5).
    """
    mask = np.int64(len(table_key) - 1)
    slot = (_mix64(keys.astype(np.uint64)).astype(np.int64)) & mask
    out = np.full(len(keys), -1, np.int64)
    pending = np.arange(len(keys))
    while pending.size:
        s = slot[pending]
        free = table_key[s] == _EMPTY
        # one winner per contested free slot this round
        uniq_s, first = np.unique(s[free], return_index=True)
        winners = pending[free][first]
        table_key[slot[winners]] = keys[winners]
        out[winners] = slot[winners]
        placed = np.zeros(len(keys), bool)
        placed[winners] = True
        pending = pending[~placed[pending]]
        slot[pending] = (slot[pending] + 1) & mask
    return out


def _probe_lookup(table_key: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Vectorized linear-probe lookup -> table slot per query (-1 = miss)."""
    mask = np.int64(len(table_key) - 1)
    slot = (_mix64(queries.astype(np.uint64)).astype(np.int64)) & mask
    out = np.full(len(queries), -1, np.int64)
    pending = np.arange(len(queries))
    while pending.size:
        s = slot[pending]
        got = table_key[s]
        hit = got == queries[pending]
        out[pending[hit]] = s[hit]
        miss = got == _EMPTY
        pending = pending[~(hit | miss)]
        slot[pending] = (slot[pending] + 1) & mask
    return out


def _next_pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class MinimizerIndex:
    """Immutable minimizer index over a set of reference sequences.

    Built once with :meth:`build`, shared read-only across queries;
    pickles cleanly (plain numpy arrays + python scalars) for
    ``--save-index`` / ``--index`` reuse.
    """
    k: int
    w: int
    occ_cap: int
    names: List[str]                    # per-reference
    lengths: np.ndarray                 # [n_refs] int64
    seqs: List[np.ndarray]              # ASCII uint8, kept for extension
    table_key: np.ndarray               # [m] int64 open-addressed seeds
    table_start: np.ndarray             # [m] int64 slice into occ arrays
    table_count: np.ndarray             # [m] int32
    occ_ref: np.ndarray                 # [n_occ] int32 reference id
    occ_pos: np.ndarray                 # [n_occ] int32 k-mer start
    occ_strand: np.ndarray              # [n_occ] uint8 canonical-strand bit
    n_seeds_total: int = 0              # pre-cap minimizer count (telemetry)
    n_seeds_capped: int = 0             # occurrences dropped by occ_cap

    @classmethod
    def build(cls, seqs: Sequence, names: Optional[Sequence[str]] = None, *,
              k: int = 15, w: int = 10,
              occ_cap: int = 64) -> "MinimizerIndex":
        """Index reference sequences (str / bytes / ASCII uint8 arrays)."""
        if not (0 < k <= 31):
            raise ValueError(f"need 0 < k <= 31 (2-bit packed int64): {k}")
        if w < 1 or occ_cap < 1:
            raise ValueError(f"need w >= 1, occ_cap >= 1: w={w}, "
                             f"occ_cap={occ_cap}")
        seqs = [as_ascii(s) for s in seqs]
        names = ([f"ref{i}" for i in range(len(seqs))] if names is None
                 else [str(n) for n in names])
        if len(names) != len(seqs):
            raise ValueError(f"{len(names)} names for {len(seqs)} sequences")
        seeds, refs, poss, strands = [], [], [], []
        for rid, s in enumerate(seqs):
            mm, pos, strand = extract_minimizers(s, k, w)
            seeds.append(mm)
            poss.append(pos)
            strands.append(strand)
            refs.append(np.full(len(mm), rid, np.int32))
        seed = np.concatenate(seeds) if seeds else np.empty(0, np.int64)
        ref = np.concatenate(refs) if refs else np.empty(0, np.int32)
        pos = np.concatenate(poss) if poss else np.empty(0, np.int32)
        strand = (np.concatenate(strands) if strands
                  else np.empty(0, np.uint8))
        n_total = int(seed.size)

        # sort occurrences by (seed, ref, pos) -> contiguous buckets
        order = np.lexsort((pos, ref, seed))
        seed, ref, pos, strand = (seed[order], ref[order], pos[order],
                                  strand[order])
        uniq, start, count = np.unique(seed, return_index=True,
                                       return_counts=True)
        # occurrence cap: repetitive seeds are dropped wholesale — from the
        # occurrence arrays too, or repeat-heavy references would pay the
        # memory the cap exists to save (rows unreachable from the table)
        keep = count <= occ_cap
        n_capped = int(count[~keep].sum())
        rows = np.repeat(keep, count)          # occurrences are seed-sorted
        ref, pos, strand = ref[rows], pos[rows], strand[rows]
        uniq, count = uniq[keep], count[keep]
        start = (np.concatenate([[0], np.cumsum(count)[:-1]])
                 if len(count) else np.empty(0)).astype(np.int64)

        m = _next_pow2(2 * max(len(uniq), 1))
        table_key = np.full(m, _EMPTY, np.int64)
        slots = _probe_insert(table_key, uniq)
        table_start = np.zeros(m, np.int64)
        table_count = np.zeros(m, np.int32)
        table_start[slots] = start
        table_count[slots] = count
        return cls(k=k, w=w, occ_cap=occ_cap, names=names,
                   lengths=np.asarray([len(s) for s in seqs], np.int64),
                   seqs=seqs, table_key=table_key, table_start=table_start,
                   table_count=table_count, occ_ref=ref, occ_pos=pos,
                   occ_strand=strand, n_seeds_total=n_total,
                   n_seeds_capped=n_capped)

    # -- queries -------------------------------------------------------------

    @property
    def n_refs(self) -> int:
        return len(self.names)

    @property
    def n_occurrences(self) -> int:
        return int(self.occ_pos.size)

    def lookup(self, seeds: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Canonical seeds -> (start, count) occurrence slices (count 0 =
        absent or capped)."""
        seeds = np.asarray(seeds, np.int64)
        slots = _probe_lookup(self.table_key, seeds)
        hit = slots >= 0
        start = np.zeros(len(seeds), np.int64)
        count = np.zeros(len(seeds), np.int32)
        start[hit] = self.table_start[slots[hit]]
        count[hit] = self.table_count[slots[hit]]
        return start, count

    def nbytes(self) -> int:
        """Index memory (hash table + occurrences; excludes kept seqs)."""
        return (self.table_key.nbytes + self.table_start.nbytes
                + self.table_count.nbytes + self.occ_ref.nbytes
                + self.occ_pos.nbytes + self.occ_strand.nbytes)

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def load(cls, path: str) -> "MinimizerIndex":
        with open(path, "rb") as f:
            idx = pickle.load(f)
        if not isinstance(idx, cls):
            raise TypeError(f"{path}: not a pickled MinimizerIndex "
                            f"(got {type(idx).__name__})")
        return idx
