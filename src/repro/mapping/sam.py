"""SAM formatting — the one header/record writer the launchers share.

Produces spec-shaped SAM (v1.6): an ``@HD``/``@SQ``/``@PG`` header built
from the reference set, and 11-column records with

* ``FLAG``  — 0x4 unmapped, 0x10 reverse strand, 0x100 secondary;
* ``POS``   — 1-based leftmost reference position (the mapper's 0-based
  ``pos`` + 1);
* ``MAPQ``  — the mapper's best-vs-second-best gap (see
  :mod:`repro.mapping.extend`), 0 on secondaries/unmapped;
* ``CIGAR`` — classic ``M``/``I``/``D`` by default (what downstream tools
  expect) or SAM-1.4 ``=``/``X`` with ``mode="extended"``, straight from
  the packed-backtrace pipeline's op arrays;
* ``SEQ``   — the read on the *forward reference* orientation (reverse-
  strand mappings store the reverse complement, per the SAM spec);
* tags — ``AS:i`` (negated alignment cost: higher is better), ``NM:i``
  (edit distance: X/I/D op count) and ``cm:i`` (chain score) on mapped
  records.

No pysam anywhere — records are plain tab-joined lines, and the tests
parse them back with the same split discipline.
"""
from __future__ import annotations

from typing import IO, Iterable, List, Optional, Sequence

import numpy as np

from repro.core import cigar as cigar_mod
from repro.data.dna import as_ascii, revcomp

__all__ = ["header_lines", "mapping_record", "unmapped_record", "write_sam"]

FLAG_UNMAPPED = 0x4
FLAG_REVERSE = 0x10
FLAG_SECONDARY = 0x100


def header_lines(names: Sequence[str], lengths: Sequence[int], *,
                 program: str = "repro", version: str = "0.1",
                 cl: Optional[str] = None) -> List[str]:
    """@HD/@SQ/@PG header for a reference set (one @SQ per reference)."""
    out = ["@HD\tVN:1.6\tSO:unknown"]
    for name, ln in zip(names, lengths):
        out.append(f"@SQ\tSN:{name}\tLN:{int(ln)}")
    pg = f"@PG\tID:{program}\tPN:{program}\tVN:{version}"
    if cl:
        pg += f"\tCL:{cl}"
    out.append(pg)
    return out


def _seq_str(read) -> str:
    return as_ascii(read).tobytes().decode("ascii")


def unmapped_record(name: str, read) -> str:
    """FLAG-4 record: no position, no CIGAR, no alignment score."""
    seq = _seq_str(read)
    return "\t".join([name, str(FLAG_UNMAPPED), "*", "0", "0", "*", "*",
                      "0", "0", seq or "*", "*"])


def mapping_record(mapping, read, name: str, ref_name: str, *,
                   mode: str = "classic") -> str:
    """One mapped SAM record from a :class:`~repro.mapping.extend.Mapping`.

    ``read`` is the read as sequenced (the mapper's input orientation);
    reverse-strand records store its reverse complement so SEQ is always
    on the forward reference strand.
    """
    if not mapping.mapped:
        return unmapped_record(name, read)
    flag = ((FLAG_REVERSE if mapping.strand else 0)
            | (FLAG_SECONDARY if mapping.secondary else 0))
    seq = as_ascii(read)
    if mapping.strand:
        seq = revcomp(seq)
    ops = mapping.ops
    nm = int(np.isin(ops, (cigar_mod.OP_X, cigar_mod.OP_I,
                           cigar_mod.OP_D)).sum())
    fields = [name, str(flag), ref_name, str(int(mapping.pos) + 1),
              str(int(mapping.mapq)), cigar_mod.cigar_string(ops, mode),
              "*", "0", "0", _seq_str(seq) or "*", "*",
              f"AS:i:{-int(mapping.score)}", f"NM:i:{nm}",
              f"cm:i:{int(mapping.chain_score)}"]
    return "\t".join(fields)


def write_sam(out: IO[str], mappings_per_read: Iterable[Sequence],
              reads: Sequence, read_names: Sequence[str],
              ref_names: Sequence[str], ref_lengths: Sequence[int], *,
              mode: str = "classic", cl: Optional[str] = None) -> int:
    """Write a full SAM stream -> number of alignment records written.

    ``mappings_per_read`` yields per-read ``[primary, *secondaries]``
    lists (any order — records are written as they arrive, matching the
    out-of-order retirement of :meth:`ReadMapper.map_stream`).
    """
    for line in header_lines(ref_names, ref_lengths, cl=cl):
        out.write(line + "\n")
    n = 0
    for maps in mappings_per_read:
        for m in maps:
            rid = m.read_id
            name = str(read_names[rid])
            if m.mapped:
                line = mapping_record(m, reads[rid], name,
                                      ref_names[m.ref_id], mode=mode)
            else:
                line = unmapped_record(name, reads[rid])
            out.write(line + "\n")
            n += 1
    return n
