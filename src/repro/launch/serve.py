"""Serving driver: batched prefill -> decode loop with a KV/state cache.

CPU-runnable at smoke scale (the production-mesh serve path is exercised by
``dryrun.py`` decode cells).  Implements the core serving mechanics: one
prefill per admitted batch, then lock-step decode with greedy sampling and a
per-slot stop condition; finished slots are refilled from the queue
(continuous-batching-lite: the cache slot is recycled by re-prefilling the
whole batch when at least ``refill_frac`` of slots are done — the KV layout
keeps one contiguous cache, which is the sharding-friendly variant).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelConfig
from repro.models.registry import get_model_fns


class BatchServer:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 512,
                 batch: int = 4, mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.fns = get_model_fns(cfg)
        self._prefill = jax.jit(
            lambda p, t: self.fns.prefill(p, cfg, t))
        self._step = jax.jit(
            lambda p, c, t, l: self.fns.serve_step(p, cfg, c, t, l))

    def generate(self, prompts: List[np.ndarray], *, max_new: int = 32,
                 eos_id: Optional[int] = None) -> List[np.ndarray]:
        """Greedy-decode a batch of token-id prompts (ragged, padded here)."""
        assert len(prompts) <= self.batch
        B = self.batch
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        with self.mesh, use_mesh(self.mesh):
            if self.cfg.family in ("ssm", "hybrid"):
                logits, cache = self._prefill(self.params, toks)
                # state caches carry no seq axis; attn caches in hybrids are
                # prefill-length — decode appends from there
                cache = self._grow_hybrid_cache(cache)
            else:
                cache = self.fns.init_cache(self.cfg, B, self.max_seq)
                logits, pcache = self._prefill(self.params, toks)
                cache = self._splice(cache, pcache, plen)
            out = [list(p) for p in prompts]
            tok = np.asarray(jnp.argmax(logits, -1), np.int32)
            done = np.zeros((B,), bool)
            for t in range(max_new):
                for i in range(len(prompts)):
                    if not done[i]:
                        out[i].append(int(tok[i]))
                        if eos_id is not None and tok[i] == eos_id:
                            done[i] = True
                if done[: len(prompts)].all() or plen + t + 1 >= self.max_seq:
                    break
                logits, cache = self._step(self.params, cache,
                                           jnp.asarray(tok),
                                           jnp.int32(plen + t))
                tok = np.asarray(jnp.argmax(logits, -1), np.int32)
        return [np.asarray(o, np.int32) for o in out]

    def _splice(self, cache, pcache, plen):
        """Copy prefill K/V (length plen) into the max_seq decode cache."""
        out = {}
        for k, big in cache.items():
            small = pcache[k]
            if big.shape == small.shape:        # state caches (ssm/conv)
                out[k] = small
            else:
                out[k] = jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), 0, axis=2)
        return out

    def _grow_hybrid_cache(self, pcache):
        out = dict(pcache)
        for k in ("attn_k", "attn_v"):
            if k in out:
                small = out[k]
                pad = self.max_seq - small.shape[2]
                if pad > 0:
                    widths = [(0, 0)] * small.ndim
                    widths[2] = (0, pad)
                    out[k] = jnp.pad(small, widths)
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    if args.arch.endswith("-smoke"):
        cfg = smoke_config(args.arch[: -len("-smoke")])
    else:
        cfg = get_config(args.arch)
    if cfg.family == "encdec":
        print("serve.py demo targets decoder-only archs", file=sys.stderr)
        return 2

    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    server = BatchServer(cfg, state["params"], batch=args.batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    n_tokens = 0
    for wave in range(0, args.requests, args.batch):
        prompts = [rng.integers(0, cfg.vocab_size, size=rng.integers(4, 17))
                   .astype(np.int32)
                   for _ in range(min(args.batch, args.requests - wave))]
        outs = server.generate(prompts, max_new=args.max_new)
        n_tokens += sum(len(o) - len(p) for o, p in zip(outs, prompts))
        print(f"[serve] wave {wave // args.batch}: "
              f"{[len(o) for o in outs]} tokens each", flush=True)
    dt = time.perf_counter() - t0
    print(f"[serve] {n_tokens} new tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s on this host)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
