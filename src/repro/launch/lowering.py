"""Cell construction for dry-run / train / serve: (fn, abstract args,
in/out shardings, donation) for every (arch x input-shape x mesh) cell.

``train_*`` lowers train_step, ``prefill_*`` lowers prefill, ``decode_*`` /
``long_*`` lower serve_step (one new token against a seq_len-deep cache).
The ``wfa-paper`` workload lowers the batched aligner with the pair axis
sharded over every mesh axis (PIM: all chips are DPUs, no collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.penalties import Penalties
from repro.core.aligner import problem_bounds
from repro.distributed.sharding import (sharding_for, tree_shardings,
                                        zero_shardings)
from repro.launch.mesh import data_shards, mesh_devices
from repro.models.common import ModelConfig, ShapeSpec, num_microbatches
from repro.models.registry import (abstract_train_state, batch_logical_axes,
                                   batch_specs, decode_logical_axes,
                                   decode_specs, get_model_fns)
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    args: Tuple[Any, ...]            # abstract (ShapeDtypeStruct) args
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _batch_shardings(mesh: Mesh, specs, axes):
    return jax.tree.map(
        lambda s, ax: sharding_for(mesh, s.shape, tuple(ax)),
        specs, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def build_lm_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  opt_cfg: Optional[AdamWConfig] = None,
                  mode: str = "memory", zero: bool = True) -> Cell:
    """``mode``:

    * ``"memory"``   — production lowering (layer scan rolled, microbatched,
      chunked attention): compiles fast, ``memory_analysis`` proves the step
      fits.  XLA counts scan bodies ONCE, so its FLOP numbers undercount.
    * ``"roofline"`` — accounting lowering (layer scan fully unrolled, no
      microbatch scan, unchunked attention): identical math, exact HLO
      FLOP/byte/collective counts for the roofline table.
    """
    assert mode in ("memory", "roofline"), mode
    if mode == "roofline":
        cfg = cfg.replace(unroll_layers=True, q_chunk=shape.seq_len,
                          microbatch_tokens=1 << 40)
    fns = get_model_fns(cfg)
    state_sds, state_axes = abstract_train_state(cfg)
    params_sds = state_sds["params"]
    params_sh = tree_shardings(mesh, params_sds, state_axes["params"])

    if shape.kind == "train":
        n_micro = num_microbatches(cfg, shape, data_shards(mesh))
        step = fns.make_train_step(cfg, opt_cfg or AdamWConfig(), n_micro)
        b_sds = batch_specs(cfg, shape)
        b_sh = _batch_shardings(mesh, b_sds, batch_logical_axes(cfg, shape))
        # ZeRO 2-D state sharding: without it no >8B train cell fits HBM
        # (§Dry-run); `zero=False` is kept as the recorded baseline.
        state_sh = (zero_shardings(mesh, state_sds, state_axes) if zero
                    else tree_shardings(mesh, state_sds, state_axes))
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=step, args=(state_sds, b_sds),
            in_shardings=(state_sh, b_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
            meta={"kind": "train", "n_micro": n_micro, "mode": mode},
        )

    if shape.kind == "prefill":
        b_sds = batch_specs(cfg, shape)
        b_sh = _batch_shardings(mesh, b_sds, batch_logical_axes(cfg, shape))

        if cfg.family == "encdec":
            fn = lambda params, tokens, frames: fns.prefill(
                params, cfg, tokens, frames)
            args = (params_sds, b_sds["tokens"], b_sds["frames"])
            in_sh = (params_sh, b_sh["tokens"], b_sh["frames"])
        elif cfg.family == "vlm":
            fn = lambda params, tokens, pe, mp: fns.prefill(
                params, cfg, tokens, patch_embeds=pe, mrope_pos=mp)
            args = (params_sds, b_sds["tokens"], b_sds["patch_embeds"],
                    b_sds["mrope_pos"])
            in_sh = (params_sh, b_sh["tokens"], b_sh["patch_embeds"],
                     b_sh["mrope_pos"])
        else:
            fn = lambda params, tokens: fns.prefill(params, cfg, tokens)
            args = (params_sds, b_sds["tokens"])
            in_sh = (params_sh, b_sh["tokens"])
        return Cell(name=f"{cfg.name}:{shape.name}", fn=fn, args=args,
                    in_shardings=in_sh, out_shardings=None,
                    donate_argnums=(), meta={"kind": "prefill", "mode": mode})

    # decode
    d_sds = decode_specs(cfg, shape)
    d_axes = decode_logical_axes(cfg)
    cache_sh = _batch_shardings(mesh, d_sds["cache"], d_axes["cache"])
    tok_sh = sharding_for(mesh, d_sds["token"].shape, ("batch",))
    len_sh = NamedSharding(mesh, P())

    if cfg.family == "vlm":
        mp_sh = sharding_for(mesh, d_sds["mrope_pos"].shape,
                             ("batch", None, None))
        fn = lambda params, cache, token, cache_len, mp: fns.serve_step(
            params, cfg, cache, token, cache_len, mrope_pos=mp)
        args = (params_sds, d_sds["cache"], d_sds["token"],
                d_sds["cache_len"], d_sds["mrope_pos"])
        in_sh = (params_sh, cache_sh, tok_sh, len_sh, mp_sh)
    else:
        fn = lambda params, cache, token, cache_len: fns.serve_step(
            params, cfg, cache, token, cache_len)
        args = (params_sds, d_sds["cache"], d_sds["token"],
                d_sds["cache_len"])
        in_sh = (params_sh, cache_sh, tok_sh, len_sh)
    return Cell(name=f"{cfg.name}:{shape.name}", fn=fn, args=args,
                in_shardings=in_sh, out_shardings=(None, cache_sh),
                donate_argnums=(1,), meta={"kind": "decode", "mode": mode})


def build_wfa_cell(workload, mesh: Mesh, *, edit_frac: Optional[float] = None,
                   pairs_per_device: Optional[int] = None,
                   variant: str = "pjit") -> Cell:
    """The paper's own workload: batched WFA, pair axis over all mesh axes.

    ``variant="pjit"`` is the baseline (global lock-step termination — SPMD
    inserts a tiny all-reduce per score iteration); ``"shard_map"`` is the
    PIM-faithful per-shard-termination version (zero collectives).
    """
    from repro.core.wavefront import wfa_scores, wfa_scores_shardmap

    ef = edit_frac if edit_frac is not None else workload.edit_frac
    ppd = pairs_per_device or workload.pairs_per_device
    n_dev = mesh_devices(mesh)
    B = ppd * n_dev
    L = workload.read_len
    Lpad = ((L + 127) // 128) * 128
    import numpy as np
    fake = np.full((1,), L, np.int32)
    s_max, k_max = problem_bounds(workload.pen, fake, fake, ef)

    if variant == "shard_map":
        def fn(pattern, text, plen, tlen):
            return wfa_scores_shardmap(pattern, text, plen, tlen,
                                       pen=workload.pen, s_max=s_max,
                                       k_max=k_max, mesh=mesh)
    else:
        def fn(pattern, text, plen, tlen):
            res = wfa_scores(pattern, text, plen, tlen, pen=workload.pen,
                             s_max=s_max, k_max=k_max)
            return res.score

    pair_spec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    seq_sds = jax.ShapeDtypeStruct((B, Lpad), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    return Cell(
        name=f"wfa-paper:E{int(ef * 100)}:{variant}",
        fn=fn, args=(seq_sds, seq_sds, len_sds, len_sds),
        in_shardings=(pair_spec, pair_spec, pair_spec, pair_spec),
        out_shardings=pair_spec,
        donate_argnums=(),
        meta={"kind": "align", "pairs": B, "s_max": s_max, "k_max": k_max,
              "edit_frac": ef, "variant": variant},
    )


def lower_cell(cell: Cell, mesh: Mesh):
    """-> (lowered, jitted). Wrap in the mesh contexts so logical-axis
    sharding constraints inside model code resolve against this mesh."""
    from repro.distributed.sharding import use_mesh

    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    with mesh, use_mesh(mesh):
        lowered = jitted.lower(*cell.args)
    return lowered, jitted
