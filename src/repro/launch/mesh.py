"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax device query, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return _compat_make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Whatever this host actually has (CPU: usually 1 device)."""
    n = jax.device_count()
    mp = model_parallel or 1
    assert n % mp == 0, (n, mp)
    return _compat_make_mesh((n // mp, mp), ("data", "model"))


def mesh_devices(mesh: Mesh) -> int:
    return int(mesh.devices.size)


def data_shards(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n
