"""Always-on alignment service launcher (in-process open-loop driver).

Not the LM-serving demo — ``launch/serve.py`` is the unrelated
model-serving stub (prefill/decode over a KV cache); *this* launcher runs
the **alignment** service: ``repro.serve.ServeLoop`` worker threads
feeding one shared streaming session with continuous batching, admission
control and out-of-order delivery.  The driver is in-process and
open-loop (a deterministic Poisson arrival trace replayed at a configured
offered load — no network dependency), which is exactly the serving
benchmark's harness; wrap ``ServeLoop.submit()`` in your transport of
choice to serve real traffic.

Examples::

    # moderate load, auto-calibrated to 75% of this host's batch pairs/s
    PYTHONPATH=src python -m repro.launch.serve_align --requests 512

    # explicit rate, per-request seams, latency SLO and tight queue
    PYTHONPATH=src python -m repro.launch.serve_align \
        --rate 500 --penalties edit --heuristic adaptive:10,50 \
        --output cigar --deadline-ms 200 --queue-depth 64
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.core import scoring
from repro.core.engine import AlignmentEngine
from repro.data.reads import ArrivalSpec, generate_trace
from repro.serve import ServeLoop, replay_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="open-loop driver for the always-on alignment service")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--pairs-per-request", type=int, default=8)
    ap.add_argument("--read-len", type=int, default=100)
    ap.add_argument("--edit-frac", type=float, default=0.02)
    ap.add_argument("--backend", default="ring")
    ap.add_argument("--rate", type=float, default=None,
                    help="offered load in requests/s (default: --load x "
                         "measured batch-mode throughput)")
    ap.add_argument("--load", type=float, default=0.75,
                    help="offered load as a fraction of batch-mode "
                         "pairs/s when --rate is not given")
    ap.add_argument("--wave-pairs", type=int, default=256,
                    help="rows per formed wave (flush-when-full bound)")
    ap.add_argument("--form-deadline-ms", type=float, default=25.0,
                    help="max ms a forming wave waits for company")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget (shortens forming)")
    ap.add_argument("--queue-depth", type=int, default=4096,
                    help="admission bound; arrivals beyond it are shed")
    ap.add_argument("--threads", type=int, default=1,
                    help="serve-loop worker threads")
    ap.add_argument("--output", default="score",
                    choices=["score", "cigar"])
    ap.add_argument("--penalties", default=None,
                    help="edit | linear:x,e | affine:x,o,e | x,o,e")
    ap.add_argument("--heuristic", default=None,
                    help="adaptive[:min_len,max_diff] | zdrop:z | none")
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture the measured replay as Chrome trace-event"
                         " JSON (open in ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the measured replay in jax.profiler.trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append one obs.metrics JSONL snapshot after the "
                         "replay")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve the live Prometheus /metrics endpoint on "
                         "this port for the duration of the run")
    args = ap.parse_args(argv)

    msrv = None
    if args.metrics_port is not None:
        msrv = obs.metrics.start_http_server(args.metrics_port)
        print(f"[serve_align] metrics endpoint -> "
              f"http://localhost:{args.metrics_port}/metrics",
              file=sys.stderr)
    try:
        return _run(args)
    finally:
        if msrv is not None:
            msrv.shutdown()


def _run(args) -> int:

    pen = (scoring.parse_penalties(args.penalties)
           if args.penalties else None)
    heur = (scoring.parse_heuristic(args.heuristic)
            if args.heuristic else None)
    eng = AlignmentEngine(backend=args.backend, edit_frac=args.edit_frac)

    spec = ArrivalSpec(n_requests=args.requests,
                       pairs_per_request=args.pairs_per_request,
                       read_len=args.read_len, edit_frac=args.edit_frac,
                       seed=args.seed)
    payloads, unit_arrivals = generate_trace(spec)

    rate = args.rate
    if rate is None:
        P = np.concatenate([p for p, _, _, _ in payloads])
        plen = np.concatenate([pl for _, pl, _, _ in payloads])
        T = np.concatenate([t for _, _, t, _ in payloads])
        tlen = np.concatenate([tl for _, _, _, tl in payloads])
        eng.align_packed(P, plen, T, tlen, penalties=pen, heuristic=heur)
        t0 = time.perf_counter()
        eng.align_packed(P, plen, T, tlen, penalties=pen, heuristic=heur)
        batch_pps = len(plen) / (time.perf_counter() - t0)
        rate = args.load * batch_pps / args.pairs_per_request
        print(f"[serve_align] batch mode: {batch_pps:,.0f} pairs/s -> "
              f"offered {rate:,.0f} req/s ({args.load:.0%} load)",
              file=sys.stderr)

    # warm the serving wave shape so the replay is steady-state
    n_warm = min(args.requests,
                 max(2 * args.wave_pairs // args.pairs_per_request, 2))
    with ServeLoop(eng, wave_pairs=args.wave_pairs,
                   form_deadline=args.form_deadline_ms / 1e3,
                   max_queue_depth=args.queue_depth,
                   n_threads=args.threads) as warm:
        replay_trace(warm, payloads[:n_warm], np.zeros(n_warm),
                     penalties=pen, heuristic=heur, output=args.output)
    traces0 = eng.cache_traces()

    with obs.capture_trace(args.trace_out), \
            obs.profile.profile(args.profile), \
            ServeLoop(eng, wave_pairs=args.wave_pairs,
                      form_deadline=args.form_deadline_ms / 1e3,
                      max_queue_depth=args.queue_depth,
                      n_threads=args.threads) as server:
        report = replay_trace(
            server, payloads, unit_arrivals / rate, penalties=pen,
            heuristic=heur, output=args.output,
            deadline=(None if args.deadline_ms is None
                      else args.deadline_ms / 1e3))
    st = report.stats
    if args.trace_out:
        print(f"[serve_align] trace -> {args.trace_out}", file=sys.stderr)
    if args.metrics_out:
        obs.metrics.write_jsonl(args.metrics_out)
        print(f"[serve_align] metrics -> {args.metrics_out}",
              file=sys.stderr)

    print(f"[serve_align] {report.n_ok}/{report.n_requests} served, "
          f"{report.n_shed} shed, {report.n_failed} failed "
          f"(driver lag max {report.lag_max * 1e3:.1f} ms)")
    print(f"[serve_align] sustained {report.sustained_pairs_per_s:,.0f} "
          f"pairs/s over {report.t_sustained:.2f}s")
    print(f"[serve_align] latency p50 {report.percentile_ms(50):.1f} ms | "
          f"p95 {report.percentile_ms(95):.1f} ms | "
          f"p99 {report.percentile_ms(99):.1f} ms "
          f"({report.latencies.size} completions)")
    print(f"[serve_align] waves: {st.n_waves} dispatched "
          f"({st.waves_full} full / {st.waves_deadline} deadline / "
          f"{st.waves_drain} drain), occupancy {st.wave_occupancy:.2f}, "
          f"padding waste {st.padding_waste_frac:.2f}")
    print(f"[serve_align] executable cache: {st.cache_hits} hits, "
          f"{st.cache_misses} misses, "
          f"{eng.cache_traces() - traces0} fresh traces during replay")
    return 0 if report.n_failed == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
