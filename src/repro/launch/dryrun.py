import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  512 placeholder host devices back the production
# meshes: 16x16 single-pod and 2x16x16 multi-pod.

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.analysis.hlo import collective_bytes
from repro.configs import ARCH_NAMES, get_config, wfa_paper
from repro.distributed.compat import cost_analysis
from repro.launch.lowering import build_lm_cell, build_wfa_cell, lower_cell
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models.common import SHAPES, model_flops

RESULTS_DEFAULT = "results/dryrun/cells.jsonl"


def mesh_tag(multi_pod: bool) -> str:
    return "pod2-2x16x16" if multi_pod else "pod1-16x16"


def _leaf_device_bytes(sds, sharding) -> int:
    shard = sharding.shard_shape(sds.shape)
    return int(np.prod(shard, dtype=np.int64)) * jax.numpy.dtype(sds.dtype).itemsize


def analytic_device_bytes(cell) -> int:
    total = 0

    def walk(sds_tree, sh_tree):
        nonlocal total
        leaves_s = jax.tree.leaves(sds_tree)
        leaves_h = jax.tree.leaves(
            sh_tree, is_leaf=lambda x: hasattr(x, "shard_shape"))
        for s, h in zip(leaves_s, leaves_h):
            total += _leaf_device_bytes(s, h)

    for arg, sh in zip(cell.args, cell.in_shardings):
        walk(arg, sh)
    return total


def _compile_and_measure(cell, mesh, n_dev) -> dict:
    t0 = time.time()
    lowered, _ = lower_cell(cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    out = {"lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2)}
    cost = cost_analysis(compiled)
    out["flops_per_device"] = float(cost.get("flops", -1.0))
    out["bytes_per_device"] = float(cost.get("bytes accessed", -1.0))
    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    out[f"mem_{attr}"] = int(v)
    except Exception as e:  # CPU backend may not implement it
        out["mem_error"] = repr(e)
    hlo = compiled.as_text()
    out["collectives"] = collective_bytes(hlo, n_dev)
    out["hlo_bytes"] = len(hlo)
    return out


def roofline_depths(cfg):
    """Three lowering depths for the per-layer extrapolation.

    Layers are identical stacked blocks, so the HLO roofline quantities are
    polynomial in depth: empirically EXACTLY quadratic (validated against a
    full 28-layer unrolled lowering to 4 significant digits — the small
    quadratic term is ~0.5% of the linear term at production depths; see
    DESIGN.md §7).  Three scan-UNROLLED shallow lowerings determine the
    quadratic, evaluated at the production depth.  Hybrids use depths
    congruent to the production depth mod the shared-block period so the
    ragged tail segment appears identically in every point; MoE keeps its
    dense head layers fixed.
    """
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        r = cfg.n_layers % e
        return r + e, r + 2 * e, r + 4 * e
    head = cfg.first_k_dense if cfg.is_moe else 0
    return head + 2, head + 4, head + 8


def _fit_quadratic(depths, values, L):
    """Exact quadratic through three (depth, value) points, evaluated at L."""
    a = np.array([[1.0, d, d * d] for d in depths])
    coef = np.linalg.solve(a, np.asarray(values, float))
    return float(coef @ np.array([1.0, L, L * L]))


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             mode: str = "memory", skip_reason: str = "",
             exact_depth: bool = False) -> dict:
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag(multi_pod),
        "pass": mode,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    if skip_reason:
        record.update(status="skipped", reason=skip_reason)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh_devices(mesh)
    try:
        if arch == "wfa-paper":
            ef = {"fig1_e2": 0.02, "fig1_e4": 0.04}[shape_name]
            cell = build_wfa_cell(wfa_paper, mesh, edit_frac=ef)
            record["model_flops"] = 0.0
            record.update(_compile_and_measure(cell, mesh, n_dev))
            record["analytic_arg_bytes_per_device"] = analytic_device_bytes(cell)
            record.update(status="ok", n_devices=n_dev,
                          **{f"meta_{k}": v for k, v in cell.meta.items()})
            return record

        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        record["model_flops"] = model_flops(cfg, shape)
        record["param_count"] = cfg.param_count()
        record["active_param_count"] = cfg.active_param_count()

        depths = roofline_depths(cfg)
        if mode == "memory" or exact_depth or cfg.n_layers <= depths[-1]:
            cell = build_lm_cell(cfg, shape, mesh, mode=mode)
            record.update(_compile_and_measure(cell, mesh, n_dev))
            record["analytic_arg_bytes_per_device"] = analytic_device_bytes(cell)
            record.update(status="ok", n_devices=n_dev,
                          **{f"meta_{k}": v for k, v in cell.meta.items()})
            return record

        # roofline pass: three shallow scan-unrolled lowerings -> quadratic
        points = []
        for L in depths:
            cell = build_lm_cell(cfg.replace(n_layers=L), shape, mesh,
                                 mode="roofline")
            m = _compile_and_measure(cell, mesh, n_dev)
            m["n_layers"] = L
            points.append(m)
        Lf = cfg.n_layers
        record["flops_per_device"] = _fit_quadratic(
            depths, [p["flops_per_device"] for p in points], Lf)
        record["bytes_per_device"] = _fit_quadratic(
            depths, [p["bytes_per_device"] for p in points], Lf)
        keys = set()
        for p in points:
            keys |= set(p["collectives"])
        coll = {k: max(0.0, _fit_quadratic(
                    depths, [p["collectives"].get(k, 0.0) for p in points], Lf))
                for k in keys}
        record["collectives"] = coll
        record["roofline_points"] = [
            {k: v for k, v in p.items() if not isinstance(v, dict)}
            for p in points]
        record["extrapolated_from"] = list(depths)
        record["compile_s"] = round(sum(p["compile_s"] for p in points), 2)
        record["lower_s"] = round(sum(p["lower_s"] for p in points), 2)
        record.update(status="ok", n_devices=n_dev,
                      **{f"meta_{k}": v for k, v in cell.meta.items()})
    except Exception:
        record.update(status="error", error=traceback.format_exc()[-4000:])
    return record


def applicable_cells(archs, shapes, meshes, passes):
    for arch in archs:
        if arch == "wfa-paper":
            arch_shapes = ["fig1_e2", "fig1_e4"]
        else:
            arch_shapes = list(SHAPES)
        for shape_name in arch_shapes:
            if shapes and shape_name not in shapes:
                continue
            skip = ""
            if arch != "wfa-paper":
                cfg = get_config(arch)
                if (shape_name == "long_500k"
                        and not cfg.supports_long_context):
                    skip = ("quadratic full attention at 512k ctx; runs only "
                            "for ssm/hybrid archs (DESIGN.md §8)")
            for multi_pod in meshes:
                for mode in (passes if arch != "wfa-paper" else ["memory"]):
                    # roofline numbers come from the single-pod mesh only
                    if mode == "roofline" and multi_pod:
                        continue
                    yield arch, shape_name, multi_pod, mode, skip


def load_done(path):
    done = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                done[(r["arch"], r["shape"], r["mesh"],
                      r.get("pass", "memory"))] = r.get("status")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", nargs="*", default=None,
                    help="arch ids (default: all 10 + wfa-paper)")
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--force", action="store_true",
                    help="re-run cells already recorded")
    ap.add_argument("--retry-errors", action="store_true")
    ap.add_argument("--pass", dest="passes", nargs="*",
                    choices=["memory", "roofline"],
                    default=["memory", "roofline"])
    args = ap.parse_args(argv)

    archs = args.arch or (ARCH_NAMES + ["wfa-paper"])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = load_done(args.out)

    n_ok = n_err = n_skip = 0
    for arch, shape_name, multi_pod, mode, skip in applicable_cells(
            archs, args.shape, meshes, args.passes):
        key = (arch, shape_name, mesh_tag(multi_pod), mode)
        prev = done.get(key)
        if prev is not None and not args.force:
            if not (args.retry_errors and prev == "error"):
                continue
        print(f"[dryrun] {key} ...", flush=True)
        rec = run_cell(arch, shape_name, multi_pod, mode=mode,
                       skip_reason=skip)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = rec["status"]
        n_ok += status == "ok"
        n_err += status == "error"
        n_skip += status == "skipped"
        extra = ""
        if status == "ok":
            extra = (f" flops/dev={rec['flops_per_device']:.3e}"
                     f" coll={rec['collectives']['total']:.3e}B"
                     f" compile={rec['compile_s']}s")
        elif status == "error":
            extra = " " + rec["error"].strip().splitlines()[-1][:160]
        print(f"[dryrun] {key} -> {status}{extra}", flush=True)

    print(f"[dryrun] done: ok={n_ok} err={n_err} skip={n_skip}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
