"""CLI over ``repro.obs.analyze``: phase tables, slow waves, diffs.

Reads the Chrome-trace JSON that ``--trace-out`` / the flight recorder
write, or ``results/perf/BENCH_*.json`` snapshots, and prints the
paper-style accounting:

* phase table — scatter/kernel/gather/traceback totals mapped onto the
  paper's Fig. 1 transfer/kernel/retrieve split
* pipeline report — occupancy, bubbles (idle gaps between waves),
  host/device overlap fraction
* top-k slowest kernel waves with their args
* per-request latency breakdown from flow critical paths
* ``--diff A B`` — A/B attribution: which (suite, phase) moved

Examples::

    python -m repro.launch.obs_report results/trace/bench_smoke.json
    python -m repro.launch.obs_report results/trace/a.json --top-k 16
    python -m repro.launch.obs_report --diff results/perf/BENCH_a.json \\
        results/perf/BENCH_b.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, List, Optional

from repro.obs import analyze

__all__ = ["main"]


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def _print_phase_table(pt: analyze.PhaseTable) -> None:
    print("phase table (paper Fig. 1 split)")
    print(f"  {'phase':<10} {'paper phase':<26} {'total':>10} "
          f"{'count':>6} {'mean':>10} {'max':>10} {'share':>7}")
    for ph in analyze.PHASE_ORDER:
        if ph not in pt.stats:
            continue
        st = pt.stats[ph]
        paper = analyze.PAPER_PHASE.get(ph, "")
        print(f"  {ph:<10} {paper:<26} {_fmt_us(st.total_us):>10} "
              f"{st.count:>6} {_fmt_us(st.mean_us):>10} "
              f"{_fmt_us(st.max_us):>10} {pt.share(ph):>6.1%}")
    print(f"  accounted {_fmt_us(pt.accounted_us)} over "
          f"{_fmt_us(pt.wall_us)} wall")


def _print_pipeline(rep: analyze.PipelineReport) -> None:
    print("pipeline")
    print(f"  device busy {_fmt_us(rep.busy_us)} / span "
          f"{_fmt_us(rep.span_us)} (occupancy {rep.occupancy:.1%}, "
          f"mean inflight {rep.mean_inflight:.2f})")
    print(f"  bubbles: {len(rep.bubbles)} totalling "
          f"{_fmt_us(rep.bubble_us)}")
    for b in sorted(rep.bubbles, key=lambda b: b.dur_us, reverse=True)[:5]:
        print(f"    at {_fmt_us(b.ts)}: idle {_fmt_us(b.dur_us)}")
    print(f"  host packing/gather {_fmt_us(rep.host_busy_us)}, "
          f"{rep.host_overlap_frac:.1%} overlapped with device")


def _print_slow_waves(trace: analyze.Trace, k: int) -> None:
    waves = analyze.slow_waves(trace, k=k)
    if not waves:
        return
    print(f"top-{len(waves)} slow kernel waves")
    for s in waves:
        extra = " ".join(f"{k_}={v}" for k_, v in sorted(s.args.items()))
        print(f"  {_fmt_us(s.dur):>10} at {_fmt_us(s.ts)}  {extra}")


def _print_flows(trace: analyze.Trace) -> None:
    paths = analyze.critical_paths(trace)
    if not paths:
        return
    lats = sorted(p.latency_us for p in paths)

    def q(p: float) -> float:
        i = min(len(lats) - 1, int(p * len(lats)))
        return lats[i]

    print(f"request critical paths ({len(paths)} flows)")
    print(f"  latency p50 {_fmt_us(q(0.50))}  p95 {_fmt_us(q(0.95))}  "
          f"max {_fmt_us(lats[-1])}")
    seg_dur: Dict[str, List[float]] = {}
    seg_wait: Dict[str, List[float]] = {}
    for p in paths:
        for s in p.segments:
            seg_dur.setdefault(s.name, []).append(s.dur_us)
            seg_wait.setdefault(s.name, []).append(s.wait_us)
    for name in sorted(seg_dur):
        print(f"  {name:<22} mean {_fmt_us(statistics.fmean(seg_dur[name])):>9}"
              f"  wait {_fmt_us(statistics.fmean(seg_wait[name])):>9}"
              f"  n={len(seg_dur[name])}")


def _load_rows(path: str) -> Optional[Dict[str, float]]:
    """BENCH snapshot → name→value map, or None if not a snapshot."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "rows" not in doc:
        return None
    return {r["name"]: float(r["us_per_call"]) for r in doc["rows"]}


def _report_one(path: str, top_k: int, assert_phases: bool) -> int:
    trace = analyze.Trace.from_file(path)
    pt = analyze.phase_accounting(trace)
    print(f"== {path} ==")
    _print_phase_table(pt)
    _print_pipeline(analyze.pipeline_analysis(trace))
    _print_slow_waves(trace, top_k)
    _print_flows(trace)
    if assert_phases and pt.is_empty():
        print("ERROR: empty phase table (no wave.* spans in trace)",
              file=sys.stderr)
        return 1
    return 0


def _diff(path_a: str, path_b: str) -> int:
    rows_a, rows_b = _load_rows(path_a), _load_rows(path_b)
    print(f"== diff {path_a} -> {path_b} ==")
    if rows_a is not None and rows_b is not None:
        deltas = analyze.diff_rows(rows_a, rows_b)
        if not deltas:
            print("no common rows")
            return 1
        print(f"  {'row':<34} {'a':>12} {'b':>12} {'ratio':>8}")
        for d in deltas[:20]:
            print(f"  {d.name:<34} {d.a:>12.4g} {d.b:>12.4g} "
                  f"{d.ratio:>8.3f}")
        worst = deltas[0]
        print(f"biggest mover: suite={worst.suite} phase={worst.phase} "
              f"({worst.a:.4g} -> {worst.b:.4g}, {worst.ratio:.3f}x)")
        return 0
    if rows_a is None and rows_b is None:
        ta = analyze.Trace.from_file(path_a)
        tb = analyze.Trace.from_file(path_b)
        deltas = analyze.diff_phase_tables(analyze.phase_accounting(ta),
                                           analyze.phase_accounting(tb))
        if not deltas:
            print("no phases in either trace")
            return 1
        print(f"  {'phase':<12} {'a':>12} {'b':>12} {'ratio':>8}")
        for d in deltas:
            print(f"  {d.phase:<12} {_fmt_us(d.a_us):>12} "
                  f"{_fmt_us(d.b_us):>12} {d.ratio:>8.3f}")
        worst = deltas[0]
        print(f"biggest mover: phase={worst.phase} "
              f"({_fmt_us(worst.a_us)} -> {_fmt_us(worst.b_us)}, "
              f"{worst.ratio:.3f}x)")
        return 0
    print("ERROR: --diff needs two traces or two BENCH snapshots, "
          "not one of each", file=sys.stderr)
    return 2


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report",
        description="Analyze repro trace captures / bench snapshots.")
    ap.add_argument("paths", nargs="+",
                    help="trace JSON (or two BENCH_*.json with --diff)")
    ap.add_argument("--diff", action="store_true",
                    help="A/B attribution between exactly two captures")
    ap.add_argument("--top-k", type=int, default=8,
                    help="slow waves to list (default 8)")
    ap.add_argument("--assert-phases", action="store_true",
                    help="exit 1 if the phase table is empty (CI smoke)")
    args = ap.parse_args(argv)

    if args.diff:
        if len(args.paths) != 2:
            ap.error("--diff takes exactly two paths")
        return _diff(args.paths[0], args.paths[1])
    rc = 0
    for p in args.paths:
        rc = max(rc, _report_one(p, args.top_k, args.assert_phases))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
