"""Alignment launcher — the paper's pipeline end-to-end.

Generates the paper's workload (read pairs at edit threshold E) and streams
it through :meth:`AlignmentEngine.stream`: read-pair chunks are submitted as
they are produced, host-side packing of the next wave overlaps the in-flight
device kernel (the paper's transfer/compute overlap — its 4.87x-with vs
37.4x-without transfer gap), and scores are gathered out of order via
``as_completed()``.  ``--mode sync`` runs the blocking ``align()`` path
instead; ``--mode both`` runs the two back-to-back and reports the overlap
win directly.  Throughput is reported both ways the paper does: *Total*
(with host<->device transfers) and *Kernel* (alignment only).
``--backend ref|ring|kernel|shardmap`` selects any registered backend
(``repro.core.backends``).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import wfa_paper
from repro.core.backends import available_backends, get_backend
from repro.core.engine import AlignmentEngine
from repro.core.gotoh import gotoh_score_vec
from repro.core.session import run_streamed
from repro.data.reads import ReadPairSpec, generate_pairs


def _run_sync(engine, P, plen, T, tlen):
    t0 = time.perf_counter()
    res = engine.align_packed(P, plen, T, tlen)
    return res.scores, res.stats, time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=4096)
    ap.add_argument("--read-len", type=int, default=wfa_paper.read_len)
    ap.add_argument("--edit-frac", type=float, default=wfa_paper.edit_frac)
    ap.add_argument("--backend", choices=available_backends(),
                    default="ring")
    ap.add_argument("--mode", choices=("stream", "sync", "both"),
                    default="stream",
                    help="pipelined session (default), blocking align(), "
                         "or both back-to-back")
    ap.add_argument("--submit-pairs", type=int, default=None,
                    help="pairs per session submit (streaming granularity; "
                         "default: --chunk-pairs)")
    ap.add_argument("--inflight", type=int, default=4,
                    help="max in-flight waves (session backpressure bound)")
    ap.add_argument("--chunk-pairs", type=int, default=1024,
                    help="pairs per device wave (same for sync and stream, "
                         "so --mode both compares equal work)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable length-bucketed batching")
    ap.add_argument("--no-adaptive", action="store_true",
                    help="disable the exact-bound overflow recovery pass")
    ap.add_argument("--verify", type=int, default=0,
                    help="cross-check N scores against the Gotoh oracle")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    pen = wfa_paper.pen
    spec = ReadPairSpec(n_pairs=args.pairs, read_len=args.read_len,
                        edit_frac=args.edit_frac, seed=args.seed)
    t0 = time.perf_counter()
    P, plen, T, tlen = generate_pairs(spec)
    print(f"[align] generated {args.pairs} pairs of ~{args.read_len}bp "
          f"(E={args.edit_frac:.0%}) in {time.perf_counter() - t0:.2f}s",
          flush=True)

    mesh = None
    if get_backend(args.backend).needs_mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    engine = AlignmentEngine(pen, backend=args.backend,
                             edit_frac=args.edit_frac,
                             chunk_pairs=args.chunk_pairs, mesh=mesh,
                             bucket_by_length=not args.no_bucket,
                             adaptive=not args.no_adaptive)
    submit_pairs = args.submit_pairs or args.chunk_pairs
    # warmup with the identical batch so the measured run is steady-state
    # serving (all executables cached, 0 retraces); a submit-sized chunk and
    # the residual chunk warm the streamed shapes when they differ
    engine.align_packed(P, plen, T, tlen)
    engine.align_packed(P[:submit_pairs], plen[:submit_pairs],
                        T[:submit_pairs], tlen[:submit_pairs])
    rem = args.pairs % submit_pairs
    if rem:
        engine.align_packed(P[-rem:], plen[-rem:], T[-rem:], tlen[-rem:])

    runs = []
    if args.mode in ("sync", "both"):
        runs.append(("sync", _run_sync(engine, P, plen, T, tlen)))
    if args.mode in ("stream", "both"):
        runs.append(("stream",
                     run_streamed(engine, P, plen, T, tlen,
                                  submit_pairs=submit_pairs,
                                  max_inflight_waves=args.inflight)))

    scores = None
    for mode, (sc, st, wall) in runs:
        if scores is None:
            scores = sc
        elif not np.array_equal(scores, sc):
            print("[align] ERROR: sync and stream scores differ")
            return 1
        pim = st.pim
        extra = ""
        if mode == "stream":
            extra = (f" submits={st.n_submits} waves={st.n_waves} "
                     f"inflight<={st.max_inflight} (peak {st.peak_inflight})")
        print(f"[align] {mode}: backend={args.backend} "
              f"workers={pim.n_workers} buckets={st.n_buckets} "
              f"cache={st.cache_hits}h/{st.cache_misses}m "
              f"retraces={st.n_traces}{extra}")
        print(f"[align] {mode}: scatter {pim.t_scatter:.3f}s  "
              f"kernel {pim.t_kernel:.3f}s  gather {pim.t_gather:.3f}s  "
              f"wall {wall:.3f}s")
        print(f"[align] {mode}: throughput Total  = "
              f"{args.pairs / wall:,.0f} pairs/s")
        print(f"[align] {mode}: throughput Kernel = "
              f"{pim.throughput_kernel():,.0f} pairs/s")
        print(f"[align] {mode}: transfers: {pim.bytes_in / 1e6:.1f} MB in, "
              f"{pim.bytes_out / 1e6:.3f} MB out")
        found = sc >= 0
        print(f"[align] {mode}: scores: mean={sc[found].mean():.2f} "
              f"max={sc[found].max()} overflow={st.n_overflow} "
              f"recovered={st.n_recovered} unresolved={int((~found).sum())}")
    if args.mode == "both":
        t_sync = runs[0][1][2]
        t_stream = runs[1][1][2]
        print(f"[align] stream vs sync wall: {t_sync:.3f}s -> {t_stream:.3f}s "
              f"({t_sync / t_stream:.2f}x)")

    if args.verify:
        n = min(args.verify, args.pairs)
        for i in range(n):
            g = gotoh_score_vec(P[i, : plen[i]], T[i, : tlen[i]], pen)
            if scores[i] >= 0 and scores[i] != g:
                print(f"[align] MISMATCH pair {i}: wfa={scores[i]} gotoh={g}")
                return 1
        print(f"[align] verified {n} scores against Gotoh oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
