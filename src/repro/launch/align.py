"""Alignment launcher — the paper's pipeline end-to-end.

Generates the paper's workload (read pairs at edit threshold E), runs the
unified :class:`~repro.core.engine.AlignmentEngine` (scatter -> align ->
gather, length-bucketed, executable-cached, overflow-recovering) and reports
throughput both ways the paper does: *Total* (with host<->device transfers)
and *Kernel* (alignment only).  ``--backend ref|ring|kernel|shardmap``
selects any registered backend (``repro.core.backends``).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import wfa_paper
from repro.core.backends import available_backends, get_backend
from repro.core.engine import AlignmentEngine
from repro.core.gotoh import gotoh_score_vec
from repro.data.reads import ReadPairSpec, generate_pairs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=4096)
    ap.add_argument("--read-len", type=int, default=wfa_paper.read_len)
    ap.add_argument("--edit-frac", type=float, default=wfa_paper.edit_frac)
    ap.add_argument("--backend", choices=available_backends(),
                    default="ring")
    ap.add_argument("--chunk-pairs", type=int, default=1 << 14)
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable length-bucketed batching")
    ap.add_argument("--no-adaptive", action="store_true",
                    help="disable the exact-bound overflow recovery pass")
    ap.add_argument("--verify", type=int, default=0,
                    help="cross-check N scores against the Gotoh oracle")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    pen = wfa_paper.pen
    spec = ReadPairSpec(n_pairs=args.pairs, read_len=args.read_len,
                        edit_frac=args.edit_frac, seed=args.seed)
    t0 = time.perf_counter()
    P, plen, T, tlen = generate_pairs(spec)
    print(f"[align] generated {args.pairs} pairs of ~{args.read_len}bp "
          f"(E={args.edit_frac:.0%}) in {time.perf_counter() - t0:.2f}s",
          flush=True)

    mesh = None
    if get_backend(args.backend).needs_mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    engine = AlignmentEngine(pen, backend=args.backend,
                             edit_frac=args.edit_frac,
                             chunk_pairs=args.chunk_pairs, mesh=mesh,
                             bucket_by_length=not args.no_bucket,
                             adaptive=not args.no_adaptive)
    # warmup with the identical batch so the measured run is steady-state
    # serving (all executables cached, 0 retraces)
    engine.align_packed(P, plen, T, tlen)
    res = engine.align_packed(P, plen, T, tlen)
    scores, stats = res.scores, res.stats.pim

    print(f"[align] backend={args.backend} workers={stats.n_workers} "
          f"buckets={res.stats.n_buckets} "
          f"cache={res.stats.cache_hits}h/{res.stats.cache_misses}m "
          f"retraces={res.stats.n_traces}")
    print(f"[align] scatter {stats.t_scatter:.3f}s  kernel {stats.t_kernel:.3f}s"
          f"  gather {stats.t_gather:.3f}s")
    print(f"[align] throughput Total  = {stats.throughput_total():,.0f} pairs/s")
    print(f"[align] throughput Kernel = {stats.throughput_kernel():,.0f} pairs/s")
    print(f"[align] transfers: {stats.bytes_in/1e6:.1f} MB in, "
          f"{stats.bytes_out/1e6:.3f} MB out")
    found = scores >= 0
    print(f"[align] scores: mean={scores[found].mean():.2f} "
          f"max={scores[found].max()} "
          f"overflow={res.stats.n_overflow} "
          f"recovered={res.stats.n_recovered} "
          f"unresolved={int((~found).sum())}")

    if args.verify:
        n = min(args.verify, args.pairs)
        for i in range(n):
            g = gotoh_score_vec(P[i, : plen[i]], T[i, : tlen[i]], pen)
            if scores[i] >= 0 and scores[i] != g:
                print(f"[align] MISMATCH pair {i}: wfa={scores[i]} gotoh={g}")
                return 1
        print(f"[align] verified {n} scores against Gotoh oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
