"""Alignment launcher — the paper's pipeline end-to-end.

Generates the paper's workload (read pairs at edit threshold E) and streams
it through :meth:`AlignmentEngine.stream`: read-pair chunks are submitted as
they are produced, host-side packing of the next wave overlaps the in-flight
device kernel (the paper's transfer/compute overlap — its 4.87x-with vs
37.4x-without transfer gap), and results are gathered out of order via
``as_completed()``.  ``--mode sync`` runs the blocking ``align()`` path
instead; ``--mode both`` runs the two back-to-back and reports the overlap
win directly.  Throughput is reported both ways the paper does: *Total*
(with host<->device transfers) and *Kernel* (alignment only).
``--backend ref|ring|kernel|shardmap`` selects any registered backend
(``repro.core.backends``).

``--output`` selects the result pathway (the read-mapping scenario of the
follow-up framework paper, arXiv:2208.01243):

* ``score`` — costs only (the throughput story);
* ``cigar`` — full alignments via each backend's trace variant (packed
  backtrace on ``ring``/``kernel``/``shardmap``); reports identity stats
  and the traceback's share of wall clock; ``--trace bidir`` switches the
  traceback to the meet-in-the-middle BiWFA recursion (``repro.biwfa``) —
  exact CIGARs in O(s) trace memory, the right choice for noisy long
  reads (pair it with ``--heuristic zdrop``);
* ``sam``  — additionally writes SAM-style records (``--sam-out``, default
  stdout): the mutated mate (*text*) is the read, the sampled reference
  read (*pattern*) is the reference, so insert/delete op codes map onto
  SAM ``I``/``D`` directly.

``--penalties edit|linear:x,e|affine:x,o,e|x,o,e`` selects the scoring
model (``core.scoring``: edit/linear run the cheaper one-matrix
recurrence) and ``--heuristic adaptive:...|zdrop:...`` enables WFA-adaptive
wavefront pruning (approximate scores; ``--verify`` switches to an
upper-bound check).  ``--reads``/``--refs`` feed real FASTA/FASTQ(.gz)
pair files through the identical pipeline instead of the synthetic
generator.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.configs import wfa_paper
from repro.core import cigar as cigar_mod
from repro.core import scoring
from repro.core.backends import available_backends, get_backend
from repro.core.engine import AlignmentEngine
from repro.core.gotoh import gotoh_score_vec, score_cigar
from repro.core.session import run_streamed
from repro.data.io import load_pair_files
from repro.data.reads import ReadPairSpec, generate_pairs


def _run_sync(engine, P, plen, T, tlen, output):
    t0 = time.perf_counter()
    res = engine.align_packed(P, plen, T, tlen, output=output)
    return res.scores, res.cigars, res.stats, time.perf_counter() - t0


def write_sam(out, scores, cigars, plen, T, tlen, cl=None) -> None:
    """Full SAM stream via the shared ``repro.mapping.sam`` writer: proper
    @HD/@SQ/@PG header (one @SQ per reference read) + one record per pair.

    The mate (*text*) maps onto reference read i at POS 1, MAPQ 255
    (unavailable — there is no candidate ranking here).  Unresolved pairs
    (score < 0: no alignment produced) are emitted as proper unmapped
    records — FLAG 4, no position, no alignment score — not as mapped
    records with a placeholder CIGAR.
    """
    from repro.mapping.extend import Mapping
    from repro.mapping.sam import (header_lines, mapping_record,
                                   unmapped_record)
    names = [f"ref{i}" for i in range(len(scores))]
    for line in header_lines(names, [int(l) for l in plen],
                             program="repro.launch.align", cl=cl):
        out.write(line + "\n")
    for i, (s, ops) in enumerate(zip(scores, cigars)):
        text = T[i, : int(tlen[i])]
        if int(s) < 0:
            line = unmapped_record(f"read{i}", text)
        else:
            m = Mapping(read_id=i, ref_id=i, pos=0, strand=0, mapq=255,
                        score=int(s), ops=ops)
            line = mapping_record(m, text, f"read{i}", f"ref{i}")
        out.write(line + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", type=int, default=4096)
    ap.add_argument("--read-len", type=int, default=wfa_paper.read_len)
    ap.add_argument("--edit-frac", type=float, default=wfa_paper.edit_frac)
    ap.add_argument("--reads", default=None, metavar="PATH",
                    help="FASTA/FASTQ(.gz) of reads (the text side); "
                         "with --refs, replaces the synthetic generator")
    ap.add_argument("--refs", default=None, metavar="PATH",
                    help="FASTA/FASTQ(.gz) of references (the pattern "
                         "side), paired record-by-record with --reads")
    ap.add_argument("--penalties", default=None, metavar="SPEC",
                    help="penalty model: 'edit', 'linear:x,e', "
                         "'affine:x,o,e' or the bare gap-affine triple "
                         "'x,o,e' (default: the paper's affine "
                         f"{wfa_paper.pen.x},{wfa_paper.pen.o},"
                         f"{wfa_paper.pen.e})")
    ap.add_argument("--heuristic", default="none", metavar="SPEC",
                    help="wavefront heuristic: 'none' (exact, default), "
                         "'adaptive[:min_wf_len,max_distance_diff]' "
                         "(WFA-adaptive band) or 'zdrop[:z]'; results are "
                         "approximate")
    ap.add_argument("--backend", choices=available_backends(),
                    default="ring")
    ap.add_argument("--mode", choices=("stream", "sync", "both"),
                    default="stream",
                    help="pipelined session (default), blocking align(), "
                         "or both back-to-back")
    ap.add_argument("--output", choices=("score", "cigar", "sam"),
                    default="score",
                    help="scores only (default), full CIGAR alignments, "
                         "or SAM-style records")
    ap.add_argument("--trace", choices=("packed", "bidir"),
                    default="packed",
                    help="traceback variant for --output cigar/sam: "
                         "'packed' (2-bit backtrace, O(s^2) trace memory) "
                         "or 'bidir' (BiWFA meet-in-the-middle recursion, "
                         "O(s) trace memory — use for long reads)")
    ap.add_argument("--sam-out", default="-", metavar="PATH",
                    help="where --output sam writes records (default "
                         "stdout)")
    ap.add_argument("--submit-pairs", type=int, default=None,
                    help="pairs per session submit (streaming granularity; "
                         "default: --chunk-pairs)")
    ap.add_argument("--inflight", type=int, default=4,
                    help="max in-flight waves (session backpressure bound)")
    ap.add_argument("--chunk-pairs", type=int, default=1024,
                    help="pairs per device wave (same for sync and stream, "
                         "so --mode both compares equal work)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable length-bucketed batching")
    ap.add_argument("--no-adaptive", action="store_true",
                    help="disable the exact-bound overflow recovery pass")
    ap.add_argument("--verify", type=int, default=0,
                    help="cross-check N scores (and CIGAR re-scores) "
                         "against the Gotoh oracle")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture the measured runs as Chrome trace-event "
                         "JSON (open in ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the measured runs in jax.profiler.trace")
    args = ap.parse_args(argv)

    pen = (scoring.parse_penalties(args.penalties)
           if args.penalties else scoring.as_model(wfa_paper.pen))
    heur = scoring.parse_heuristic(args.heuristic)
    out_mode = "score" if args.output == "score" else "cigar"
    # SAM on stdout must stay a valid SAM stream: move the progress report
    # to stderr so `--output sam > out.sam` parses
    sam_to_stdout = args.output == "sam" and args.sam_out == "-"
    log_file = sys.stderr if sam_to_stdout else sys.stdout

    def log(*a, **kw):
        print(*a, file=log_file, flush=True, **kw)

    if (args.reads is None) != (args.refs is None):
        ap.error("--reads and --refs must be given together")
    t0 = time.perf_counter()
    if args.reads is not None:
        P, plen, T, tlen = load_pair_files(args.reads, args.refs,
                                           limit=args.pairs)
        args.pairs = int(P.shape[0])
        log(f"[align] loaded {args.pairs} read pairs from {args.reads} / "
            f"{args.refs} in {time.perf_counter() - t0:.2f}s")
    else:
        spec = ReadPairSpec(n_pairs=args.pairs, read_len=args.read_len,
                            edit_frac=args.edit_frac, seed=args.seed)
        P, plen, T, tlen = generate_pairs(spec)
        log(f"[align] generated {args.pairs} pairs of ~{args.read_len}bp "
            f"(E={args.edit_frac:.0%}) in {time.perf_counter() - t0:.2f}s")
    log(f"[align] scoring: {pen} heuristic={heur}"
        + (" (approximate scores)" if not heur.exact else ""))

    mesh = None
    if get_backend(args.backend).needs_mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    engine = AlignmentEngine(pen, backend=args.backend,
                             edit_frac=args.edit_frac, heuristic=heur,
                             chunk_pairs=args.chunk_pairs, mesh=mesh,
                             bucket_by_length=not args.no_bucket,
                             adaptive=not args.no_adaptive,
                             trace_variant=args.trace)
    submit_pairs = args.submit_pairs or args.chunk_pairs
    # warmup with the identical batch so the measured run is steady-state
    # serving (all executables cached, 0 retraces); a submit-sized chunk and
    # the residual chunk warm the streamed shapes when they differ
    engine.align_packed(P, plen, T, tlen, output=out_mode)
    engine.align_packed(P[:submit_pairs], plen[:submit_pairs],
                        T[:submit_pairs], tlen[:submit_pairs],
                        output=out_mode)
    rem = args.pairs % submit_pairs
    if rem:
        engine.align_packed(P[-rem:], plen[-rem:], T[-rem:], tlen[-rem:],
                            output=out_mode)

    runs = []
    with obs.capture_trace(args.trace_out), \
            obs.profile.profile(args.profile):
        if args.mode in ("sync", "both"):
            runs.append(("sync",
                         _run_sync(engine, P, plen, T, tlen, out_mode)))
        if args.mode in ("stream", "both"):
            runs.append(("stream",
                         run_streamed(engine, P, plen, T, tlen,
                                      submit_pairs=submit_pairs,
                                      max_inflight_waves=args.inflight,
                                      output=out_mode)))
    if args.trace_out:
        log(f"[align] trace -> {args.trace_out}")

    scores = cigars = None
    for mode, (sc, cg, st, wall) in runs:
        if scores is None:
            scores, cigars = sc, cg
        elif not np.array_equal(scores, sc):
            log("[align] ERROR: sync and stream scores differ")
            return 1
        pim = st.pim
        extra = ""
        if mode == "stream":
            extra = (f" submits={st.n_submits} waves={st.n_waves} "
                     f"inflight<={st.max_inflight} (peak {st.peak_inflight})")
        trace = (f" trace={args.trace}" if out_mode == "cigar" else "")
        log(f"[align] {mode}: backend={args.backend} output={out_mode}"
              f"{trace} "
              f"workers={pim.n_workers} buckets={st.n_buckets} "
              f"cache={st.cache_hits}h/{st.cache_misses}m "
              f"retraces={st.n_traces}{extra}")
        log(f"[align] {mode}: scatter {pim.t_scatter:.3f}s  "
              f"kernel {pim.t_kernel:.3f}s  gather {pim.t_gather:.3f}s  "
              f"wall {wall:.3f}s")
        log(f"[align] {mode}: throughput Total  = "
              f"{args.pairs / wall:,.0f} pairs/s")
        log(f"[align] {mode}: throughput Kernel = "
              f"{pim.throughput_kernel():,.0f} pairs/s")
        log(f"[align] {mode}: transfers: {pim.bytes_in / 1e6:.1f} MB in, "
              f"{pim.bytes_out / 1e6:.3f} MB out")
        found = sc >= 0
        log(f"[align] {mode}: scores: mean={sc[found].mean():.2f} "
              f"max={sc[found].max()} overflow={st.n_overflow} "
              f"recovered={st.n_recovered} unresolved={int((~found).sum())}")
        if cg is not None:
            # identity over resolved pairs only: an unresolved pair has no
            # alignment, not a perfect one
            ident = np.asarray([cigar_mod.cigar_identity(c)
                                for c, f in zip(cg, found) if f])
            cols = sum(len(c) for c in cg)
            log(f"[align] {mode}: cigars: {cols} alignment columns, "
                  f"identity mean={ident.mean():.4f} min={ident.min():.4f} "
                  f"(gather incl. traceback: {pim.t_gather:.3f}s)")
    if args.mode == "both":
        t_sync = runs[0][1][3]
        t_stream = runs[1][1][3]
        log(f"[align] stream vs sync wall: {t_sync:.3f}s -> {t_stream:.3f}s "
              f"({t_sync / t_stream:.2f}x)")

    if args.output == "sam":
        cl = "repro.launch.align " + " ".join(argv or sys.argv[1:])
        if args.sam_out == "-":
            write_sam(sys.stdout, scores, cigars, plen, T, tlen, cl=cl)
        else:
            with open(args.sam_out, "w") as f:
                write_sam(f, scores, cigars, plen, T, tlen, cl=cl)
            log(f"[align] wrote {args.pairs} SAM records to "
                  f"{args.sam_out}")

    if args.verify:
        n = min(args.verify, args.pairs)
        pen_triple = pen.as_penalties()
        for i in range(n):
            pa, ta = P[i, : plen[i]], T[i, : tlen[i]]
            g = gotoh_score_vec(pa, ta, pen_triple)
            # heuristic scores are an upper bound, not the exact optimum
            bad = (scores[i] != g if heur.exact else scores[i] < g)
            if scores[i] >= 0 and bad:
                log(f"[align] MISMATCH pair {i}: wfa={scores[i]} gotoh={g}")
                return 1
            if cigars is not None and scores[i] >= 0:
                cost, ci, cj, ok = score_cigar(cigars[i], pa, ta, pen_triple)
                # the CIGAR must re-score to the reported (possibly
                # approximate) cost — and to the oracle when exact
                if not ok or cost != scores[i]:
                    log(f"[align] CIGAR MISMATCH pair {i}: "
                          f"re-score={cost} wfa={scores[i]} ok={ok}")
                    return 1
        what = "scores + CIGARs" if cigars is not None else "scores"
        against = ("Gotoh oracle" if heur.exact
                   else "Gotoh oracle (upper-bound check: heuristic)")
        log(f"[align] verified {n} {what} against {against}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
