"""Training driver: data pipeline -> train_step -> checkpoint/restart loop.

Runs the real thing at whatever scale the host has (CPU here: smoke-size or
the examples' ~100M config); the production-mesh path is exercised by
``dryrun.py`` (same Cell construction).  Demonstrates the full
fault-tolerance loop: periodic async checkpoints, simulated failure,
restart-and-continue (bit-exact, verified by tests/test_checkpoint.py).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, smoke_config
from repro.distributed.fault import FailureInjector, StragglerMonitor
from repro.distributed.sharding import use_mesh
from repro.data.tokens import TokenStreamSpec, batch_for_step
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelConfig, ShapeSpec
from repro.models.registry import get_model_fns
from repro.optim import compression
from repro.optim.adamw import AdamWConfig


def example_100m(vocab: int = 8192) -> ModelConfig:
    """~100M-param dense decoder for the end-to-end example run."""
    return ModelConfig(
        name="example-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab_size=vocab, qk_norm=True, tie_embeddings=True,
        remat_policy="dots", microbatch_tokens=1 << 30)


def _grad_transform(kind: Optional[str]):
    if kind in (None, "none"):
        return None
    if kind == "bf16":
        return lambda g: compression.decompress_bf16(compression.compress_bf16(g))
    if kind == "int8":
        return lambda g: compression.decompress_int8(compression.compress_int8(g))
    raise ValueError(kind)


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq_len: int,
          opt_cfg: Optional[AdamWConfig] = None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, resume: bool = False,
          fail_at_step: Optional[int] = None, grad_compress: Optional[str] = None,
          seed: int = 0, log_every: int = 10, mesh=None):
    """Returns (final state, list of per-step losses)."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps,
                                     warmup_steps=max(1, steps // 20))
    fns = get_model_fns(cfg)
    mesh = mesh if mesh is not None else make_host_mesh()
    spec = TokenStreamSpec(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           global_batch=global_batch, seed=seed)
    injector = FailureInjector(fail_at_step)
    monitor = StragglerMonitor(n_workers=1)
    writer = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None

    with mesh, use_mesh(mesh):
        state, _ = fns.init_train_state(cfg, jax.random.key(seed))
        start_step = 0
        if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            state, start_step = ckpt.restore(ckpt_dir, state)
            start_step += 1
            print(f"[train] resumed from step {start_step - 1}", flush=True)

        step_fn = jax.jit(fns.make_train_step(
            cfg, opt_cfg, n_micro=1, grad_transform=_grad_transform(grad_compress)),
            donate_argnums=(0,))

        losses = []
        for step in range(start_step, steps):
            batch = batch_for_step(spec, step)
            if cfg.family == "encdec":
                batch["frames"] = np.zeros(
                    (global_batch, cfg.enc_frames, cfg.d_model), np.float32)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            monitor.record(0, time.perf_counter() - t0)
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            if writer and (step + 1) % ckpt_every == 0:
                writer.save(step, state, extra_meta={"arch": cfg.name})
            injector.check(step)  # may raise SimulatedFailure AFTER ckpt
        if writer:
            writer.save(steps - 1, state, extra_meta={"arch": cfg.name})
            writer.wait()
    return state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="example-100m",
                    help="arch id, 'example-100m', or '<id>-smoke'")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="raise a simulated node failure at this step")
    ap.add_argument("--grad-compress", choices=["none", "bf16", "int8"],
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.arch == "example-100m":
        cfg = example_100m()
    elif args.arch.endswith("-smoke"):
        cfg = smoke_config(args.arch[: -len("-smoke")])
    else:
        cfg = get_config(args.arch)

    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 20))
    try:
        train(cfg, steps=args.steps, global_batch=args.global_batch,
              seq_len=args.seq, opt_cfg=opt, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, resume=args.resume,
              fail_at_step=args.simulate_failure,
              grad_compress=args.grad_compress, seed=args.seed)
    except FailureInjector.SimulatedFailure as e:
        print(f"[train] {e} — restart with --resume to continue", flush=True)
        return 42
    return 0


if __name__ == "__main__":
    sys.exit(main())
