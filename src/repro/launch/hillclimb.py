import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must run before any jax import (same contract as dryrun.py).

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Each experiment = a dry-run cell + a named change (config overrides or a
cell variant).  Lowers, compiles, measures the same roofline quantities as
dryrun.py, and appends to results/perf/experiments.jsonl so every
hypothesis -> change -> before -> after cycle is on the record.

    PYTHONPATH=src python -m repro.launch.hillclimb --exp wfa_shardmap
    PYTHONPATH=src python -m repro.launch.hillclimb --list
"""

import argparse
import json
import sys
import time
import traceback

from repro.configs import get_config, wfa_paper
from repro.launch.dryrun import _compile_and_measure, _fit_quadratic, roofline_depths
from repro.launch.lowering import build_lm_cell, build_wfa_cell
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models.common import SHAPES, model_flops

RESULTS = "results/perf/experiments.jsonl"


def measure_lm(arch: str, shape_name: str, overrides: dict, *,
               multi_pod: bool = False, zero: bool = True,
               mode: str = "roofline") -> dict:
    """Roofline-pass measurement (quadratic depth extrapolation) of an LM
    cell with config overrides applied."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh_devices(mesh)
    cfg = get_config(arch).replace(**overrides)
    shape = SHAPES[shape_name]
    rec = {"model_flops": model_flops(cfg, shape), "n_devices": n_dev}
    if mode == "memory":
        cell = build_lm_cell(cfg, shape, mesh, mode="memory", zero=zero)
        rec.update(_compile_and_measure(cell, mesh, n_dev))
        return rec
    depths = roofline_depths(cfg)
    if cfg.n_layers <= depths[-1]:
        cell = build_lm_cell(cfg, shape, mesh, mode="roofline", zero=zero)
        rec.update(_compile_and_measure(cell, mesh, n_dev))
        return rec
    points = []
    for L in depths:
        cell = build_lm_cell(cfg.replace(n_layers=L), shape, mesh,
                             mode="roofline", zero=zero)
        m = _compile_and_measure(cell, mesh, n_dev)
        m["n_layers"] = L
        points.append(m)
    Lf = cfg.n_layers
    rec["flops_per_device"] = _fit_quadratic(
        depths, [p["flops_per_device"] for p in points], Lf)
    rec["bytes_per_device"] = _fit_quadratic(
        depths, [p["bytes_per_device"] for p in points], Lf)
    keys = set()
    for p in points:
        keys |= set(p["collectives"])
    rec["collectives"] = {
        k: max(0.0, _fit_quadratic(depths,
                                   [p["collectives"].get(k, 0.0)
                                    for p in points], Lf))
        for k in keys}
    rec["compile_s"] = round(sum(p["compile_s"] for p in points), 2)
    return rec


def measure_wfa(variant: str, *, edit_frac: float = 0.02,
                multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh_devices(mesh)
    cell = build_wfa_cell(wfa_paper, mesh, edit_frac=edit_frac,
                          variant=variant)
    rec = {"n_devices": n_dev, "model_flops": 0.0}
    rec.update(_compile_and_measure(cell, mesh, n_dev))
    return rec


# ---------------------------------------------------------------------------
# Experiment registry: name -> callable() -> record dict
# (hypotheses + analysis live in EXPERIMENTS.md §Perf; this file is the
#  measurement rig so each row is reproducible)

EXPERIMENTS = {
    # -- cell 1: the paper's own technique (wfa-paper : fig1_e2) ----------
    "wfa_pjit_baseline": lambda: measure_wfa("pjit"),
    "wfa_shardmap": lambda: measure_wfa("shard_map"),
    "wfa_pjit_multipod": lambda: measure_wfa("pjit", multi_pod=True),
    "wfa_shardmap_multipod": lambda: measure_wfa("shard_map", multi_pod=True),

    # -- cell 2: most collective-bound LM cell ----------------------------
    "qwen3_32b_prefill_baseline": lambda: measure_lm(
        "qwen3-32b", "prefill_32k", {}),
    "qwen3_32b_prefill_seqshard": lambda: measure_lm(
        "qwen3-32b", "prefill_32k", {"seq_shard": True}),
    "granite8b_train_baseline": lambda: measure_lm(
        "granite-8b", "train_4k", {}),
    "granite8b_train_seqshard": lambda: measure_lm(
        "granite-8b", "train_4k", {"seq_shard": True}),

    # -- ZeRO 2-D state sharding: the fit fix, costed both ways -----------
    "qwen3_32b_train_zero_mem": lambda: measure_lm(
        "qwen3-32b", "train_4k", {}, mode="memory", zero=True),
    "qwen3_32b_train_nozero_mem": lambda: measure_lm(
        "qwen3-32b", "train_4k", {}, mode="memory", zero=False),
    "qwen3_32b_train_zero_roofline": lambda: measure_lm(
        "qwen3-32b", "train_4k", {}, zero=True),
    "qwen3_32b_train_nozero_roofline": lambda: measure_lm(
        "qwen3-32b", "train_4k", {}, zero=False),

    # -- cell 2 (most collective-bound): zamba2 split vs fused xBC proj ---
    "zamba2_train_fusedproj": lambda: measure_lm(
        "zamba2-7b", "train_4k", {"ssm_split_proj": False}),
    "zamba2_train_splitproj": lambda: measure_lm(
        "zamba2-7b", "train_4k", {"ssm_split_proj": True}),
    "zamba2_train_seqshard": lambda: measure_lm(
        "zamba2-7b", "train_4k", {"seq_shard": True}),

    # -- follow-ups: memory-fit iterations on the flagship train cell ------
    "qwen3_32b_train_remat_nothing_mem": lambda: measure_lm(
        "qwen3-32b", "train_4k", {"remat_policy": "nothing"}, mode="memory"),
    "qwen3_32b_train_micro2k_mem": lambda: measure_lm(
        "qwen3-32b", "train_4k", {"microbatch_tokens": 2048}, mode="memory"),
    "qwen3_32b_train_seqshard": lambda: measure_lm(
        "qwen3-32b", "train_4k", {"seq_shard": True}),
    "granite8b_train_seqshard_mem": lambda: measure_lm(
        "granite-8b", "train_4k", {"seq_shard": True}, mode="memory"),
    "qwen3_32b_train_fit_combo_mem": lambda: measure_lm(
        "qwen3-32b", "train_4k",
        {"remat_policy": "nothing", "seq_shard": True}, mode="memory"),
    "granite34b_train_fit_combo_mem": lambda: measure_lm(
        "granite-34b", "train_4k",
        {"remat_policy": "nothing", "seq_shard": True}, mode="memory"),
    "qwen2vl_train_fit_combo_mem": lambda: measure_lm(
        "qwen2-vl-7b", "train_4k",
        {"remat_policy": "nothing", "seq_shard": True}, mode="memory"),
    "zamba2_train_fit_combo_mem": lambda: measure_lm(
        "zamba2-7b", "train_4k",
        {"remat_policy": "nothing", "seq_shard": True}, mode="memory"),
    "zamba2_train_fit_dots_mem": lambda: measure_lm(
        "zamba2-7b", "train_4k",
        {"seq_shard": True, "ssm_chunk": 64}, mode="memory"),
    "phi35_train_fit_combo_mem": lambda: measure_lm(
        "phi3.5-moe-42b-a6.6b", "train_4k",
        {"remat_policy": "nothing", "seq_shard": True, "moe_ep": True},
        mode="memory", multi_pod=True),

    # -- cell 3: worst-fraction cell (filled from the roofline table) -----
    "zamba2_prefill_baseline": lambda: measure_lm(
        "zamba2-7b", "prefill_32k", {}),
    "zamba2_prefill_chunk512": lambda: measure_lm(
        "zamba2-7b", "prefill_32k", {"ssm_chunk": 512}),
    "zamba2_prefill_chunk256": lambda: measure_lm(
        "zamba2-7b", "prefill_32k", {"ssm_chunk": 256}),
    "deepseek_train_baseline": lambda: measure_lm(
        "deepseek-v2-lite-16b", "train_4k", {}),
    "deepseek_train_ep": lambda: measure_lm(
        "deepseek-v2-lite-16b", "train_4k", {"moe_ep": True}),
    "phi35_train_ep": lambda: measure_lm(
        "phi3.5-moe-42b-a6.6b", "train_4k", {"moe_ep": True}),
    "deepseek_decode_baseline": lambda: measure_lm(
        "deepseek-v2-lite-16b", "decode_32k", {}),
    "deepseek_decode_absorb": lambda: measure_lm(
        "deepseek-v2-lite-16b", "decode_32k", {"mla_absorb": True}),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", nargs="*", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args(argv)

    if args.list:
        for name in EXPERIMENTS:
            print(name)
        return 0

    names = args.exp or list(EXPERIMENTS)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    rc = 0
    for name in names:
        print(f"[hillclimb] {name} ...", flush=True)
        rec = {"experiment": name,
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}
        try:
            rec.update(EXPERIMENTS[name]())
            rec["status"] = "ok"
            coll = rec.get("collectives", {}).get("total", 0.0)
            print(f"[hillclimb] {name}: flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} coll={coll:.3e}B "
                  f"compile={rec['compile_s']}s", flush=True)
        except Exception:
            rec.update(status="error", error=traceback.format_exc()[-3000:])
            print(f"[hillclimb] {name}: ERROR", flush=True)
            rc = 1
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
