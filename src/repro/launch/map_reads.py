"""Read-mapping launcher — FASTQ reads onto FASTA references, end to end.

The scenario the paper's throughput numbers exist to serve: build (or
load) a minimizer index over the references, generate candidate loci per
read by colinear chaining, verify candidates as batched WFA extensions
through ``AlignmentEngine.stream()``, and emit SAM.

    python -m repro.launch.map_reads \
        --refs ref.fa --reads reads.fq --sam-out out.sam

``--index``/``--save-index`` reuse a pickled index across runs (built
once, shared by every query).  ``--penalties``/``--heuristic`` are the
PR-4 per-submit scoring seam; ``--backend`` any registered engine
backend.  Progress goes to stderr when SAM goes to stdout, so
``... --sam-out - > out.sam`` stays a valid SAM stream.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.core import scoring
from repro.core.backends import available_backends, get_backend
from repro.core.engine import AlignmentEngine
from repro.data.io import read_seqs
from repro.mapping.extend import ReadMapper, suggested_edit_frac
from repro.mapping.index import MinimizerIndex
from repro.mapping.sam import write_sam


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reads", required=True, metavar="PATH",
                    help="FASTA/FASTQ(.gz) reads to map")
    ap.add_argument("--refs", default=None, metavar="PATH",
                    help="FASTA/FASTQ(.gz) references to index (required "
                         "unless --index loads a prebuilt one)")
    ap.add_argument("--index", default=None, metavar="PATH",
                    help="load a pickled MinimizerIndex instead of "
                         "building from --refs")
    ap.add_argument("--save-index", default=None, metavar="PATH",
                    help="pickle the built index for reuse")
    ap.add_argument("--k", type=int, default=None,
                    help="minimizer k-mer size (default 15; build-time "
                         "only — ignored with --index)")
    ap.add_argument("--w", type=int, default=None,
                    help="minimizer window, keep 1 of w consecutive "
                         "k-mers (default 10; build-time only)")
    ap.add_argument("--occ-cap", type=int, default=None,
                    help="drop seeds with more reference occurrences "
                         "(default 64; build-time only)")
    ap.add_argument("--top-n", type=int, default=2,
                    help="candidate loci verified per read "
                         "(primary + secondaries)")
    ap.add_argument("--edit-frac", type=float, default=0.02,
                    help="expected read divergence E (window + bound sizing)")
    ap.add_argument("--penalties", default=None, metavar="SPEC",
                    help="penalty model: 'edit', 'linear:x,e', "
                         "'affine:x,o,e' or the bare triple 'x,o,e'")
    ap.add_argument("--heuristic", default="none", metavar="SPEC",
                    help="wavefront heuristic: 'none' (exact, default), "
                         "'adaptive[:min_wf_len,max_distance_diff]' or "
                         "'zdrop[:z]'")
    ap.add_argument("--backend", choices=available_backends(),
                    default="ring")
    ap.add_argument("--batch-reads", type=int, default=256,
                    help="reads per session submit (ticket granularity)")
    ap.add_argument("--inflight", type=int, default=2,
                    help="max in-flight waves (session backpressure)")
    ap.add_argument("--limit", type=int, default=0,
                    help="map only the first N reads (0 = all)")
    ap.add_argument("--sam-out", default="-", metavar="PATH",
                    help="SAM output (default stdout)")
    ap.add_argument("--cigar-mode", choices=("classic", "extended"),
                    default="classic",
                    help="CIGAR spelling: pre-1.4 M (default) or 1.4 =/X")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture the mapping pass as Chrome trace-event "
                         "JSON (open in ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the mapping pass in jax.profiler.trace")
    args = ap.parse_args(argv)

    sam_to_stdout = args.sam_out == "-"
    log_file = sys.stderr if sam_to_stdout else sys.stdout

    def log(*a, **kw):
        print(*a, file=log_file, flush=True, **kw)

    if args.index is None and args.refs is None:
        ap.error("need --refs (build an index) or --index (load one)")

    t0 = time.perf_counter()
    if args.index is not None:
        if any(v is not None for v in (args.k, args.w, args.occ_cap)):
            ap.error("--k/--w/--occ-cap are index build parameters; they "
                     "cannot be applied to a prebuilt --index (rebuild "
                     "from --refs to change them)")
        index = MinimizerIndex.load(args.index)
        log(f"[map] loaded index {args.index}: {index.n_refs} refs, "
            f"{index.n_occurrences} seed occurrences, "
            f"{index.nbytes() / 1e6:.1f} MB "
            f"in {time.perf_counter() - t0:.2f}s")
    else:
        names, seqs = read_seqs(args.refs)
        t1 = time.perf_counter()
        k = 15 if args.k is None else args.k
        w = 10 if args.w is None else args.w
        occ_cap = 64 if args.occ_cap is None else args.occ_cap
        index = MinimizerIndex.build(seqs, names, k=k, w=w, occ_cap=occ_cap)
        dt = time.perf_counter() - t1
        total = int(index.lengths.sum())
        log(f"[map] indexed {index.n_refs} refs ({total} bp) in {dt:.2f}s "
            f"({total / max(dt, 1e-9) / 1e6:.1f} Mbp/s): "
            f"{index.n_occurrences} seed occurrences "
            f"({index.n_seeds_capped} capped at occ>{occ_cap}), "
            f"{index.nbytes() / 1e6:.1f} MB")
    if args.save_index:
        index.save(args.save_index)
        log(f"[map] saved index to {args.save_index}")

    read_names, reads = read_seqs(args.reads)
    if args.limit:
        read_names, reads = (read_names[:args.limit], reads[:args.limit])
    log(f"[map] loaded {len(reads)} reads from {args.reads}")

    pen = (scoring.parse_penalties(args.penalties)
           if args.penalties else scoring.as_model(None))
    heur = scoring.parse_heuristic(args.heuristic)
    read_len = int(np.median([len(r) for r in reads])) if reads else 100
    mesh = None
    if get_backend(args.backend).needs_mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    engine = AlignmentEngine(
        pen, backend=args.backend, heuristic=heur, mesh=mesh,
        edit_frac=suggested_edit_frac(pen, args.edit_frac, read_len))
    mapper = ReadMapper(index, engine, top_n=args.top_n,
                        edit_frac=args.edit_frac, read_len=read_len,
                        batch_reads=args.batch_reads, penalties=pen,
                        heuristic=heur)

    cl = "repro.launch.map_reads " + " ".join(argv or sys.argv[1:])
    t2 = time.perf_counter()
    with obs.capture_trace(args.trace_out), \
            obs.profile.profile(args.profile):
        stream = mapper.map_stream(reads, max_inflight_waves=args.inflight)
        if sam_to_stdout:
            n_rec = write_sam(sys.stdout, stream, reads, read_names,
                              index.names, index.lengths,
                              mode=args.cigar_mode, cl=cl)
        else:
            with open(args.sam_out, "w") as f:
                n_rec = write_sam(f, stream, reads, read_names, index.names,
                                  index.lengths, mode=args.cigar_mode,
                                  cl=cl)
    wall = time.perf_counter() - t2
    if args.trace_out:
        log(f"[map] trace -> {args.trace_out}")

    st = mapper.stats
    log(f"[map] mapped {st.n_mapped}/{st.n_reads} reads "
        f"({st.candidates_per_read:.2f} candidates/read, "
        f"{st.n_unresolved} unresolved extensions, "
        f"{st.n_tickets} tickets) -> {n_rec} SAM records"
        + ("" if sam_to_stdout else f" in {args.sam_out}"))
    log(f"[map] throughput: {st.n_reads / max(wall, 1e-9):,.0f} reads/s "
        f"({st.n_extensions / max(wall, 1e-9):,.0f} extensions/s), "
        f"wall {wall:.3f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
