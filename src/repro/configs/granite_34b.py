"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — code model. [arXiv:2405.04324; hf]

granite-34b-code is GPT-BigCode-style: MQA + plain (non-gated) 4x MLP —
with SwiGLU the param count would be 47B, not the published 34B.  We keep
RoPE per the assignment's "llama-arch" label (deviation noted in
DESIGN.md §9)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab_size=49152,
    mlp_gated=False,
    rope_theta=1e4,
    remat_policy="dots",
)
