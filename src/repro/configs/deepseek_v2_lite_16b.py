"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400 — MLA kv_lora=512 + 64-dim decoupled rope key; MoE 64 routed
top-6 + 2 shared experts; first layer dense (d_ff=10944).
[arXiv:2405.04434; hf]  (The assignment note "160 routed" contradicts its
own primary spec "MoE 64e top-6"; we implement the primary spec, which
matches the released DeepSeek-V2-Lite.)"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    attn_kind="mla", kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
    first_k_dense=1, dense_layer_ff=10944,
    rope_theta=1e4,
    remat_policy="dots",
)
