"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec with sinusoidal positions (rope disabled); the
conv/mel frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings [B, 1500, 512].  [arXiv:2212.04356; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, enc_frames=1500,
    d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, vocab_size=51865,
    mlp_gated=False, rope_theta=0.0, tie_embeddings=True,
    remat_policy="dots",
)
