"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE over (temporal, height, width) sections (16,24,24);
the vision frontend is a STUB: input_specs() supplies merged patch
embeddings + 3-D position ids.  [arXiv:2409.12191; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944, vocab_size=152064,
    mrope=True, mrope_sections=(16, 24, 24), n_patches=256,
    rope_theta=1e6,
    remat_policy="dots",
)
