"""mamba2-780m [ssm]: 48L d_model=1536, attn-free, ssm_state=128 — SSD
(state-space duality) blocks.  vocab=50280.  [arXiv:2405.21060; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280, d_head=64, tie_embeddings=True,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    remat_policy="dots",
)
