"""The paper's own workload as a config: batched WFA alignment of
100bp read pairs at E in {2%, 4%} (Fig. 1 regime), distributed PIM-style
(pair axis over every mesh axis, no collectives)."""
import dataclasses

from repro.core.penalties import Penalties


@dataclasses.dataclass(frozen=True)
class WFAWorkload:
    name: str = "wfa-paper"
    family: str = "alignment"
    read_len: int = 100
    edit_frac: float = 0.02          # paper E=2% (Fig. 1 also runs 4%)
    pairs_per_device: int = 2048     # one "MRAM load" per device per wave
    pen: Penalties = Penalties(x=4, o=6, e=2)
    block_pairs: int = 8             # kernel grid block ("DPU" granularity)


CONFIG = WFAWorkload()
