"""zamba2-7b [hybrid]: 81 Mamba2 layers d_model=3584, ssm_state=64, with a
SHARED attention+MLP block (32H kv=32, d_ff=14336) applied every 6th layer
— structural simplification of Zamba2's dual alternating shared blocks
(recorded in DESIGN.md §9).  vocab=32000.  [arXiv:2411.15242; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    hybrid_attn_every=6,
    rope_theta=1e4,
    remat_policy="dots",
)
