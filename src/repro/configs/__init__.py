"""Assigned architectures (exact public configs) + the paper's own workload.

``get_config(name)`` returns the full-size ModelConfig; ``smoke_config(name)``
returns a reduced same-family config for CPU smoke tests (small depth/width,
few experts, tiny vocab — the full sizes are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig

from repro.configs.qwen3_32b import CONFIG as qwen3_32b
from repro.configs.qwen3_0_6b import CONFIG as qwen3_0_6b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.granite_8b import CONFIG as granite_8b
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.phi3_5_moe_42b import CONFIG as phi3_5_moe_42b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.mamba2_780m import CONFIG as mamba2_780m
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.qwen2_vl_7b import CONFIG as qwen2_vl_7b
from repro.configs.wfa_paper import CONFIG as wfa_paper  # alignment workload

CONFIGS: dict[str, ModelConfig] = {
    "qwen3-32b": qwen3_32b,
    "qwen3-0.6b": qwen3_0_6b,
    "granite-34b": granite_34b,
    "granite-8b": granite_8b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "zamba2-7b": zamba2_7b,
    "mamba2-780m": mamba2_780m,
    "whisper-base": whisper_base,
    "qwen2-vl-7b": qwen2_vl_7b,
}

ARCH_NAMES = list(CONFIGS)


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return CONFIGS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: runnable forward/train step on 1 CPU."""
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_head=64,
        d_ff=512,
        vocab_size=512,
        microbatch_tokens=1 << 30,  # no microbatching in smoke tests
    )
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=2, d_expert=128,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_k_dense=min(cfg.first_k_dense, 1),
                  dense_layer_ff=256 if cfg.first_k_dense else 0)
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32,
                  v_head_dim=32)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.family == "hybrid":
        kw.update(hybrid_attn_every=2)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, enc_frames=64)
    if cfg.family == "vlm":
        kw.update(n_patches=16, mrope_sections=(8, 12, 12))  # sums to d_head/2
    return dataclasses.replace(cfg, **kw)
