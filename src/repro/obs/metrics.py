"""Bounded-memory metrics: counters, gauges, log-bucketed histograms.

The registry is the service-health complement of :mod:`repro.obs.trace`'s
timeline: cheap monotonic counters (cache hits, shed requests), gauges
(queue depth, in-flight waves) and **log-bucketed histograms** whose
p50/p95/p99 come from a fixed array of geometric buckets — *not* from an
ever-growing stored sample list, so a week-long server reports the same
percentiles in the same few hundred bytes as a unit test does.

Quantile error is bounded by the bucket ratio: :meth:`Histogram.quantile`
returns the upper edge of the bucket holding the target rank, so the
exact sample satisfies ``q_exact <= quantile(q) < q_exact * factor``
(default factor ``2**0.25`` ≈ +19%) — "within one bucket", which the
test suite pins.

Exposition:

* :func:`render_prometheus` — Prometheus text format (``_bucket``/
  ``_sum``/``_count`` series per histogram plus derived ``_p50/_p95/_p99``
  gauges); :func:`start_http_server` serves it at ``/metrics``.
* :func:`snapshot` / :func:`write_jsonl` — one JSON document per call,
  appended as a line, for offline trending next to ``BENCH_*.json``.

All operations are thread-safe and O(1) (quantiles O(n_buckets)).
"""
from __future__ import annotations

import json
import math
import os
import re
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "counter", "gauge", "histogram", "render_prometheus",
           "snapshot", "start_http_server", "write_jsonl"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "help", "_v", "_lock")

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v

    def expose(self) -> List[str]:
        return [f"{self.name} {self._v:g}"]

    def to_dict(self) -> dict:
        return {"kind": "counter", "value": self._v}


class Gauge:
    """Point-in-time value (set / inc / dec)."""

    __slots__ = ("name", "help", "_v", "_lock")

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._v -= n

    @property
    def value(self) -> float:
        return self._v

    def expose(self) -> List[str]:
        return [f"{self.name} {self._v:g}"]

    def to_dict(self) -> dict:
        return {"kind": "gauge", "value": self._v}


class Histogram:
    """Log-bucketed histogram with bounded memory.

    ``n_buckets`` geometric buckets span ``(0, lo * factor**(n-1)]``:
    bucket 0 holds samples ``<= lo``, bucket ``i`` holds
    ``(lo * factor**(i-1), lo * factor**i]``, and the last bucket also
    absorbs anything larger (so no sample is ever dropped — the top edge
    just saturates).  Defaults size the latency use case: ``lo=1e-5`` s,
    ``factor=2**0.25``, 96 buckets → ~10 µs to ~170 s at ≤19% bucket
    width.  ``sum``/``count``/``max`` are exact.
    """

    __slots__ = ("name", "help", "lo", "factor", "_log_factor", "_counts",
                 "_sum", "_max", "_count", "_lock")

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-5,
                 factor: float = 2 ** 0.25, n_buckets: int = 96):
        if lo <= 0 or factor <= 1 or n_buckets < 2:
            raise ValueError("need lo > 0, factor > 1, n_buckets >= 2")
        self.name = name
        self.help = help
        self.lo = float(lo)
        self.factor = float(factor)
        self._log_factor = math.log(self.factor)
        self._counts = [0] * int(n_buckets)
        self._sum = 0.0
        self._max = 0.0
        self._count = 0
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log(v / self.lo) / self._log_factor - 1e-12))
        return min(i, len(self._counts) - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._index(v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v > self._max:
                self._max = v

    # -- reading -------------------------------------------------------------

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def max(self) -> float:
        return self._max

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else float("nan")

    @property
    def n_buckets(self) -> int:
        return len(self._counts)

    def bucket_edge(self, i: int) -> float:
        """Upper edge of bucket ``i``."""
        return self.lo * self.factor ** i

    def counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the rank-``q`` sample
        (``q`` in [0, 1]); NaN when empty.  Within one bucket of exact:
        ``exact <= quantile(q) < exact * factor``."""
        with self._lock:
            total = self._count
            if total == 0:
                return float("nan")
            target = max(1, math.ceil(q * total))
            acc = 0
            for i, c in enumerate(self._counts):
                acc += c
                if acc >= target:
                    # never report past the observed max (the top bucket's
                    # edge can be far above a saturated sample)
                    return min(self.bucket_edge(i), self._max)
        return self._max

    def nbytes(self) -> int:
        """Approximate resident size of the bucket storage — constant for
        the histogram's lifetime (the bounded-memory contract)."""
        return len(self._counts) * 8

    # -- exposition ----------------------------------------------------------

    def expose(self) -> List[str]:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        out, acc = [], 0
        for i, c in enumerate(counts):
            acc += c
            out.append(f'{self.name}_bucket{{le="{self.bucket_edge(i):g}"}}'
                       f" {acc}")
        out.append(f'{self.name}_bucket{{le="+Inf"}} {total}')
        out.append(f"{self.name}_sum {s:g}")
        out.append(f"{self.name}_count {total}")
        for q, tag in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            v = self.quantile(q)
            out.append(f"{self.name}_{tag} {v:g}")
        return out

    def to_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s, mx = self._count, self._sum, self._max
        return {"kind": "histogram", "lo": self.lo, "factor": self.factor,
                "counts": counts, "sum": s, "count": total, "max": mx,
                "p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class Registry:
    """Name → metric map with get-or-create accessors.

    One process-global :data:`REGISTRY` backs the module-level helpers;
    tests build private registries.  Re-requesting a name returns the
    existing metric (type-checked), so modules can declare their metrics
    at call sites without import-order coupling.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw):
        name = _sanitize(name)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get_or_create(Histogram, name, help, **kw)

    def attach(self, metric) -> None:
        """Register (or replace) an externally-constructed metric under
        its own name — e.g. a :class:`~repro.serve.loop.ServeLoop`'s
        per-instance latency histogram, where the *newest* server is the
        one a scrape should see."""
        with self._lock:
            self._metrics[_sanitize(metric.name)] = metric

    def get(self, name: str):
        return self._metrics.get(_sanitize(name))

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exposition ----------------------------------------------------------

    def render_prometheus(self) -> str:
        """The full registry in Prometheus text exposition format."""
        with self._lock:
            metrics = list(self._metrics.values())
        lines = []
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        with self._lock:
            metrics = dict(self._metrics)
        return {"ts_unix": time.time(),
                "metrics": {n: m.to_dict() for n, m in metrics.items()}}

    def write_jsonl(self, path: str) -> str:
        """Append one snapshot as a JSON line → ``path``."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            json.dump(self.snapshot(), f)
            f.write("\n")
        return path


REGISTRY = Registry()

# Module-level helpers over the process-global registry — what the
# instrumented subsystems call.
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
render_prometheus = REGISTRY.render_prometheus
snapshot = REGISTRY.snapshot
write_jsonl = REGISTRY.write_jsonl


def start_http_server(port: int = 9100, registry: Optional[Registry] = None):
    """Serve ``registry`` (default: the global one) at ``/metrics`` on a
    daemon thread → the ``http.server`` instance (``.shutdown()`` stops
    it).  Zero dependencies: the standard Prometheus scrape endpoint for
    an always-on aligner service."""
    import http.server

    reg = REGISTRY if registry is None else registry

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):                            # noqa: N802 (stdlib API)
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_response(404)
                self.end_headers()
                return
            body = reg.render_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                   # silence per-scrape logs
            pass

    srv = http.server.ThreadingHTTPServer(("", int(port)), _Handler)
    th = threading.Thread(target=srv.serve_forever, daemon=True,
                          name="obs-metrics-http")
    th.start()
    return srv
