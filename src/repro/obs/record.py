"""Always-on flight recorder: a bounded ring of recent trace events.

Full tracing (``obs.trace.enable()``) is something you turn on for a
run you *planned* to inspect.  The failures worth inspecting — a shed
under load, an ``as_completed`` timeout, a ticket failure, a BiWFA
fallback — happen on runs where it was off.  The flight recorder keeps
the last N span/instant/counter events in a ``collections.deque`` ring
even while the tracer is off, and :func:`dump` writes them as a
Perfetto-viewable Chrome trace (plus a metrics snapshot) the moment
something goes wrong.

Cost model: ``trace._emit`` gains one global read on the fully-off
path; with the recorder active each span pays one dict build and one
GIL-atomic ``deque.append`` (no lock).  ``benchmarks/obs_overhead.py
--check`` holds this inside the same ≤2% disabled-overhead budget as
the bare instrumentation points.

Lifecycle: the recorder is **off by default** (so ``obs.trace``'s
zero-allocation disabled contract holds for plain library use).
Long-running components acquire it refcounted — ``ServeLoop.start()``
calls :func:`acquire`, ``stop()`` calls :func:`release` — and
:func:`enable` turns it on explicitly (e.g. from a launcher flag).
:func:`dump` is a no-op when inactive, so hook sites never guard.

Usage::

    from repro.obs import record as obs_record

    obs_record.enable(capacity=8192)
    ...
    obs_record.dump("shed", {"request": rid})   # -> results/flightrec/...
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Deque, Optional

from . import metrics as obs_metrics
from . import trace as obs_trace

__all__ = ["FlightRecorder", "acquire", "active", "disable", "dump",
           "enable", "get", "release"]

# Where post-mortems land; tests point this at a tmp dir via the env var.
ENV_DIR = "REPRO_FLIGHTREC_DIR"
DEFAULT_DIR = os.path.join("results", "flightrec")
DEFAULT_CAPACITY = 8192
# Repeated failures (a shed storm, a fallback-heavy workload) must not
# turn the recorder into a disk-filling loop: one dump per reason per
# interval.
DEFAULT_MIN_INTERVAL_S = 30.0


class FlightRecorder:
    """Bounded ring of trace events + post-mortem dump writer."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 out_dir: Optional[str] = None,
                 min_interval_s: float = DEFAULT_MIN_INTERVAL_S):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.out_dir = out_dir or os.environ.get(ENV_DIR, DEFAULT_DIR)
        self.min_interval_s = float(min_interval_s)
        # deque.append with maxlen is GIL-atomic: the hot recording path
        # takes no lock.  The dump path snapshots via list(ring), which
        # is likewise safe against concurrent appends.
        self._ring: Deque[dict] = collections.deque(maxlen=self.capacity)
        self._dump_lock = threading.Lock()
        self._last_dump: dict = {}          # reason -> monotonic ts
        self.n_dumps = 0

    def record(self, ev: dict) -> None:
        """Sink for ``trace._emit`` — called for every emitted event."""
        self._ring.append(ev)

    def __len__(self) -> int:
        return len(self._ring)

    def events(self) -> list:
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()

    def dump(self, reason: str, args: Optional[dict] = None,
             path: Optional[str] = None) -> Optional[str]:
        """Write the ring as a Chrome trace post-mortem.

        Returns the written path, or ``None`` when rate-limited.  Safe
        from any thread; never raises on I/O failure (a broken disk
        must not take down the serve loop it is diagnosing).
        """
        now = time.monotonic()
        with self._dump_lock:
            last = self._last_dump.get(reason)
            if last is not None and (now - last) < self.min_interval_s:
                return None
            self._last_dump[reason] = now
            ring = list(self._ring)
            self.n_dumps += 1
        if path is None:
            stamp = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
            fname = f"flightrec_{reason}_{stamp}_{os.getpid()}.json"
            path = os.path.join(self.out_dir, fname)
        marker = {"name": f"flightrec.dump:{reason}", "cat": "flightrec",
                  "ph": "i", "s": "g", "ts": obs_trace._now_us(),
                  "pid": os.getpid(), "tid": threading.get_ident(),
                  "args": dict(args) if args else {}}
        meta = [{"name": "process_name", "ph": "M", "pid": os.getpid(),
                 "tid": 0, "args": {"name": "repro-flightrec"}}]
        payload = {
            "traceEvents": meta + ring + [marker],
            "displayTimeUnit": "ms",
            "flightrec": {"reason": reason,
                          "args": dict(args) if args else {},
                          "n_events": len(ring),
                          "capacity": self.capacity,
                          "ts_unix": time.time()},
            "metrics": obs_metrics.snapshot(),
        }
        try:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "w") as f:
                json.dump(payload, f)
                f.write("\n")
        except OSError:
            return None
        obs_metrics.counter("flightrec_dumps_total",
                            "flight-recorder post-mortems written").inc()
        return path


# ---------------------------------------------------------------------------
# Module-level lifecycle: one process-global recorder, refcounted.

_lock = threading.Lock()
_active: Optional[FlightRecorder] = None
_acquires = 0
_explicit = False


def enable(capacity: int = DEFAULT_CAPACITY, out_dir: Optional[str] = None,
           min_interval_s: float = DEFAULT_MIN_INTERVAL_S) -> FlightRecorder:
    """Explicitly install a recorder (survives component release())."""
    global _active, _explicit
    with _lock:
        _active = FlightRecorder(capacity=capacity, out_dir=out_dir,
                                 min_interval_s=min_interval_s)
        _explicit = True
        obs_trace._set_recorder(_active)
        return _active


def disable() -> None:
    """Remove the recorder unconditionally (drops any refcounts)."""
    global _active, _acquires, _explicit
    with _lock:
        _active = None
        _acquires = 0
        _explicit = False
        obs_trace._set_recorder(None)


def acquire(**kw) -> FlightRecorder:
    """Refcounted activation for long-running components.

    ``ServeLoop.start()`` acquires; ``stop()`` releases.  The first
    acquire installs a default recorder; an explicitly :func:`enable`-d
    one is reused and outlives all releases.
    """
    global _active, _acquires
    with _lock:
        if _active is None:
            _active = FlightRecorder(**kw)
            obs_trace._set_recorder(_active)
        _acquires += 1
        return _active


def release() -> None:
    global _active, _acquires
    with _lock:
        if _acquires > 0:
            _acquires -= 1
        if _acquires == 0 and not _explicit and _active is not None:
            _active = None
            obs_trace._set_recorder(None)


def active() -> Optional[FlightRecorder]:
    return _active


def get() -> Optional[FlightRecorder]:
    return _active


def dump(reason: str, args: Optional[dict] = None) -> Optional[str]:
    """Dump the current ring if a recorder is active; no-op otherwise.

    This is the form hook sites use — no guard needed at the call site.
    """
    rec = _active
    if rec is None:
        return None
    return rec.dump(reason, args)
