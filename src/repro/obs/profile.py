"""`jax.profiler` bridge: device-level traces lined up with our spans.

:func:`profile` wraps a block in ``jax.profiler.trace(outdir)`` (the
``--profile DIR`` flag on the launchers), capturing XLA/TPU activity
viewable in TensorBoard or Perfetto.  :func:`annotation` emits a named
``jax.profiler.TraceAnnotation`` **only while a profile is active**, so
the instrumented hot path (session dispatch, kernel wait) carries the
same stage names in the device trace as in :mod:`repro.obs.trace`'s
host-side timeline — matching up "wave.kernel" on both sides is how the
paper's scatter/kernel/gather phase split (Fig. 1) is attributed to real
device time.

When no profile is active, :func:`annotation` returns a shared no-op
context manager (no allocation), mirroring the disabled-mode contract of
the tracer.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

__all__ = ["active", "annotation", "profile"]

# Set only while a profile() block is running; annotation() gates on it so
# steady-state code pays one branch when not profiling.
_active = False

_NULL_CTX = contextlib.nullcontext()


def active() -> bool:
    return _active


@contextlib.contextmanager
def profile(outdir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace of the block into ``outdir``
    (``None`` → no-op, so callers can pass an optional CLI flag straight
    through).  View with TensorBoard's profile plugin or Perfetto."""
    global _active
    if not outdir:
        yield
        return
    import jax

    _active = True
    try:
        with jax.profiler.trace(outdir):
            yield
    finally:
        _active = False


def annotation(name: str):
    """A named ``TraceAnnotation`` scope when a profile is active, else a
    shared no-op context manager."""
    if not _active:
        return _NULL_CTX
    import jax

    return jax.profiler.TraceAnnotation(name)
