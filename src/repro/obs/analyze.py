"""Trace analysis: phase accounting, critical paths, pipeline bubbles.

This is the *consumption* side of ``repro.obs``: a typed loader for the
Chrome-trace JSON that ``obs.trace.save`` (and the flight recorder)
writes, plus the analyses the paper's evaluation is built on:

* **Phase accounting** — per-wave span time grouped into the paper's
  Fig. 1 split.  Our spans map onto it as
  ``wave.scatter`` → CPU→DPU *transfer*, ``wave.kernel`` → *kernel*,
  ``wave.gather``/``wave.traceback`` → DPU→CPU *retrieve* (+ host
  post-processing).  :func:`phase_accounting` reproduces that
  breakdown from any capture.
* **Critical paths** — the PR-9 flow arrows connect one ticket's
  submit span to every wave it rode, across threads.
  :func:`critical_paths` rebinds each flow point to its enclosing span
  and reports per-segment busy/wait time, i.e. where a request's
  latency actually went.
* **Pipeline analysis** — :func:`pipeline_analysis` reconstructs device
  busy intervals from the ``inflight_waves`` counter track, reports
  idle **bubbles** between waves, time-weighted mean inflight depth,
  and how much host-side packing/gather overlapped device kernels.
* **Diffing** — :func:`diff_phase_tables` / :func:`diff_rows` attribute
  a regression between two captures (trace JSON or ``BENCH_*.json``
  snapshots) to the (suite, phase) that moved.

Stdlib-only and side-effect-free: importing or running the analyzer
never touches the process-global tracer.
"""
from __future__ import annotations

import bisect
import json
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Bubble", "CounterPoint", "FlowPath", "InstantPoint",
           "PathSegment", "PhaseDelta", "PhaseStat", "PhaseTable",
           "PipelineReport", "RowDelta", "SpanEvent", "Trace",
           "critical_paths", "diff_phase_tables", "diff_rows",
           "phase_accounting", "pipeline_analysis", "slow_waves",
           "PAPER_PHASE", "SPAN_PHASE"]

# Span name → phase bucket.  The wave lifecycle spans are the
# accounting unit; everything else (session.submit, serve.*) shows up
# in critical paths but not the phase table.
SPAN_PHASE: Dict[str, str] = {
    "wave.scatter": "scatter",
    "wave.kernel": "kernel",
    "wave.gather": "gather",
    "wave.traceback": "traceback",
}

# Phase bucket → the paper's Fig. 1 terminology (CPU-DPU transfer /
# DPU kernel / DPU-CPU retrieval).  Traceback is host post-processing
# folded into the retrieve side, as in the framework paper's accounting.
PAPER_PHASE: Dict[str, str] = {
    "scatter": "transfer (CPU->DPU)",
    "kernel": "kernel (DPU)",
    "gather": "retrieve (DPU->CPU)",
    "traceback": "retrieve/host traceback",
}

PHASE_ORDER = ("scatter", "kernel", "gather", "traceback")


# ---------------------------------------------------------------------------
# Typed events + loader.


@dataclass(frozen=True)
class SpanEvent:
    name: str
    cat: str
    ts: float              # microseconds, trace origin
    dur: float
    tid: int
    args: dict = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


@dataclass(frozen=True)
class FlowPoint:
    id: int
    ph: str                # "s" | "t" | "f"
    ts: float
    tid: int


@dataclass(frozen=True)
class CounterPoint:
    name: str
    ts: float
    value: float


@dataclass(frozen=True)
class InstantPoint:
    name: str
    ts: float
    tid: int
    args: dict = field(default_factory=dict)


class Trace:
    """Typed view over one Chrome-trace capture.

    Spans are kept per-tid sorted by start time so enclosing-span
    lookups are ``O(log n + depth)``; flow points are grouped by id in
    timeline order.
    """

    def __init__(self, spans: Sequence[SpanEvent],
                 flows: Sequence[FlowPoint],
                 counters: Sequence[CounterPoint],
                 instants: Sequence[InstantPoint]):
        self.spans = sorted(spans, key=lambda s: s.ts)
        self.flows = sorted(flows, key=lambda p: p.ts)
        self.counters = sorted(counters, key=lambda c: c.ts)
        self.instants = sorted(instants, key=lambda i: i.ts)
        self._by_tid: Dict[int, List[SpanEvent]] = {}
        for s in self.spans:
            self._by_tid.setdefault(s.tid, []).append(s)
        self._tid_starts: Dict[int, List[float]] = {
            tid: [s.ts for s in spans_] for tid, spans_ in self._by_tid.items()}

    # -- construction --------------------------------------------------------

    @classmethod
    def from_events(cls, events: Iterable[dict]) -> "Trace":
        spans: List[SpanEvent] = []
        flows: List[FlowPoint] = []
        counters: List[CounterPoint] = []
        instants: List[InstantPoint] = []
        for ev in events:
            ph = ev.get("ph")
            if ph == "X":
                spans.append(SpanEvent(name=str(ev.get("name", "")),
                                       cat=str(ev.get("cat", "")),
                                       ts=float(ev.get("ts", 0.0)),
                                       dur=float(ev.get("dur", 0.0)),
                                       tid=int(ev.get("tid", 0)),
                                       args=dict(ev.get("args") or {})))
            elif ph in ("s", "t", "f"):
                flows.append(FlowPoint(id=int(ev.get("id", 0)), ph=ph,
                                       ts=float(ev.get("ts", 0.0)),
                                       tid=int(ev.get("tid", 0))))
            elif ph == "C":
                args = ev.get("args") or {}
                counters.append(CounterPoint(name=str(ev.get("name", "")),
                                             ts=float(ev.get("ts", 0.0)),
                                             value=float(
                                                 args.get("value", 0.0))))
            elif ph == "i":
                instants.append(InstantPoint(name=str(ev.get("name", "")),
                                             ts=float(ev.get("ts", 0.0)),
                                             tid=int(ev.get("tid", 0)),
                                             args=dict(ev.get("args") or {})))
            # "M" metadata and anything else: ignored.
        return cls(spans, flows, counters, instants)

    @classmethod
    def from_file(cls, path: str) -> "Trace":
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc, dict):
            events = doc.get("traceEvents", [])
        else:
            events = doc
        return cls.from_events(events)

    # -- queries -------------------------------------------------------------

    def wall_us(self) -> float:
        """First event start → last span end (0 for an empty trace)."""
        ts = [s.ts for s in self.spans] + [p.ts for p in self.flows] \
            + [c.ts for c in self.counters] + [i.ts for i in self.instants]
        if not ts:
            return 0.0
        ends = [s.end for s in self.spans] or ts
        return max(max(ends), max(ts)) - min(ts)

    def spans_named(self, name: str) -> List[SpanEvent]:
        return [s for s in self.spans if s.name == name]

    def enclosing_span(self, tid: int, ts: float) -> Optional[SpanEvent]:
        """The innermost span on ``tid`` containing ``ts``.

        Spans on one tid nest (same-thread context managers), so the
        latest-starting span that contains ``ts`` is the innermost.
        Scans backwards from the bisect point, bounded — pathological
        traces degrade to a miss, not a hang.
        """
        starts = self._tid_starts.get(tid)
        if not starts:
            return None
        spans = self._by_tid[tid]
        i = bisect.bisect_right(starts, ts) - 1
        lo = max(0, i - 256)
        for j in range(i, lo - 1, -1):
            s = spans[j]
            if s.ts <= ts <= s.end:
                return s
        return None


# ---------------------------------------------------------------------------
# Phase accounting.


@dataclass
class PhaseStat:
    phase: str
    total_us: float = 0.0
    count: int = 0
    max_us: float = 0.0

    @property
    def mean_us(self) -> float:
        return self.total_us / self.count if self.count else 0.0


@dataclass
class PhaseTable:
    stats: Dict[str, PhaseStat]
    wall_us: float

    @property
    def accounted_us(self) -> float:
        return sum(s.total_us for s in self.stats.values())

    def get(self, phase: str) -> PhaseStat:
        return self.stats.get(phase, PhaseStat(phase))

    def total_s(self, phase: str) -> float:
        return self.get(phase).total_us / 1e6

    def share(self, phase: str) -> float:
        acc = self.accounted_us
        return self.get(phase).total_us / acc if acc else 0.0

    def as_rows(self, prefix: str = "phase") -> List[tuple]:
        """``(name, value, derived)`` rows in the BENCH snapshot format —
        phase totals in seconds plus each phase's share of accounted
        time, so snapshot diffs can attribute a move to a phase."""
        rows: List[tuple] = []
        for ph in PHASE_ORDER:
            if ph not in self.stats:
                continue
            st = self.stats[ph]
            paper = PAPER_PHASE.get(ph, ph)
            rows.append((f"{prefix}/{ph}_s", st.total_us / 1e6,
                         f"{paper}: {st.count} spans, mean "
                         f"{st.mean_us:.0f} us, max {st.max_us:.0f} us"))
            rows.append((f"{prefix}/{ph}_share", self.share(ph),
                         f"{paper} share of accounted span time"))
        return rows

    def is_empty(self) -> bool:
        return not any(s.count for s in self.stats.values())


def phase_accounting(trace: Trace,
                     span_phase: Optional[Dict[str, str]] = None
                     ) -> PhaseTable:
    """Group wave-lifecycle span time into the paper's phase split."""
    mapping = SPAN_PHASE if span_phase is None else span_phase
    stats: Dict[str, PhaseStat] = {}
    for s in trace.spans:
        ph = mapping.get(s.name)
        if ph is None:
            continue
        st = stats.setdefault(ph, PhaseStat(ph))
        st.total_us += s.dur
        st.count += 1
        st.max_us = max(st.max_us, s.dur)
    return PhaseTable(stats=stats, wall_us=trace.wall_us())


def slow_waves(trace: Trace, k: int = 8,
               name: str = "wave.kernel") -> List[SpanEvent]:
    """The ``k`` longest spans of one wave phase, worst first."""
    return sorted(trace.spans_named(name),
                  key=lambda s: s.dur, reverse=True)[:max(0, k)]


# ---------------------------------------------------------------------------
# Critical paths from flow arrows.


@dataclass(frozen=True)
class PathSegment:
    name: str
    tid: int
    ts: float
    dur_us: float
    wait_us: float         # gap since previous segment's span ended
    args: dict = field(default_factory=dict)


@dataclass(frozen=True)
class FlowPath:
    id: int
    segments: Tuple[PathSegment, ...]

    @property
    def latency_us(self) -> float:
        if not self.segments:
            return 0.0
        first = self.segments[0]
        last = self.segments[-1]
        return (last.ts + last.dur_us) - first.ts

    @property
    def busy_us(self) -> float:
        return sum(s.dur_us for s in self.segments)

    @property
    def wait_us(self) -> float:
        return sum(s.wait_us for s in self.segments)


def critical_paths(trace: Trace) -> List[FlowPath]:
    """Rebuild each flow id's span chain: the request's critical path.

    Every flow point (start/step/end) is bound to the innermost span
    enclosing it on its own thread — the same binding rule Perfetto
    uses to draw the arrows.  Consecutive points landing in the same
    span dedupe to one segment; ``wait_us`` is the scheduling gap
    between one segment's span ending and the next one starting.
    """
    by_id: Dict[int, List[FlowPoint]] = {}
    for p in trace.flows:
        by_id.setdefault(p.id, []).append(p)
    paths: List[FlowPath] = []
    for fid in sorted(by_id):
        segs: List[PathSegment] = []
        prev_span: Optional[SpanEvent] = None
        for p in sorted(by_id[fid], key=lambda q: q.ts):
            s = trace.enclosing_span(p.tid, p.ts)
            if s is None or s is prev_span:
                continue
            wait = 0.0
            if prev_span is not None:
                wait = max(0.0, s.ts - prev_span.end)
            segs.append(PathSegment(name=s.name, tid=s.tid, ts=s.ts,
                                    dur_us=s.dur, wait_us=wait,
                                    args=dict(s.args)))
            prev_span = s
        if segs:
            paths.append(FlowPath(id=fid, segments=tuple(segs)))
    return paths


# ---------------------------------------------------------------------------
# Pipeline bubbles / occupancy.


@dataclass(frozen=True)
class Bubble:
    ts: float
    dur_us: float


@dataclass
class PipelineReport:
    span_us: float          # first busy start -> last busy end
    busy_us: float          # time with >=1 wave in flight
    bubbles: List[Bubble]
    mean_inflight: float    # time-weighted over the busy+idle span
    host_busy_us: float     # union of scatter/gather/traceback spans
    host_overlap_us: float  # host work overlapping device-busy time

    @property
    def bubble_us(self) -> float:
        return sum(b.dur_us for b in self.bubbles)

    @property
    def occupancy(self) -> float:
        return self.busy_us / self.span_us if self.span_us else 0.0

    @property
    def host_overlap_frac(self) -> float:
        return (self.host_overlap_us / self.host_busy_us
                if self.host_busy_us else 0.0)


def _union(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    if not intervals:
        return []
    intervals = sorted(intervals)
    out = [intervals[0]]
    for lo, hi in intervals[1:]:
        if lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _intersect_len(a: List[Tuple[float, float]],
                   b: List[Tuple[float, float]]) -> float:
    total = 0.0
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


def pipeline_analysis(trace: Trace,
                      counter: str = "inflight_waves") -> PipelineReport:
    """Reconstruct device occupancy from the inflight-waves counter.

    The counter samples form a step function; intervals where it is
    positive are device-busy, zero-valued gaps between them are
    pipeline **bubbles** (the host failed to keep a wave in flight).
    Falls back to the union of ``wave.kernel`` spans when the counter
    track is absent (e.g. a flight-recorder ring that rolled past it).
    """
    samples = [c for c in trace.counters if c.name == counter]
    busy: List[Tuple[float, float]] = []
    mean_inflight = 0.0
    if len(samples) >= 2:
        area = 0.0
        open_ts: Optional[float] = None
        for prev, cur in zip(samples, samples[1:]):
            dt = cur.ts - prev.ts
            area += prev.value * dt
            if prev.value > 0 and open_ts is None:
                open_ts = prev.ts
            elif prev.value <= 0 and open_ts is not None:
                busy.append((open_ts, prev.ts))
                open_ts = None
        last = samples[-1]
        if last.value > 0 and open_ts is None:
            open_ts = last.ts
        if open_ts is not None:
            end = max(last.ts, open_ts)
            if end > open_ts:
                busy.append((open_ts, end))
            elif not busy:
                busy.append((open_ts, open_ts))
        total_dt = samples[-1].ts - samples[0].ts
        mean_inflight = area / total_dt if total_dt > 0 else 0.0
    else:
        busy = _union([(s.ts, s.end) for s in trace.spans_named(
            "wave.kernel")])
        if busy:
            span = busy[-1][1] - busy[0][0]
            busy_total = sum(hi - lo for lo, hi in busy)
            mean_inflight = busy_total / span if span > 0 else 0.0
    busy = _union(busy)
    bubbles: List[Bubble] = []
    for (_, hi), (lo2, _) in zip(busy, busy[1:]):
        if lo2 > hi:
            bubbles.append(Bubble(ts=hi, dur_us=lo2 - hi))
    span_us = busy[-1][1] - busy[0][0] if busy else 0.0
    busy_us = sum(hi - lo for lo, hi in busy)
    host = _union([(s.ts, s.end) for s in trace.spans
                   if s.name in ("wave.scatter", "wave.gather",
                                 "wave.traceback")])
    host_busy_us = sum(hi - lo for lo, hi in host)
    host_overlap_us = _intersect_len(host, busy)
    return PipelineReport(span_us=span_us, busy_us=busy_us, bubbles=bubbles,
                          mean_inflight=mean_inflight,
                          host_busy_us=host_busy_us,
                          host_overlap_us=host_overlap_us)


# ---------------------------------------------------------------------------
# Diffing: trace-vs-trace and snapshot-vs-snapshot.


@dataclass(frozen=True)
class PhaseDelta:
    phase: str
    a_us: float
    b_us: float

    @property
    def ratio(self) -> float:
        if self.a_us == 0:
            return math.inf if self.b_us > 0 else 1.0
        return self.b_us / self.a_us


def diff_phase_tables(a: PhaseTable, b: PhaseTable) -> List[PhaseDelta]:
    """Per-phase deltas between two captures, biggest mover first."""
    phases = sorted(set(a.stats) | set(b.stats),
                    key=lambda p: PHASE_ORDER.index(p)
                    if p in PHASE_ORDER else len(PHASE_ORDER))
    deltas = [PhaseDelta(p, a.get(p).total_us, b.get(p).total_us)
              for p in phases]
    return sorted(deltas, key=_delta_magnitude, reverse=True)


@dataclass(frozen=True)
class RowDelta:
    name: str              # full row name, e.g. "serving/p99_ms"
    suite: str             # "serving"
    phase: str             # "p99_ms"
    a: float
    b: float

    @property
    def ratio(self) -> float:
        if self.a == 0:
            return math.inf if self.b > 0 else 1.0
        return self.b / self.a


def _delta_magnitude(d) -> float:
    r = d.ratio
    if r == math.inf:
        return math.inf
    if r <= 0:
        return math.inf
    return abs(math.log(r))


def diff_rows(rows_a: Dict[str, float],
              rows_b: Dict[str, float]) -> List[RowDelta]:
    """Attribute a snapshot regression to the (suite, phase) that moved.

    ``rows_*`` are BENCH-snapshot name→value maps (``suite/metric``).
    Only names present in both are compared; the result is sorted by
    relative movement (``|log ratio|``) so the first entry names the
    biggest mover.
    """
    deltas: List[RowDelta] = []
    for name in sorted(set(rows_a) & set(rows_b)):
        a, b = rows_a[name], rows_b[name]
        suite, _, phase = name.partition("/")
        deltas.append(RowDelta(name=name, suite=suite, phase=phase,
                               a=float(a), b=float(b)))
    return sorted(deltas, key=_delta_magnitude, reverse=True)
