"""`repro.obs` — unified tracing, metrics and profiling.

The paper's core argument is a *phase breakdown*: scatter / kernel /
gather time split across thousands of PIM workers decides whether PIM
beats the CPU (Fig. 1).  This package is the reproduction's common
measurement layer — every subsystem (engine waves, streaming session,
serve loop, read mapper, BiWFA recursion) emits the same vocabulary of
spans, counters and histograms, so one timeline shows a request's whole
life and one scrape shows the service's health:

* :mod:`repro.obs.trace` — a thread-safe span/instant/counter tracer
  emitting Chrome trace-event JSON (open in https://ui.perfetto.dev).
  Flow IDs follow a :class:`~repro.core.session.Ticket` from ``submit()``
  through pack → dispatch → kernel → retire → traceback (and, in the
  serve loop, a request from admit → wave-form → dispatch → delivery).
  A process-global switch gates everything: when off, every entry point
  is a single branch returning a shared no-op object — safe to leave
  compiled into the hot path (``benchmarks/obs_overhead.py`` gates it).
* :mod:`repro.obs.metrics` — a registry of counters, gauges and
  log-bucketed latency histograms (p50/p95/p99 from bounded buckets, not
  stored sample lists), with Prometheus text exposition (optionally over
  HTTP) and JSONL snapshots.
* :mod:`repro.obs.profile` — the ``jax.profiler`` bridge: wrap steady
  state in ``jax.profiler.trace(dir)`` (the ``--profile DIR`` flag on the
  launchers) with named ``TraceAnnotation``s that line up with our spans.
* :mod:`repro.obs.analyze` — the consumption side: typed trace loader,
  per-wave phase accounting (the paper's transfer/kernel/retrieve
  split), per-ticket critical paths from flow arrows, pipeline
  bubble/occupancy analysis, and trace/snapshot diffing that attributes
  a regression to the (suite, phase) that moved.
* :mod:`repro.obs.record` — the always-on flight recorder: a bounded
  ring of recent events kept live while full tracing is off, dumped as
  a Perfetto-viewable post-mortem on shed / timeout / failure /
  BiWFA fallback.

Quickstart::

    from repro import obs

    with obs.capture_trace("t.json"):        # enable -> run -> save
        engine.align(patterns, texts)

    obs.metrics.render_prometheus()          # scrape text
    obs.metrics.write_jsonl("metrics.jsonl") # append one snapshot line
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from repro.obs import analyze, metrics, profile, record, trace

__all__ = ["analyze", "capture_trace", "metrics", "profile", "record",
           "trace"]


@contextlib.contextmanager
def capture_trace(path: Optional[str]) -> Iterator[None]:
    """Enable tracing for a ``with`` block and save the Chrome-trace JSON
    to ``path`` on exit (``None`` → no-op, so callers can pass an optional
    CLI flag straight through).

    Nesting-safe: if tracing was already on when the block was entered
    (an outer capture is live), it stays on at exit — the inner capture
    saves its view of the shared timeline without clobbering the outer
    one's switch."""
    if not path:
        yield
        return
    was_on = trace.enabled()
    trace.enable()
    try:
        yield
    finally:
        trace.save(path)
        if not was_on:
            trace.disable()
