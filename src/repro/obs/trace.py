"""Thread-safe Chrome trace-event tracer (Perfetto-viewable).

One process-global tracer collects *events* — spans (complete ``"X"``
duration events), instants, counter samples and flow start/step/end
markers — and :func:`save` writes the standard Chrome trace-event JSON
(``{"traceEvents": [...]}``), which https://ui.perfetto.dev and
``chrome://tracing`` open directly.

Design constraints (this layer stays compiled into the hot path):

* **near-zero overhead when disabled** — every public entry point checks
  one module-global boolean and returns immediately; :func:`span` returns
  the shared :data:`NULL` no-op span (no allocation), so instrumented code
  pays a function call and a branch, nothing else.
  ``benchmarks/obs_overhead.py --check`` gates this (≤2% projected).
* **thread-safe** — events are appended under one lock; timestamps come
  from a single ``time.perf_counter`` origin so spans from any number of
  threads land on one consistent timeline (per-thread lanes via ``tid``).
* **flow IDs** — :func:`new_flow` allocates process-unique IDs;
  ``Span.flow_start/flow_step/flow_end`` emit flow events *inside* the
  span (same thread + a timestamp within the slice), which is how
  Perfetto binds the arrows: a ticket's flow connects its submit span to
  every wave dispatch/kernel/retire span it rode, across threads.

Usage::

    from repro.obs import trace

    trace.enable()
    with trace.span("wave.kernel", args={"rows": 256}) as sp:
        sp.flow_step(fid)          # arrow through this span
        ...
    trace.counter("inflight", 2)
    trace.save("t.json")           # open in ui.perfetto.dev
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from typing import Dict, Iterator, List, Optional

__all__ = ["NULL", "Span", "counter", "disable", "enable", "enabled",
           "events", "instant", "isolated", "new_flow", "reset", "save",
           "span"]

_PID = os.getpid()
_T0 = time.perf_counter()

# THE switch: one module-global read gates every emission path.
_on = False

# Secondary sink: the flight recorder (repro.obs.record).  When installed
# it receives every emitted event even while the full tracer is off, so a
# bounded ring of recent history exists to dump on failure.  ``None``
# keeps the disabled fast path a single extra global read.
_rec = None


def _set_recorder(rec) -> None:
    """Install/remove the flight-recorder sink (``repro.obs.record``
    owns this — instrumented code never calls it)."""
    global _rec
    _rec = rec

_lock = threading.Lock()
_events: List[dict] = []
_flow_ids = itertools.count(1)     # itertools.count is GIL-atomic

# Flow events must share one (name, cat) per id chain for the viewers to
# join the arrows; everything in this process is one logical stream.
_FLOW_NAME = "flow"
_FLOW_CAT = "flow"


def _now_us() -> float:
    return (time.perf_counter() - _T0) * 1e6


def _emit(ev: dict) -> None:
    if _on:
        with _lock:
            _events.append(ev)
    r = _rec
    if r is not None:
        r.record(ev)


# ---------------------------------------------------------------------------
# Switch / lifecycle.


def enable() -> None:
    """Turn the process-global tracer on (events start accumulating)."""
    global _on
    _on = True


def disable() -> None:
    global _on
    _on = False


def enabled() -> bool:
    """The single-branch check instrumented code uses for arg-building
    it wants to skip entirely when tracing is off."""
    return _on


def reset() -> None:
    """Drop every buffered event (the switch state is unchanged)."""
    with _lock:
        _events.clear()


def events() -> List[dict]:
    """A snapshot copy of the buffered events."""
    with _lock:
        return list(_events)


def save(path: str) -> str:
    """Write the buffered events as Chrome trace-event JSON → ``path``."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    meta = [{"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
             "args": {"name": "repro"}}]
    with _lock:
        payload = {"traceEvents": meta + list(_events),
                   "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f)
        f.write("\n")
    return path


def new_flow() -> int:
    """Allocate a process-unique flow ID (thread-safe)."""
    return next(_flow_ids)


# ---------------------------------------------------------------------------
# Spans.


class Span:
    """One duration event, emitted as a complete ``"X"`` record at exit.

    Created via :func:`span` (never directly) — when tracing is off that
    returns the shared no-op :data:`NULL` instead, so every method here
    can assume the tracer is live.
    """

    __slots__ = ("name", "cat", "args", "_ts", "_tid")

    def __init__(self, name: str, cat: str, args: Optional[dict]):
        self.name = name
        self.cat = cat
        self.args = dict(args) if args else {}
        self._tid = threading.get_ident()
        self._ts = _now_us()

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        _emit({"name": self.name, "cat": self.cat, "ph": "X",
               "ts": self._ts, "dur": _now_us() - self._ts,
               "pid": _PID, "tid": self._tid, "args": self.args})

    def set(self, **kw) -> "Span":
        """Attach args discovered mid-span."""
        self.args.update(kw)
        return self

    # -- flows: arrows binding this span into a cross-thread chain ----------

    def _flow(self, ph: str, fid: int) -> None:
        ev = {"name": _FLOW_NAME, "cat": _FLOW_CAT, "ph": ph, "id": int(fid),
              "ts": _now_us(), "pid": _PID, "tid": self._tid}
        if ph == "f":
            ev["bp"] = "e"        # bind the arrowhead to the enclosing slice
        _emit(ev)

    def flow_start(self, fid: int) -> None:
        self._flow("s", fid)

    def flow_step(self, fid: int) -> None:
        self._flow("t", fid)

    def flow_end(self, fid: int) -> None:
        self._flow("f", fid)


class _NullSpan:
    """The shared disabled-mode span: every operation is a no-op.

    A singleton, so disabled-mode ``span()`` allocates nothing — the
    identity ``span(...) is NULL`` is what the no-allocation test pins.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def set(self, **kw) -> "_NullSpan":
        return self

    def flow_start(self, fid: int) -> None:
        pass

    def flow_step(self, fid: int) -> None:
        pass

    def flow_end(self, fid: int) -> None:
        pass


NULL = _NullSpan()


def span(name: str, cat: str = "repro",
         args: Optional[dict] = None) -> "Span | _NullSpan":
    """Open a span (use as a context manager).  Disabled → :data:`NULL`.

    When the flight recorder is active the real span is created even
    with the tracer off, so the ring sees recent history; the fully-off
    path (no tracer, no recorder) still allocates nothing.
    """
    if not _on and _rec is None:
        return NULL
    return Span(name, cat, args)


def instant(name: str, cat: str = "repro",
            args: Optional[dict] = None) -> None:
    """Mark a point in time (thread-scoped instant event)."""
    if not _on and _rec is None:
        return
    _emit({"name": name, "cat": cat, "ph": "i", "s": "t",
           "ts": _now_us(), "pid": _PID, "tid": threading.get_ident(),
           "args": dict(args) if args else {}})


def counter(name: str, value: float, cat: str = "repro") -> None:
    """Sample a counter track (rendered as a stacked chart in Perfetto)."""
    if not _on and _rec is None:
        return
    _emit({"name": name, "cat": cat, "ph": "C",
           "ts": _now_us(), "pid": _PID, "tid": 0,
           "args": {"value": value}})


@contextlib.contextmanager
def isolated() -> Iterator[None]:
    """Run a block against a private event buffer, then restore.

    Self-measuring code (``benchmarks/obs_overhead.py``) toggles the
    tracer and emits hundreds of thousands of throwaway spans; under an
    outer live capture (``benchmarks.run --trace-out``) that would wipe
    or flood the shared timeline.  Inside this block the outer events
    and switch state are stashed and the buffer starts empty; on exit
    both are restored and everything emitted inside is dropped.
    """
    global _on
    with _lock:
        stash = list(_events)
        _events.clear()
    was_on = _on
    try:
        yield
    finally:
        _on = was_on
        with _lock:
            _events.clear()
            _events.extend(stash)
