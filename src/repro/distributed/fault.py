"""Fault tolerance: straggler detection, failure drills, elastic remesh.

At 1000+ nodes the failure model is: (a) slow workers (stragglers), (b) dead
workers, (c) whole-pod loss.  The framework's contract:

* training state is periodically checkpointed (``repro.checkpoint``) with
  *logical* shapes, so a restart may land on a different healthy-device count
  (``plan_elastic_mesh``) and simply re-device_put the state;
* the data pipeline is keyed by (seed, step, shard) (``repro.data``), so a
  restarted or reassigned worker regenerates exactly its shard — stragglers
  can be fenced and their shards reassigned without divergence;
* ``StragglerMonitor`` implements the detection policy (median-factor rule,
  the standard backup-task trigger from MapReduce onward).

This container has one real device, so node death is *simulated*
(``FailureInjector`` raises at a chosen step); the restart drill in
``tests/test_checkpoint.py`` and ``launch/train.py --simulate-failure``
exercises the full kill -> restore -> bit-identical-continuation path.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks per-worker step durations; flags workers slower than
    ``factor`` x the healthy median over a sliding window."""
    n_workers: int
    factor: float = 2.0
    window: int = 8

    def __post_init__(self):
        self._hist: Dict[int, List[float]] = {w: [] for w in range(self.n_workers)}

    def record(self, worker: int, duration: float) -> None:
        h = self._hist[worker]
        h.append(duration)
        if len(h) > self.window:
            h.pop(0)

    def _avg(self, w: int) -> Optional[float]:
        h = self._hist[w]
        return sum(h) / len(h) if h else None

    def stragglers(self) -> List[int]:
        avgs = {w: a for w in range(self.n_workers)
                if (a := self._avg(w)) is not None}
        if len(avgs) < 2:
            return []
        med = sorted(avgs.values())[len(avgs) // 2]
        return [w for w, a in avgs.items() if a > self.factor * med]

    def reassignment(self, shards_per_worker: int = 1) -> Dict[int, List[int]]:
        """Shard indices of stragglers -> healthy workers (round-robin)."""
        bad = set(self.stragglers())
        healthy = [w for w in range(self.n_workers) if w not in bad]
        if not healthy or not bad:
            return {}
        plan: Dict[int, List[int]] = {w: [] for w in healthy}
        i = 0
        for w in sorted(bad):
            for s in range(shards_per_worker):
                plan[healthy[i % len(healthy)]].append(w * shards_per_worker + s)
                i += 1
        return {w: s for w, s in plan.items() if s}


class FailureInjector:
    """Deterministic failure for restart drills: raises at a chosen step."""

    class SimulatedFailure(RuntimeError):
        pass

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def check(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise self.SimulatedFailure(f"simulated node failure at step {step}")


def plan_elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                      pods: int = 1) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) grid fitting ``n_devices`` healthy chips.

    Model parallelism is fixed by memory (a shard must hold 1/MP of the
    params), so elasticity comes from the data axis: we keep MP and shrink
    DP to the largest value with pods*DP*MP <= n_devices.  DP is rounded
    down to a power of two so global batch stays divisible.
    """
    per_pod = n_devices // pods
    dp = per_pod // model_parallel
    if dp < 1:
        raise ValueError(f"{n_devices} devices cannot fit model_parallel="
                         f"{model_parallel} x pods={pods}")
    dp = 1 << int(math.floor(math.log2(dp)))
    if pods > 1:
        return (pods, dp, model_parallel), ("pod", "data", "model")
    return (dp, model_parallel), ("data", "model")


@dataclasses.dataclass
class HeartbeatRegistry:
    """Liveness bookkeeping: workers ping; silence beyond ``timeout_s`` marks
    them dead.  The launcher consults ``dead()`` between steps and triggers
    checkpoint-restore with a re-planned mesh when membership changes."""
    n_workers: int
    timeout_s: float = 60.0

    def __post_init__(self):
        now = time.monotonic()
        self._last: Dict[int, float] = {w: now for w in range(self.n_workers)}

    def ping(self, worker: int, at: Optional[float] = None) -> None:
        self._last[worker] = time.monotonic() if at is None else at

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self._last.items() if now - t > self.timeout_s]

    def healthy_count(self, now: Optional[float] = None) -> int:
        return self.n_workers - len(self.dead(now))
