from repro.distributed import sharding  # noqa: F401
from repro.distributed import fault  # noqa: F401
