"""Version compatibility shims for the jax sharding API.

The codebase targets the modern mesh API (``jax.make_mesh(...,
axis_types=(AxisType.Auto, ...))``), but CI images pin older jax releases
(0.4.x) where ``jax.sharding.AxisType`` does not exist and ``make_mesh``
rejects the ``axis_types`` kwarg.  Everything that builds a mesh —
``launch.mesh``, the distributed sharding/fault tests, ad-hoc scripts —
goes through :func:`make_mesh` here so the rest of the tree never touches
the moving part of the API directly.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh


def auto_axis_types(n: int) -> Optional[Tuple]:
    """``(AxisType.Auto,) * n`` on jax versions that have it, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on every jax version.

    Older jax returns a one-element list of per-program dicts; newer jax
    returns the dict directly.  Either way an empty result becomes ``{}``.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported.

    Falls back to the positional-only signature on jax versions whose
    ``make_mesh`` predates the ``axis_types`` kwarg.
    """
    kwargs = {} if devices is None else {"devices": devices}
    types = auto_axis_types(len(shape))
    if types is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=types, **kwargs)
        except TypeError:
            pass  # old make_mesh: no axis_types kwarg
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
