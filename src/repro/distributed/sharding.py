"""Logical-axis sharding: one place that decides how every tensor shards.

The model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", "expert", "kv_seq", ...).  This module maps logical names onto mesh axes
(("pod",) "data", "model") and degrades gracefully: an axis whose size does not
divide the mesh-axis product is left unsharded (this is what makes e.g.
whisper's 8 heads, MQA's single KV head, or batch=1 long-context decode lower
cleanly on a 16x16 mesh).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> preferred mesh axes (in order; combined into one spec entry).
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "kv_seq": ("model",),     # KV-cache sequence axis (decode) — see DESIGN.md §6
    "seq": ("model",),        # activation seq axis (sequence parallelism, §Perf)
    "seq_data": ("data",),    # sequence sharding over the data axis (long ctx)
    "embed": (),              # d_model stays replicated across 'model'
    None: (),
}

_state = threading.local()


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = _mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except KeyError:
        return 1


def spec_entry(mesh: Mesh, dim_size: int, logical: Optional[str]):
    """Mesh axes for one tensor dim; drops axes that don't divide dim_size."""
    axes = [a for a in RULES.get(logical, ()) if a in mesh.axis_names]
    # Greedily keep the longest prefix whose product divides dim_size.
    kept: list[str] = []
    prod = 1
    for a in axes:
        n = mesh_axis_size(mesh, a)
        if n > 1 and dim_size % (prod * n) == 0:
            kept.append(a)
            prod *= n
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(mesh: Mesh, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
    assert len(shape) == len(logical), (shape, logical)
    return P(*[spec_entry(mesh, s, l) for s, l in zip(shape, logical)])


def sharding_for(mesh: Mesh, shape: Sequence[int], logical: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, shape, logical))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op outside one."""
    mesh = _mesh()
    if mesh is None or len(mesh.devices.flatten()) == 1:
        return x
    spec = spec_for(mesh, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param trees: leaves annotated at init with logical axes.


def ann(array, *axes):
    """Annotate a freshly-initialized parameter with logical axes."""
    assert len(axes) == array.ndim, (array.shape, axes)
    return (array, tuple(axes))


def split_annotations(tree):
    """(array, axes) leaf tree -> (param tree, logical-axes tree)."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "ndim")
    params = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return params, axes


def tree_shardings(mesh: Mesh, params, axes_tree):
    """Build a NamedSharding pytree for `params` from its logical-axes tree."""
    def one(p, ax):
        ax = tuple(ax)
        if len(ax) < p.ndim:  # stacked-layer leading dims added after init
            ax = (None,) * (p.ndim - len(ax)) + ax
        return sharding_for(mesh, p.shape, ax)

    return jax.tree.map(one, params, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))


def tree_specs(mesh: Mesh, params, axes_tree):
    return jax.tree.map(lambda s: s.spec, tree_shardings(mesh, params, axes_tree))


def zero_shardings(mesh: Mesh, params, axes_tree, *, data_axis: str = "data",
                   min_size: int = 1 << 16):
    """ZeRO-style 2-D parameter sharding: after the logical ('model') rules,
    shard the largest still-unsharded dim of every big tensor over the
    ``data`` axis.  Params, grads and optimizer moments then occupy
    1/(data*model) of their global size per device — the difference between
    a 32B-param train step fitting in 16 GB HBM or not (EXPERIMENTS.md
    §Dry-run).  XLA SPMD inserts the weight all-gathers / gradient
    reduce-scatters this implies (the ZeRO-3 communication pattern).
    Sharding stays *within* a pod: the pod axis is untouched, so cross-pod
    links only carry the data-parallel gradient reduction.
    """
    if data_axis not in mesh.axis_names or mesh_axis_size(mesh, data_axis) == 1:
        return tree_shardings(mesh, params, axes_tree)
    n = mesh_axis_size(mesh, data_axis)

    def one(p, ax):
        ax = tuple(ax)
        if len(ax) < p.ndim:
            ax = (None,) * (p.ndim - len(ax)) + ax
        spec = [spec_entry(mesh, s, l) for s, l in zip(p.shape, ax)]
        size = 1
        for s in p.shape:
            size *= int(s)
        if size >= min_size:
            # biggest unsharded dim divisible by the data-axis size
            cands = [(s, i) for i, (s, e) in enumerate(zip(p.shape, spec))
                     if e is None and s % n == 0]
            if cands:
                _, i = max(cands)
                spec[i] = data_axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, params, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))
