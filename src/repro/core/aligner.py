"""Legacy alignment API — thin wrappers over ``core.engine``.

.. deprecated::
    ``WFAligner`` predates the unified :class:`~repro.core.engine.
    AlignmentEngine` and is kept as a compatibility shim.  New code should
    construct an ``AlignmentEngine`` directly: it adds the backend registry
    (``core.backends``), length-bucketed batching, executable caching and
    adaptive two-pass overflow recovery that this wrapper only proxies.

``WFAligner.align`` delegates to an engine instance (so old call sites get
bucketing + caching for free); ``align_arrays`` remains the raw array-level
dispatch through the backend registry for code that manages its own bounds
(benchmarks, the PIM executor's compile warm-ups).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence

import numpy as np

from repro.core import cigar as cigar_mod
from repro.core import wavefront as wf
from repro.core.backends import get_backend
from repro.core.engine import (AlignmentEngine, Seq, encode, pack_batch,
                               problem_bounds)
from repro.core.penalties import DEFAULT, Penalties

__all__ = ["AlignResult", "WFAligner", "Seq", "encode", "pack_batch",
           "problem_bounds"]


# The char map this deprecated API always emitted ('M' = match only, 'X' =
# mismatch) — frozen here so legacy callers' output never shifts under
# them; new code uses EngineResult.cigar_strings(mode="extended"|"classic").
_LEGACY_CHARS = {cigar_mod.OP_M: "M", cigar_mod.OP_X: "X",
                 cigar_mod.OP_I: "I", cigar_mod.OP_D: "D"}


@dataclasses.dataclass
class AlignResult:
    scores: np.ndarray                      # [B] int32; -1 = exceeded s_max
    cigars: Optional[List[np.ndarray]]      # per-pair op arrays, or None
    n_steps: int                            # score-loop trips (telemetry)
    s_max: int
    k_max: int

    def cigar_strings(self) -> List[str]:
        if self.cigars is None:
            raise ValueError("align with with_cigar=True")
        return [cigar_mod.run_length_string(c, _LEGACY_CHARS)
                for c in self.cigars]


class WFAligner:
    """Compatibility façade over :class:`AlignmentEngine` (see module doc)."""

    def __init__(self, pen: Penalties = DEFAULT, *, backend: str = "ring",
                 edit_frac: Optional[float] = None,
                 s_max: Optional[int] = None, k_max: Optional[int] = None,
                 with_cigar: bool = False, penalties=None):
        warnings.warn(
            "WFAligner is deprecated; use repro.core.engine.AlignmentEngine "
            "(blocking align()) or AlignmentEngine.stream() for pipelined "
            "submission via repro.core.session.AlignmentSession",
            DeprecationWarning, stacklevel=2)
        if penalties is not None:
            # Engine-era spelling forwarded for convenience: accept it with
            # a warning instead of raising on an unknown kwarg.
            warnings.warn(
                "WFAligner(penalties=...) is the AlignmentEngine spelling; "
                "forwarding it as this aligner's penalty model "
                "(gap-affine triples map to scoring.GapAffine)",
                DeprecationWarning, stacklevel=2)
            pen = penalties
        self._engine = AlignmentEngine(pen, backend=backend,
                                       edit_frac=edit_frac, s_max=s_max,
                                       k_max=k_max, with_cigar=with_cigar)

    @property
    def engine(self) -> AlignmentEngine:
        return self._engine

    # Config lives on the engine (single source of truth): align() and
    # align_arrays() always see the same settings.
    @property
    def pen(self):
        return self._engine.pen

    @property
    def backend(self):
        return self._engine.backend

    @property
    def edit_frac(self):
        return self._engine.edit_frac

    @property
    def with_cigar(self):
        return self._engine.with_cigar

    @property
    def _s_max(self):
        return self._engine._s_max

    @property
    def _k_max(self):
        return self._engine._k_max

    # -- array-level entry point (jit-compatible batches) --------------------
    def align_arrays(self, pattern, text, plen, tlen, *, s_max: int,
                     k_max: int) -> wf.WFAResult:
        spec = get_backend(self.backend)
        return spec.fn(pattern, text, plen, tlen, pen=self.pen,
                       s_max=s_max, k_max=k_max)

    # -- sequence-level entry point -------------------------------------------
    def align(self, patterns: Sequence[Seq], texts: Sequence[Seq]) -> AlignResult:
        assert len(patterns) == len(texts)
        res = self._engine.align(patterns, texts)
        return AlignResult(res.scores, res.cigars, res.n_steps,
                           res.s_max, res.k_max)

    def align_pair(self, pattern: Seq, text: Seq) -> AlignResult:
        return self.align([pattern], [text])
