"""Public alignment API: encoding, padding/batching, backend selection.

``WFAligner`` is the user-facing object: it takes python sequences
(str/bytes/int arrays), pads them into rectangular device batches, sizes the
static WFA buffers from the configured divergence regime, and dispatches to a
backend:

* ``"ref"``    — full-history pure-jnp WFA (supports CIGAR traceback)
* ``"ring"``   — rolling-window pure-jnp WFA (score-only throughput mode)
* ``"kernel"`` — the Pallas TPU kernel (score-only; interpret=True on CPU)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import cigar as cigar_mod
from repro.core import wavefront as wf
from repro.core.penalties import DEFAULT, Penalties, band_bound, score_bound

Seq = Union[str, bytes, np.ndarray]


def encode(seq: Seq) -> np.ndarray:
    if isinstance(seq, str):
        return np.frombuffer(seq.encode("ascii"), dtype=np.uint8).astype(np.int32)
    if isinstance(seq, bytes):
        return np.frombuffer(seq, dtype=np.uint8).astype(np.int32)
    return np.asarray(seq, dtype=np.int32)


def pack_batch(seqs: Sequence[Seq], pad_to: Optional[int] = None,
               multiple: int = 1):
    """-> (codes [B, L] int32, lens [B] int32). Padding value 0 (never read)."""
    enc = [encode(s) for s in seqs]
    lens = np.asarray([len(e) for e in enc], np.int32)
    L = max(1, pad_to if pad_to is not None else int(lens.max(initial=1)))
    L = ((L + multiple - 1) // multiple) * multiple
    out = np.zeros((len(enc), L), np.int32)
    for i, e in enumerate(enc):
        out[i, : len(e)] = e
    return out, lens


@dataclasses.dataclass
class AlignResult:
    scores: np.ndarray                      # [B] int32; -1 = exceeded s_max
    cigars: Optional[List[np.ndarray]]      # per-pair op arrays, or None
    n_steps: int                            # score-loop trips (telemetry)
    s_max: int
    k_max: int

    def cigar_strings(self) -> List[str]:
        assert self.cigars is not None, "align with with_cigar=True"
        return [cigar_mod.cigar_string(c) for c in self.cigars]


def problem_bounds(pen: Penalties, plens: np.ndarray, tlens: np.ndarray,
                   edit_frac: Optional[float], s_max: Optional[int] = None,
                   k_max: Optional[int] = None) -> Tuple[int, int]:
    """Static (s_max, k_max) for a batch.

    With ``edit_frac`` (the paper's E): score_bound over the batch max length.
    Without it: the exact worst case (all-mismatch diagonal + one gap), which
    guarantees every pair terminates with a real score.
    """
    max_len = int(max(plens.max(initial=1), tlens.max(initial=1)))
    max_diff = int(np.abs(tlens - plens).max(initial=0))
    if s_max is None:
        if edit_frac is not None:
            s_max = score_bound(pen, max_len, edit_frac, len_diff=max_diff)
        else:
            # exact per-pair worst case (all-mismatch diagonal + one gap),
            # maxed over the batch so every pair is guaranteed to terminate
            worst = (pen.x * np.minimum(plens, tlens)
                     + np.where(plens != tlens,
                                pen.o + pen.e * np.abs(tlens - plens), 0))
            s_max = int(worst.max(initial=0)) + 1
    if k_max is None:
        k_max = min(band_bound(pen, s_max), max_len)
    k_max = max(k_max, max_diff, 1)
    return int(s_max), int(k_max)


class WFAligner:
    def __init__(self, pen: Penalties = DEFAULT, *, backend: str = "ring",
                 edit_frac: Optional[float] = None,
                 s_max: Optional[int] = None, k_max: Optional[int] = None,
                 with_cigar: bool = False):
        assert backend in ("ref", "ring", "kernel"), backend
        if with_cigar and backend != "ref":
            raise ValueError("CIGAR traceback needs backend='ref' "
                             "(full wavefront history)")
        self.pen = pen
        self.backend = backend
        self.edit_frac = edit_frac
        self._s_max = s_max
        self._k_max = k_max
        self.with_cigar = with_cigar

    # -- array-level entry point (jit-compatible batches) --------------------
    def align_arrays(self, pattern, text, plen, tlen, *, s_max: int,
                     k_max: int) -> wf.WFAResult:
        if self.backend == "ref":
            return wf.wfa_forward(pattern, text, plen, tlen, pen=self.pen,
                                  s_max=s_max, k_max=k_max, keep_history=True)
        if self.backend == "ring":
            return wf.wfa_scores(pattern, text, plen, tlen, pen=self.pen,
                                 s_max=s_max, k_max=k_max)
        from repro.kernels.wfa import ops as kops
        score = kops.wfa_align(pattern, text, plen, tlen, pen=self.pen,
                               s_max=s_max, k_max=k_max)
        return wf.WFAResult(score, None, None, None, np.int32(s_max))

    # -- sequence-level entry point -------------------------------------------
    def align(self, patterns: Sequence[Seq], texts: Sequence[Seq]) -> AlignResult:
        assert len(patterns) == len(texts)
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        s_max, k_max = problem_bounds(self.pen, plen, tlen, self.edit_frac,
                                      self._s_max, self._k_max)
        res = self.align_arrays(p, t, plen, tlen, s_max=s_max, k_max=k_max)
        cigars = None
        if self.with_cigar:
            cigars = cigar_mod.traceback_batch(res, self.pen, plen, tlen, k_max)
        return AlignResult(np.asarray(res.score), cigars, int(res.n_steps),
                           s_max, k_max)

    def align_pair(self, pattern: Seq, text: Seq) -> AlignResult:
        return self.align([pattern], [text])
