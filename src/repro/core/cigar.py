"""WFA traceback: wavefront history -> CIGAR op sequences.

Traceback is pointer-chasing over the [s_max+1, B, K] M/I/D history — an
inherently sequential, data-dependent walk, so (like the reference WFA2-lib,
and like the paper's host-side result handling) it runs on the host in numpy.
The throughput path (scores) never needs it; tests and the alignment examples
do.

Op codes match ``core.gotoh.score_cigar``: 0=M(match) 1=X(mismatch)
2=I(insert, consumes text) 3=D(delete, consumes pattern); -1 = padding.
"""
from __future__ import annotations

import numpy as np

from repro.core.penalties import Penalties
from repro.core.wavefront import NEG, _VALID_THRESH

OP_M, OP_X, OP_I, OP_D = 0, 1, 2, 3


def _get(hist, s, k, k_max):
    K = hist.shape[-1]
    j = k + k_max
    if s < 0 or j < 0 or j >= K:
        return NEG
    return int(hist[s, j])


def traceback_one(m_hist, i_hist, d_hist, pen: Penalties, score: int,
                  plen: int, tlen: int, k_max: int) -> np.ndarray:
    """Traceback for one pair. hist arrays are [s_max+1, K] for this pair."""
    if score < 0:
        return np.empty((0,), np.int8)
    x, o, e = pen.x, pen.o, pen.e
    ops: list[int] = []          # built back-to-front
    state = "M"
    s = int(score)
    k = tlen - plen
    h = tlen
    guard = 4 * (plen + tlen) + 4 * (s + 1) + 8
    while guard > 0:
        guard -= 1
        if state == "M":
            if s == 0:
                assert k == 0, (s, k, h)
                ops.extend([OP_M] * h)
                break
            cand_x = _get(m_hist, s - x, k, k_max)
            cand_x = cand_x + 1 if cand_x > _VALID_THRESH else NEG
            i_val = _get(i_hist, s, k, k_max)
            d_val = _get(d_hist, s, k, k_max)
            pre = max(cand_x, i_val, d_val)
            assert pre > _VALID_THRESH and h >= pre, (s, k, h, pre)
            ops.extend([OP_M] * (h - pre))
            h = pre
            if pre == cand_x:
                ops.append(OP_X)
                s -= x
                h -= 1
                # stay in M
            elif pre == i_val:
                state = "I"
            else:
                state = "D"
        elif state == "I":
            ext = _get(i_hist, s - e, k - 1, k_max) if s >= e else NEG
            ext = ext + 1 if ext > _VALID_THRESH else NEG
            ops.append(OP_I)
            if ext > _VALID_THRESH and h == ext:
                s -= e
                k -= 1
                h -= 1
                # stay in I (gap extension)
            else:
                opn = _get(m_hist, s - o - e, k - 1, k_max)
                assert opn > _VALID_THRESH and h == opn + 1, (s, k, h, opn)
                s -= o + e
                k -= 1
                h -= 1
                state = "M"
        else:  # "D"
            ext = _get(d_hist, s - e, k + 1, k_max) if s >= e else NEG
            ops.append(OP_D)
            if ext > _VALID_THRESH and h == ext:
                s -= e
                k += 1
                # stay in D
            else:
                opn = _get(m_hist, s - o - e, k + 1, k_max)
                assert opn > _VALID_THRESH and h == opn, (s, k, h, opn)
                s -= o + e
                k += 1
                state = "M"
    else:
        raise RuntimeError("traceback did not terminate")
    return np.asarray(ops[::-1], np.int8)


def traceback_batch(result, pen: Penalties, plen, tlen, k_max: int):
    """-> list of per-pair op arrays (ragged)."""
    m_h = np.asarray(result.m_hist)
    i_h = np.asarray(result.i_hist)
    d_h = np.asarray(result.d_hist)
    scores = np.asarray(result.score)
    plen = np.asarray(plen)
    tlen = np.asarray(tlen)
    return [
        traceback_one(m_h[:, b], i_h[:, b], d_h[:, b], pen, int(scores[b]),
                      int(plen[b]), int(tlen[b]), k_max)
        for b in range(scores.shape[0])
    ]


def cigar_string(ops: np.ndarray) -> str:
    """Run-length encode ops to a CIGAR-like string (M/X/I/D)."""
    chars = {OP_M: "M", OP_X: "X", OP_I: "I", OP_D: "D"}
    out = []
    run_c, run_n = None, 0
    for op in ops:
        c = chars[int(op)]
        if c == run_c:
            run_n += 1
        else:
            if run_c is not None:
                out.append(f"{run_n}{run_c}")
            run_c, run_n = c, 1
    if run_c is not None:
        out.append(f"{run_n}{run_c}")
    return "".join(out)
