"""WFA traceback: wavefront provenance -> CIGAR op sequences.

Two trace encodings come off the device (``core.wavefront``):

* **full history** — three ``[s_max+1, B, K]`` int32 offset arrays
  (``wfa_forward(keep_history=True)``, the ``ref`` backend).  Traceback is
  the classic pointer chase over stored offsets.
* **packed backtrace** — three ``[n_trace_words, B, K]`` int32 arrays of
  2-bit per-cell provenance codes (``wfa_scores_packed`` / the Pallas trace
  kernel), ~16x smaller.  Traceback decodes the packed words into the edit
  chain (phase A: walk codes from the end cell back to the origin), then
  replays that chain forward, re-deriving every match run by greedy
  extension against the sequences (phase B).  This is exact: each stored M
  wavefront value is the *maximal* extension, so replaying maximal LCP
  extension at every M-cell entry reproduces the forward offsets bit for
  bit.

Both encodings are **per penalty model** (``core.scoring``): gap-affine
traces walk the three-matrix M/I/D provenance, while linear models
(``GapLinear`` / ``Edit``) come off the device with a single M plane and
walk the one-matrix chain (every gap op sources M directly at cost ``e``).
Every decode is exact for the trace it is given — including traces
produced under a wavefront heuristic, whose *scores* are approximate but
whose provenance chains are internally consistent (pruned lanes are
unreachable: no surviving cell derives from one).

Traceback is a data-dependent walk, so (like the reference WFA2-lib, and
like the paper's host-side result handling) it runs on the host in numpy.
Malformed provenance (a bug, or corrupted words) raises
:class:`TracebackError` carrying the failing coordinates — never a bare
``assert`` (those are stripped under ``python -O``).

Op codes match ``core.gotoh.score_cigar``: 0=M(match) 1=X(mismatch)
2=I(insert, consumes text) 3=D(delete, consumes pattern); -1 = padding.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import scoring
from repro.core.wavefront import (BT_GAP_EXT, BT_GAP_OPEN, BT_M_FROM_D,
                                  BT_M_FROM_I, BT_M_FROM_X, NEG,
                                  TRACE_CELLS_PER_WORD, _VALID_THRESH)

OP_M, OP_X, OP_I, OP_D = 0, 1, 2, 3

_OP_CHARS_EXT = {OP_M: "=", OP_X: "X", OP_I: "I", OP_D: "D"}   # SAM 1.4
_OP_CHARS_CLASSIC = {OP_M: "M", OP_X: "M", OP_I: "I", OP_D: "D"}


class TracebackError(RuntimeError):
    """Inconsistent wavefront provenance during traceback.

    Carries the failing coordinates: ``pair`` (batch row), ``s`` (score),
    ``k`` (diagonal) and ``h`` (text offset, when known) so a corrupted
    trace pinpoints the cell instead of dying in a bare assert (which
    ``python -O`` would strip entirely).
    """

    def __init__(self, msg: str, *, pair: Optional[int] = None,
                 s: Optional[int] = None, k: Optional[int] = None,
                 h: Optional[int] = None):
        self.pair, self.s, self.k, self.h = pair, s, k, h
        where = ", ".join(f"{n}={v}" for n, v in
                          (("pair", pair), ("s", s), ("k", k), ("h", h))
                          if v is not None)
        super().__init__(f"{msg} ({where})" if where else msg)


# ---------------------------------------------------------------------------
# Full-history traceback (ref backend): pointer chase over stored offsets.


def _get(hist, s, k, k_max):
    K = hist.shape[-1]
    j = k + k_max
    if s < 0 or j < 0 or j >= K:
        return NEG
    return int(hist[s, j])


def traceback_one(m_hist, i_hist, d_hist, pen, score: int,
                  plen: int, tlen: int, k_max: int,
                  pair: Optional[int] = None, begin_state: str = "M",
                  end_state: str = "M") -> np.ndarray:
    """Gap-affine traceback for one pair. hist arrays are [s_max+1, K].

    ``begin_state``/``end_state`` mirror the solver's boundary states
    (BiWFA sub-alignments): the walk starts in ``end_state`` and may
    terminate on the gap seed cell ``I_0[0] = 0`` / ``D_0[0] = 0``
    (inherited open gap, no op of its own) instead of the M origin.
    """
    if score < 0:
        return np.empty((0,), np.int8)
    pen = scoring.as_model(pen)
    x, o, e = pen.x, pen.o, pen.e
    ops: list[int] = []          # built back-to-front
    state = end_state
    s = int(score)
    k = tlen - plen
    h = tlen
    guard = 4 * (plen + tlen) + 4 * (s + 1) + 8
    while guard > 0:
        guard -= 1
        if state == "M":
            if s == 0:
                if k != 0:
                    raise TracebackError("origin cell off diagonal 0",
                                         pair=pair, s=s, k=k, h=h)
                ops.extend([OP_M] * h)
                break
            cand_x = _get(m_hist, s - x, k, k_max)
            cand_x = cand_x + 1 if cand_x > _VALID_THRESH else NEG
            i_val = _get(i_hist, s, k, k_max)
            d_val = _get(d_hist, s, k, k_max)
            pre = max(cand_x, i_val, d_val)
            if pre <= _VALID_THRESH or h < pre:
                raise TracebackError("no valid M predecessor",
                                     pair=pair, s=s, k=k, h=h)
            ops.extend([OP_M] * (h - pre))
            h = pre
            if pre == cand_x:
                ops.append(OP_X)
                s -= x
                h -= 1
                # stay in M
            elif pre == i_val:
                state = "I"
            else:
                state = "D"
        elif state == "I":
            if s == 0:
                # gap seed cell (begin_state="I"): inherited open gap,
                # carries no op
                if begin_state != "I" or k != 0 or h != 0:
                    raise TracebackError("I chain hit s=0 off the gap seed",
                                         pair=pair, s=s, k=k, h=h)
                break
            ext = _get(i_hist, s - e, k - 1, k_max) if s >= e else NEG
            ext = ext + 1 if ext > _VALID_THRESH else NEG
            ops.append(OP_I)
            if ext > _VALID_THRESH and h == ext:
                s -= e
                k -= 1
                h -= 1
                # stay in I (gap extension)
            else:
                opn = _get(m_hist, s - o - e, k - 1, k_max)
                if opn <= _VALID_THRESH or h != opn + 1:
                    raise TracebackError("no valid I predecessor",
                                         pair=pair, s=s, k=k, h=h)
                s -= o + e
                k -= 1
                h -= 1
                state = "M"
        else:  # "D"
            if s == 0:
                if begin_state != "D" or k != 0 or h != 0:
                    raise TracebackError("D chain hit s=0 off the gap seed",
                                         pair=pair, s=s, k=k, h=h)
                break
            ext = _get(d_hist, s - e, k + 1, k_max) if s >= e else NEG
            ops.append(OP_D)
            if ext > _VALID_THRESH and h == ext:
                s -= e
                k += 1
                # stay in D
            else:
                opn = _get(m_hist, s - o - e, k + 1, k_max)
                if opn <= _VALID_THRESH or h != opn:
                    raise TracebackError("no valid D predecessor",
                                         pair=pair, s=s, k=k, h=h)
                s -= o + e
                k += 1
                state = "M"
    else:
        raise TracebackError("traceback did not terminate",
                             pair=pair, s=s, k=k, h=h)
    return np.asarray(ops[::-1], np.int8)


def traceback_linear_one(m_hist, pen, score: int, plen: int, tlen: int,
                         k_max: int, pair: Optional[int] = None) -> np.ndarray:
    """One-matrix (gap-linear / edit) traceback for one pair.

    With no gap-open cost there are no I/D states: every op (mismatch,
    insertion, deletion) sources M directly — mismatch at ``s - x`` on the
    same diagonal, gaps at ``s - e`` on the neighbouring diagonals.
    """
    if score < 0:
        return np.empty((0,), np.int8)
    model = scoring.as_model(pen)
    x, e = model.x, model.e
    ops: list[int] = []          # built back-to-front
    s = int(score)
    k = tlen - plen
    h = tlen
    guard = 4 * (plen + tlen) + 4 * (s + 1) + 8
    while guard > 0:
        guard -= 1
        if s == 0:
            if k != 0:
                raise TracebackError("origin cell off diagonal 0",
                                     pair=pair, s=s, k=k, h=h)
            ops.extend([OP_M] * h)
            break
        cand_x = _get(m_hist, s - x, k, k_max)
        cand_x = cand_x + 1 if cand_x > _VALID_THRESH else NEG
        cand_i = _get(m_hist, s - e, k - 1, k_max)
        cand_i = cand_i + 1 if cand_i > _VALID_THRESH else NEG
        cand_d = _get(m_hist, s - e, k + 1, k_max)
        pre = max(cand_x, cand_i, cand_d)
        if pre <= _VALID_THRESH or h < pre:
            raise TracebackError("no valid M predecessor",
                                 pair=pair, s=s, k=k, h=h)
        ops.extend([OP_M] * (h - pre))
        h = pre
        if pre == cand_x:
            ops.append(OP_X)
            s -= x
            h -= 1
        elif pre == cand_i:
            ops.append(OP_I)
            s -= e
            k -= 1
            h -= 1
        else:
            ops.append(OP_D)
            s -= e
            k += 1
    else:
        raise TracebackError("traceback did not terminate",
                             pair=pair, s=s, k=k, h=h)
    return np.asarray(ops[::-1], np.int8)


def traceback_batch(result, pen, plen, tlen, k_max: int,
                    begin_state: str = "M", end_state: str = "M"):
    """-> list of per-pair op arrays (ragged), dispatched on the model."""
    model = scoring.as_model(pen)
    m_h = np.asarray(result.m_hist)
    scores = np.asarray(result.score)
    plen = np.asarray(plen)
    tlen = np.asarray(tlen)
    if model.kind == "linear":
        if begin_state != "M" or end_state != "M":
            raise ValueError("linear models have no I/D boundary states")
        return [
            traceback_linear_one(m_h[:, b], model, int(scores[b]),
                                 int(plen[b]), int(tlen[b]), k_max, pair=b)
            for b in range(scores.shape[0])
        ]
    i_h = np.asarray(result.i_hist)
    d_h = np.asarray(result.d_hist)
    return [
        traceback_one(m_h[:, b], i_h[:, b], d_h[:, b], model, int(scores[b]),
                      int(plen[b]), int(tlen[b]), k_max, pair=b,
                      begin_state=begin_state, end_state=end_state)
        for b in range(scores.shape[0])
    ]


# ---------------------------------------------------------------------------
# Packed-backtrace traceback: decode 2-bit provenance words, replay forward.


def unpack_codes(words: np.ndarray, s_max: int) -> np.ndarray:
    """[n_words, ..., K] packed int32 -> [s_max+1, ..., K] uint8 codes.

    Vectorized word decode (tests and tooling; the traceback walk below
    decodes per-access instead, touching only the O(score) cells it visits).
    """
    words = np.asarray(words)
    s = np.arange(s_max + 1)
    w, off = s // TRACE_CELLS_PER_WORD, 2 * (s % TRACE_CELLS_PER_WORD)
    shaped = (slice(None),) + (None,) * (words.ndim - 1)
    return ((words[w] >> off[shaped]) & 3).astype(np.uint8)


def _code_at(words: np.ndarray, s: int, k: int, k_center: int) -> int:
    """2-bit code of cell (s, k) from one pair's [n_words, K] packed words."""
    j = k + k_center
    if s < 0 or j < 0 or j >= words.shape[-1] \
            or s // TRACE_CELLS_PER_WORD >= words.shape[0]:
        return 0
    return (int(words[s // TRACE_CELLS_PER_WORD, j])
            >> (2 * (s % TRACE_CELLS_PER_WORD))) & 3


def _lcp(p: np.ndarray, t: np.ndarray, v: int, h: int) -> int:
    """Greedy match run length of pattern[v:] vs text[h:] (vectorized)."""
    n = min(len(p) - v, len(t) - h)
    if n <= 0:
        return 0
    neq = np.flatnonzero(p[v:v + n] != t[h:h + n])
    return n if neq.size == 0 else int(neq[0])


def _replay(rev, p, t, plen: int, tlen: int,
            pair: Optional[int] = None, extend_start: bool = True) -> np.ndarray:
    """Phase B: replay a back-to-front edit chain forward, re-deriving each
    match run by maximal extension (exactly the forward pass's extend
    step).  ``rev`` holds ``(op, extend_after)`` pairs.  ``extend_start``
    is False when the chain terminated on a begin-state gap seed (the
    alignment opens mid-gap: no leading match run to re-derive)."""
    ops: list[int] = []
    v = h = 0
    if extend_start:
        r = _lcp(p, t, v, h)
        ops.extend([OP_M] * r)
        v += r
        h += r
    for op, extend_after in reversed(rev):
        if op == OP_X:
            if v >= plen or h >= tlen:
                raise TracebackError("mismatch op past sequence end",
                                     pair=pair, h=h)
            v += 1
            h += 1
        elif op == OP_I:
            if h >= tlen:
                raise TracebackError("insertion op past text end",
                                     pair=pair, h=h)
            h += 1
        else:  # OP_D
            if v >= plen:
                raise TracebackError("deletion op past pattern end",
                                     pair=pair, h=h)
            v += 1
        ops.append(op)
        if extend_after:
            r = _lcp(p, t, v, h)
            ops.extend([OP_M] * r)
            v += r
            h += r
    if v != plen or h != tlen:
        raise TracebackError(
            f"replay consumed ({v}, {h}) of ({plen}, {tlen})",
            pair=pair, h=h)
    return np.asarray(ops, np.int8)


def traceback_packed_one(m_bt, i_bt, d_bt, pen, score: int,
                         pattern, text, plen: int, tlen: int,
                         pair: Optional[int] = None, begin_state: str = "M",
                         end_state: str = "M") -> np.ndarray:
    """Gap-affine traceback for one pair from packed provenance words.

    ``m_bt/i_bt/d_bt`` are this pair's ``[n_words, K]`` int32 code words;
    ``pattern``/``text`` the (padded) integer code rows — needed because
    match runs are *replayed*, not stored.  The diagonal center is
    ``K // 2`` (true for both the jnp layout ``K = 2*k_max+1`` and the
    kernel's lane-padded layout).

    ``begin_state``/``end_state`` mirror the solver's boundary states
    (BiWFA sub-alignments): the walk starts in ``end_state``; a
    begin-state gap chain terminates on the (codeless) gap seed cell at
    ``s = 0``, and replay then skips the leading match extension (the
    alignment opens mid-gap).
    """
    if score < 0:
        return np.empty((0,), np.int8)
    pen = scoring.as_model(pen)
    x, o, e = pen.x, pen.o, pen.e
    kc = m_bt.shape[-1] // 2
    p = np.asarray(pattern)[:plen]
    t = np.asarray(text)[:tlen]

    # Phase A: walk provenance codes from the end cell to the origin.
    # Emits the *edit* chain only (no match runs) back-to-front; each op is
    # tagged with whether forward replay re-enters an M cell after it (and
    # so must re-extend matches there).
    s, k, state = int(score), tlen - plen, end_state
    rev: list[tuple[int, bool]] = []          # (op, extend_after)
    close = False                             # next gap op folds into M
    extend_start = True
    guard = 4 * (plen + tlen) + 4 * (s + 1) + 8
    while guard > 0:
        guard -= 1
        if state == "M":
            if s == 0:
                if k != 0:
                    raise TracebackError("origin cell off diagonal 0",
                                         pair=pair, s=s, k=k)
                break
            c = _code_at(m_bt, s, k, kc)
            if c == BT_M_FROM_X:
                rev.append((OP_X, True))
                s -= x
            elif c == BT_M_FROM_I:
                state, close = "I", True
            elif c == BT_M_FROM_D:
                state, close = "D", True
            else:
                raise TracebackError("invalid M provenance code",
                                     pair=pair, s=s, k=k)
        elif state == "I":
            if s == 0:
                # begin-state gap seed: inherited open gap, no op, no
                # leading match run before it
                if begin_state != "I" or k != 0:
                    raise TracebackError("I chain hit s=0 off the gap seed",
                                         pair=pair, s=s, k=k)
                extend_start = False
                break
            c = _code_at(i_bt, s, k, kc)
            if c == 0:
                raise TracebackError("invalid I provenance code",
                                     pair=pair, s=s, k=k)
            rev.append((OP_I, close))
            close = False
            k -= 1
            if c == BT_GAP_EXT:
                s -= e
            else:
                s -= o + e
                state = "M"
        else:  # "D"
            if s == 0:
                if begin_state != "D" or k != 0:
                    raise TracebackError("D chain hit s=0 off the gap seed",
                                         pair=pair, s=s, k=k)
                extend_start = False
                break
            c = _code_at(d_bt, s, k, kc)
            if c == 0:
                raise TracebackError("invalid D provenance code",
                                     pair=pair, s=s, k=k)
            rev.append((OP_D, close))
            close = False
            k += 1
            if c == BT_GAP_EXT:
                s -= e
            else:
                s -= o + e
                state = "M"
    else:
        raise TracebackError("packed traceback did not terminate",
                             pair=pair, s=s, k=k)

    return _replay(rev, p, t, plen, tlen, pair=pair,
                   extend_start=extend_start)


def traceback_packed_linear_one(m_bt, pen, score: int, pattern, text,
                                plen: int, tlen: int,
                                pair: Optional[int] = None) -> np.ndarray:
    """One-matrix (gap-linear / edit) traceback from the single packed
    M-provenance plane: code 1 = mismatch (``s - x``, same diagonal),
    2 = insertion (``s - e``, diagonal k-1), 3 = deletion (``s - e``,
    diagonal k+1).  Every op returns to an M cell, so forward replay
    re-extends matches after each one.
    """
    if score < 0:
        return np.empty((0,), np.int8)
    model = scoring.as_model(pen)
    x, e = model.x, model.e
    kc = m_bt.shape[-1] // 2
    p = np.asarray(pattern)[:plen]
    t = np.asarray(text)[:tlen]

    s, k = int(score), tlen - plen
    rev: list[tuple[int, bool]] = []          # (op, extend_after)
    guard = 4 * (plen + tlen) + 4 * (s + 1) + 8
    while guard > 0:
        guard -= 1
        if s == 0:
            if k != 0:
                raise TracebackError("origin cell off diagonal 0",
                                     pair=pair, s=s, k=k)
            break
        c = _code_at(m_bt, s, k, kc)
        if c == BT_M_FROM_X:
            rev.append((OP_X, True))
            s -= x
        elif c == BT_M_FROM_I:
            rev.append((OP_I, True))
            s -= e
            k -= 1
        elif c == BT_M_FROM_D:
            rev.append((OP_D, True))
            s -= e
            k += 1
        else:
            raise TracebackError("invalid M provenance code",
                                 pair=pair, s=s, k=k)
    else:
        raise TracebackError("packed traceback did not terminate",
                             pair=pair, s=s, k=k)

    return _replay(rev, p, t, plen, tlen, pair=pair)


def traceback_packed_batch(result, pen, pattern, text, plen, tlen,
                           begin_state: str = "M", end_state: str = "M"):
    """-> list of per-pair op arrays (ragged) from packed provenance,
    dispatched on the model's recurrence kind."""
    model = scoring.as_model(pen)
    m_bt = np.asarray(result.m_bt)
    scores = np.asarray(result.score)
    pattern = np.asarray(pattern)
    text = np.asarray(text)
    plen = np.asarray(plen).reshape(-1)
    tlen = np.asarray(tlen).reshape(-1)
    if model.kind == "linear":
        if begin_state != "M" or end_state != "M":
            raise ValueError("linear models have no I/D boundary states")
        return [
            traceback_packed_linear_one(m_bt[:, b], model, int(scores[b]),
                                        pattern[b], text[b], int(plen[b]),
                                        int(tlen[b]), pair=b)
            for b in range(scores.shape[0])
        ]
    i_bt = np.asarray(result.i_bt)
    d_bt = np.asarray(result.d_bt)
    return [
        traceback_packed_one(m_bt[:, b], i_bt[:, b], d_bt[:, b], model,
                             int(scores[b]), pattern[b], text[b],
                             int(plen[b]), int(tlen[b]), pair=b,
                             begin_state=begin_state, end_state=end_state)
        for b in range(scores.shape[0])
    ]


def traceback_result(result, pen, *, pattern, text, plen, tlen,
                     k_max: int, begin_state: str = "M",
                     end_state: str = "M"):
    """Dispatch on the trace encoding a ``WFAResult`` carries.

    Full offset history (``ref``) -> pointer-chase traceback; packed
    provenance words (``ring``/``kernel``/``shardmap``) -> decode + replay.
    ``pen`` may be a legacy ``Penalties`` triple or any ``PenaltyModel``;
    linear models decode their single M plane.  ``begin_state`` /
    ``end_state`` select BiWFA sub-alignment boundaries (affine only).
    """
    if getattr(result, "m_hist", None) is not None:
        return traceback_batch(result, pen, plen, tlen, k_max,
                               begin_state=begin_state, end_state=end_state)
    if getattr(result, "m_bt", None) is not None:
        return traceback_packed_batch(result, pen, pattern, text, plen,
                                      tlen, begin_state=begin_state,
                                      end_state=end_state)
    raise ValueError("result carries no trace (score-only backend output); "
                     "run the backend's trace variant (output='cigar')")


def trace_nbytes(result) -> int:
    """Host-visible bytes of whichever trace encoding ``result`` carries."""
    total = 0
    for f in ("m_hist", "i_hist", "d_hist", "m_bt", "i_bt", "d_bt"):
        arr = getattr(result, f, None)
        if arr is not None:
            total += arr.size * arr.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# CIGAR formatting / summary helpers.


def run_length_string(ops: np.ndarray, chars: dict) -> str:
    """Run-length encode ops (-1 padding skipped) under an op->char map."""
    out = []
    run_c, run_n = None, 0
    for op in ops:
        op = int(op)
        if op < 0:
            continue
        c = chars[op]
        if c == run_c:
            run_n += 1
        else:
            if run_c is not None:
                out.append(f"{run_n}{run_c}")
            run_c, run_n = c, 1
    if run_c is not None:
        out.append(f"{run_n}{run_c}")
    return "".join(out)


def cigar_string(ops: np.ndarray, mode: str = "extended") -> str:
    """Run-length encode ops to a CIGAR string.

    ``mode="extended"`` (default) distinguishes matches and mismatches the
    SAM 1.4 way (``=`` / ``X``); ``mode="classic"`` folds both into ``M``
    (pre-1.4 CIGAR, what most downstream tools expect).
    """
    if mode == "extended":
        chars = _OP_CHARS_EXT
    elif mode == "classic":
        chars = _OP_CHARS_CLASSIC
    else:
        raise ValueError(f"unknown cigar mode {mode!r}; "
                         "use 'extended' or 'classic'")
    return run_length_string(ops, chars)


def cigar_identity(ops: np.ndarray) -> float:
    """Fraction of alignment columns that are matches (gaps count as
    columns; the read-mapping 'BLAST identity').  Empty alignments (both
    sequences empty) are identical by convention — callers must mask
    *unresolved* pairs (``score == -1``, also empty ops) themselves, as
    :meth:`EngineResult.cigar_identities` does (NaN)."""
    ops = np.asarray(ops)
    ops = ops[ops >= 0]
    if ops.size == 0:
        return 1.0
    return float((ops == OP_M).sum()) / float(ops.size)
