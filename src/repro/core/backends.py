"""Alignment backend registry.

A *backend* is one way to evaluate a batch of WFA problems on device.  The
engine (``core.engine``) is backend-agnostic: it plans buckets, sizes the
static ``(s_max, k_max)`` buffers, caches executables and recovers overflow
pairs, then hands each rectangular batch to whatever backend the user named.
New strategies (bidirectional, banded, a new kernel) plug in with
:func:`register_backend` and never touch the engine.

Contract — a backend callable has the signature::

    fn(pattern, text, plen, tlen, *, pen, s_max, k_max, **extra) -> WFAResult

with ``pattern``/``text`` ``[B, L]`` int32 device/host arrays, ``plen``/
``tlen`` ``[B]`` int32, and static ``pen``/``s_max``/``k_max``.  It must be
jit-traceable (the engine compiles one executable per bucket shape around
it).

The contract has two *scoring axes* (``core.scoring``):

* ``pen`` may be any :class:`~repro.core.scoring.PenaltyModel` (or a legacy
  gap-affine ``Penalties`` triple).  ``BackendSpec.models`` names the
  recurrence kinds a backend serves (``"affine"`` / ``"linear"``); the four
  built-ins serve both (their solvers statically specialize per model),
  while plug-ins default to affine-only until they declare otherwise.
* a backend that also understands **wavefront heuristics** takes a ``heur``
  keyword (a :class:`~repro.core.scoring.WavefrontHeuristic`, static).  The
  engine only passes ``heur`` when a non-exact heuristic is requested, so
  heuristic-unaware plug-ins keep working for exact alignment and fail
  loudly (not wrongly) when pruning is asked of them.

Every backend serves two *output modes* (the engine's
``output="score" | "cigar"``):

* ``fn`` — the score-only throughput path;
* ``trace_variant`` — same signature, but the returned ``WFAResult`` also
  carries a trace that ``core.cigar`` can turn into exact CIGARs: either
  the full offset history (``m_hist``/``i_hist``/``d_hist``) or the ~16x
  smaller packed 2-bit provenance words (``m_bt``/``i_bt``/``d_bt``; the
  I/D planes are ``None`` for linear models).  ``supports_cigar`` is
  simply "has a trace variant"; score-only plug-ins may omit it.

Backends that shard over a device mesh set ``needs_mesh`` and receive the
engine's ``mesh`` as a keyword.  Two further hooks tune how the engine
*drives* a backend (both optional):

* ``donate_args`` — positional indices of ``(pattern, text, plen, tlen)``
  whose device buffers may be donated to the executable
  (``jit(donate_argnums=...)``).  On GPU/TPU this lets XLA alias the
  ``[B]`` int32 score output onto a spent input buffer, so a streaming
  session's double-buffered waves don't accumulate dead input allocations.
  Ignored on CPU (donation is unsupported there).
* ``dispatch`` — ``dispatch(exe_fn, *arrays) -> WFAResult`` intercepts the
  jitted call itself.  The engine and the streaming session route every
  wave through it, so a backend can split a wave across streams, add
  tracing, or stage inputs its own way without touching engine code.

Built-ins (all CIGAR-capable, all serving every penalty model and
heuristic):

* ``"ref"``      — pure-jnp WFA; trace variant keeps the full offset
                   history (the memory-hungry oracle path)
* ``"ring"``     — rolling-window pure-jnp WFA; trace variant records the
                   packed backtrace alongside the rings
* ``"kernel"``   — the Pallas TPU kernel (interpret=True on CPU); trace
                   variant OR-accumulates packed words in VMEM
* ``"shardmap"`` — ring solver inside ``shard_map`` (per-shard termination,
                   zero collectives — the paper's "no inter-DPU
                   communication"); trace variant runs the packed solver
                   per shard
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import wavefront as wf

ALL_MODELS = ("affine", "linear")


def _accepts_kw(fn: Optional[Callable], kw: str) -> bool:
    """True when ``fn`` takes keyword ``kw`` (or ``**kwargs``)."""
    if fn is None:
        return False
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):    # builtins / odd callables: assume yes
        return True
    if kw in sig.parameters:
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in sig.parameters.values())


def _accepts_heur(fn: Optional[Callable]) -> bool:
    """True when ``fn`` takes a ``heur`` keyword (or ``**kwargs``)."""
    return _accepts_kw(fn, "heur")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: Callable[..., wf.WFAResult]
    trace_variant: Optional[Callable[..., wf.WFAResult]] = None
    meet_variant: Optional[Callable[..., "wf.BidirMeetResult"]] = None
    needs_mesh: bool = False
    donate_args: Tuple[int, ...] = ()
    dispatch: Optional[Callable[..., wf.WFAResult]] = None
    models: Tuple[str, ...] = ("affine",)
    doc: str = ""

    @property
    def supports_cigar(self) -> bool:
        return self.trace_variant is not None

    def supports_model(self, kind: str) -> bool:
        return kind in self.models

    def accepts_heuristic(self, output: str = "score") -> bool:
        """Whether the callable serving ``output`` takes ``heur=``."""
        return _accepts_heur(self.fn if output == "score"
                             else self.trace_variant)

    def callables(self) -> Tuple[Callable, ...]:
        """Every non-None solver callable this backend exposes (used by the
        engine to validate ``backend_opts`` keys up front)."""
        return tuple(f for f in (self.fn, self.trace_variant,
                                 self.meet_variant) if f is not None)

    def accepts_states(self) -> bool:
        """Whether the trace variant takes ``begin_state``/``end_state``
        (the BiWFA recursion's boundary-constrained sub-alignments).  The
        engine silently substitutes the ``ring`` trace path for stateful
        children on backends that don't."""
        return _accepts_kw(self.trace_variant, "begin_state")

    def variant(self, output: str,
                model_kind: str = "affine") -> Callable[..., wf.WFAResult]:
        """The callable serving one output mode ('score' or 'cigar') under
        one penalty-model recurrence kind ('affine' or 'linear')."""
        if model_kind not in self.models:
            raise ValueError(
                f"backend {self.name!r} serves penalty models "
                f"{self.models}; {model_kind!r} models need one of: "
                f"{model_backends(model_kind)}")
        if output == "score":
            return self.fn
        if output == "cigar":
            if self.trace_variant is None:
                raise ValueError(
                    f"backend {self.name!r} is score-only (no trace "
                    f"variant); CIGAR-capable backends: "
                    f"{cigar_backends()}")
            return self.trace_variant
        raise ValueError(f"unknown output mode {output!r}; "
                         "use 'score' or 'cigar'")


_REGISTRY: Dict[str, BackendSpec] = {}


def register_backend(name: str, fn: Optional[Callable] = None, *,
                     trace_variant: Optional[Callable] = None,
                     meet_variant: Optional[Callable] = None,
                     supports_cigar: bool = False,
                     needs_mesh: bool = False,
                     donate_args: Tuple[int, ...] = (),
                     dispatch: Optional[Callable] = None,
                     models: Tuple[str, ...] = ("affine",),
                     doc: str = ""):
    """Register an alignment backend (usable as a decorator).

    Re-registering a name replaces the previous entry (useful for tests and
    for swapping in tuned variants).  ``models`` declares the penalty-model
    recurrence kinds the backend serves (plug-ins default to affine-only;
    pass ``models=("affine", "linear")`` when the backend handles linear
    models too).  ``meet_variant`` optionally replaces the shared jnp
    BiWFA meet solver (``wf.wfa_bidir_meet`` — same signature and
    ``BidirMeetResult`` contract) for ``trace_variant="bidir"`` meet
    waves.  ``supports_cigar=True`` is the deprecated pre-output-mode
    spelling for backends whose ``fn`` itself returns a traceback-capable
    ``WFAResult`` (full history, like the old ``ref``): it makes ``fn``
    double as the trace variant.
    """
    def _add(f):
        tv = trace_variant
        if tv is None and supports_cigar:
            tv = f
        _REGISTRY[name] = BackendSpec(name=name, fn=f,
                                      trace_variant=tv,
                                      meet_variant=meet_variant,
                                      needs_mesh=needs_mesh,
                                      donate_args=tuple(donate_args),
                                      dispatch=dispatch,
                                      models=tuple(models),
                                      doc=doc or (f.__doc__ or "").strip())
        return f

    if fn is not None:
        return _add(fn)
    return _add


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown alignment backend {name!r}; "
                       f"available: {available_backends()}") from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


def cigar_backends() -> List[str]:
    """Backends with a trace variant (serve ``output='cigar'``)."""
    return sorted(n for n, s in _REGISTRY.items() if s.supports_cigar)


def model_backends(kind: str) -> List[str]:
    """Backends serving penalty models of recurrence ``kind``."""
    return sorted(n for n, s in _REGISTRY.items() if s.supports_model(kind))


# ---------------------------------------------------------------------------
# Built-in backends.


def _ref_trace(pattern, text, plen, tlen, *, pen, s_max, k_max, heur=None,
               begin_state="M", end_state="M"):
    return wf.wfa_forward(pattern, text, plen, tlen, pen=pen,
                          s_max=s_max, k_max=k_max, keep_history=True,
                          heur=heur, begin_state=begin_state,
                          end_state=end_state)


@register_backend("ref", trace_variant=_ref_trace, models=ALL_MODELS,
                  doc="pure-jnp WFA; full-history CIGAR traceback")
def _ref_backend(pattern, text, plen, tlen, *, pen, s_max, k_max, heur=None):
    return wf.wfa_forward(pattern, text, plen, tlen, pen=pen,
                          s_max=s_max, k_max=k_max, keep_history=False,
                          heur=heur)


def _ring_trace(pattern, text, plen, tlen, *, pen, s_max, k_max, heur=None,
                begin_state="M", end_state="M", band_cap=None):
    return wf.wfa_scores_packed(pattern, text, plen, tlen, pen=pen,
                                s_max=s_max, k_max=k_max, heur=heur,
                                begin_state=begin_state, end_state=end_state,
                                band_cap=band_cap)


# The [B] int32 length buffers are donatable: the [B] int32 score output
# can alias one of them, so streamed waves recycle device memory.
@register_backend("ring", donate_args=(2, 3), trace_variant=_ring_trace,
                  models=ALL_MODELS,
                  doc="rolling-window pure-jnp WFA; packed backtrace")
def _ring_backend(pattern, text, plen, tlen, *, pen, s_max, k_max, heur=None,
                  band_cap=None):
    return wf.wfa_scores(pattern, text, plen, tlen, pen=pen,
                         s_max=s_max, k_max=k_max, heur=heur,
                         band_cap=band_cap)


def _kernel_trace(pattern, text, plen, tlen, *, pen, s_max, k_max,
                  heur=None, block_pairs=None, gather=None, ext_stride=1,
                  band_cap=None):
    from repro.kernels.wfa import ops as kops  # lazy: pallas import is heavy
    score, m_bt, i_bt, d_bt = kops.wfa_align_trace(
        pattern, text, plen, tlen, pen=pen, s_max=s_max, k_max=k_max,
        heur=heur, block_pairs=block_pairs, gather=gather,
        ext_stride=ext_stride, band_cap=band_cap)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max),
                        m_bt, i_bt, d_bt)


def _kernel_meet(pattern, text, plen, tlen, starget, *, pen, s_max, k_max,
                 heur=None, begin_state="M", end_state="M",
                 block_pairs=None):
    from repro.kernels.wfa import ops as kops  # lazy: pallas import is heavy
    return kops.wfa_bidir_meet_kernel(
        pattern, text, plen, tlen, starget, pen=pen, s_max=s_max,
        k_max=k_max, heur=heur, begin_state=begin_state,
        end_state=end_state, block_pairs=block_pairs)


@register_backend("kernel", donate_args=(2, 3), trace_variant=_kernel_trace,
                  meet_variant=_kernel_meet,
                  models=ALL_MODELS,
                  doc="Pallas TPU kernel (interpret on CPU); packed "
                      "backtrace in VMEM; fused in-grid BiWFA meet")
def _kernel_backend(pattern, text, plen, tlen, *, pen, s_max, k_max,
                    heur=None, block_pairs=None, gather=None, ext_stride=1,
                    band_cap=None):
    from repro.kernels.wfa import ops as kops  # lazy: pallas import is heavy
    score = kops.wfa_align(pattern, text, plen, tlen, pen=pen,
                           s_max=s_max, k_max=k_max, heur=heur,
                           block_pairs=block_pairs, gather=gather,
                           ext_stride=ext_stride, band_cap=band_cap)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max))


def _shardmap_trace(pattern, text, plen, tlen, *, pen, s_max, k_max, mesh,
                    heur=None, band_cap=None):
    score, m_bt, i_bt, d_bt = wf.wfa_trace_shardmap(
        pattern, text, plen, tlen, pen=pen, s_max=s_max, k_max=k_max,
        mesh=mesh, heur=heur, band_cap=band_cap)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max),
                        m_bt, i_bt, d_bt)


@register_backend("shardmap", needs_mesh=True, trace_variant=_shardmap_trace,
                  models=ALL_MODELS,
                  doc="ring solver in shard_map: per-shard termination, "
                      "zero collectives; per-shard packed backtrace")
def _shardmap_backend(pattern, text, plen, tlen, *, pen, s_max, k_max, mesh,
                      heur=None, band_cap=None):
    score = wf.wfa_scores_shardmap(pattern, text, plen, tlen, pen=pen,
                                   s_max=s_max, k_max=k_max, mesh=mesh,
                                   heur=heur, band_cap=band_cap)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max))
