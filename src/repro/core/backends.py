"""Alignment backend registry.

A *backend* is one way to evaluate a batch of WFA problems on device.  The
engine (``core.engine``) is backend-agnostic: it plans buckets, sizes the
static ``(s_max, k_max)`` buffers, caches executables and recovers overflow
pairs, then hands each rectangular batch to whatever backend the user named.
New strategies (bidirectional, banded, a new kernel) plug in with
:func:`register_backend` and never touch the engine.

Contract — a backend callable has the signature::

    fn(pattern, text, plen, tlen, *, pen, s_max, k_max, **extra) -> WFAResult

with ``pattern``/``text`` ``[B, L]`` int32 device/host arrays, ``plen``/
``tlen`` ``[B]`` int32, and static ``pen``/``s_max``/``k_max``.  It must be
jit-traceable (the engine compiles one executable per bucket shape around
it).  Backends that keep the full wavefront history set ``supports_cigar``;
backends that shard over a device mesh set ``needs_mesh`` and receive the
engine's ``mesh`` as a keyword.

Two hooks tune how the engine *drives* a backend (both optional):

* ``donate_args`` — positional indices of ``(pattern, text, plen, tlen)``
  whose device buffers may be donated to the executable
  (``jit(donate_argnums=...)``).  On GPU/TPU this lets XLA alias the
  ``[B]`` int32 score output onto a spent input buffer, so a streaming
  session's double-buffered waves don't accumulate dead input allocations.
  Ignored on CPU (donation is unsupported there).
* ``dispatch`` — ``dispatch(exe_fn, *arrays) -> WFAResult`` intercepts the
  jitted call itself.  The engine and the streaming session route every
  wave through it, so a backend can split a wave across streams, add
  tracing, or stage inputs its own way without touching engine code.

Built-ins:

* ``"ref"``      — full-history pure-jnp WFA (CIGAR traceback capable)
* ``"ring"``     — rolling-window pure-jnp WFA (score-only throughput mode)
* ``"kernel"``   — the Pallas TPU kernel (score-only; interpret=True on CPU)
* ``"shardmap"`` — ring solver inside ``shard_map`` (per-shard termination,
  zero collectives — the paper's "no inter-DPU communication")
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import wavefront as wf


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: Callable[..., wf.WFAResult]
    supports_cigar: bool = False
    needs_mesh: bool = False
    donate_args: Tuple[int, ...] = ()
    dispatch: Optional[Callable[..., wf.WFAResult]] = None
    doc: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}


def register_backend(name: str, fn: Optional[Callable] = None, *,
                     supports_cigar: bool = False, needs_mesh: bool = False,
                     donate_args: Tuple[int, ...] = (),
                     dispatch: Optional[Callable] = None,
                     doc: str = ""):
    """Register an alignment backend (usable as a decorator).

    Re-registering a name replaces the previous entry (useful for tests and
    for swapping in tuned variants).
    """
    def _add(f):
        _REGISTRY[name] = BackendSpec(name=name, fn=f,
                                      supports_cigar=supports_cigar,
                                      needs_mesh=needs_mesh,
                                      donate_args=tuple(donate_args),
                                      dispatch=dispatch,
                                      doc=doc or (f.__doc__ or "").strip())
        return f

    if fn is not None:
        return _add(fn)
    return _add


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown alignment backend {name!r}; "
                       f"available: {available_backends()}") from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in backends.


@register_backend("ref", supports_cigar=True,
                  doc="full-history pure-jnp WFA (CIGAR traceback)")
def _ref_backend(pattern, text, plen, tlen, *, pen, s_max, k_max):
    return wf.wfa_forward(pattern, text, plen, tlen, pen=pen,
                          s_max=s_max, k_max=k_max, keep_history=True)


# The [B] int32 length buffers are donatable: the [B] int32 score output
# can alias one of them, so streamed waves recycle device memory.
@register_backend("ring", donate_args=(2, 3),
                  doc="rolling-window pure-jnp WFA (score-only)")
def _ring_backend(pattern, text, plen, tlen, *, pen, s_max, k_max):
    return wf.wfa_scores(pattern, text, plen, tlen, pen=pen,
                         s_max=s_max, k_max=k_max)


@register_backend("kernel", donate_args=(2, 3),
                  doc="Pallas TPU kernel (score-only; interpret on CPU)")
def _kernel_backend(pattern, text, plen, tlen, *, pen, s_max, k_max):
    from repro.kernels.wfa import ops as kops  # lazy: pallas import is heavy
    score = kops.wfa_align(pattern, text, plen, tlen, pen=pen,
                           s_max=s_max, k_max=k_max)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max))


@register_backend("shardmap", needs_mesh=True,
                  doc="ring solver in shard_map: per-shard termination, "
                      "zero collectives")
def _shardmap_backend(pattern, text, plen, tlen, *, pen, s_max, k_max, mesh):
    score = wf.wfa_scores_shardmap(pattern, text, plen, tlen, pen=pen,
                                   s_max=s_max, k_max=k_max, mesh=mesh)
    return wf.WFAResult(score, None, None, None, jnp.int32(s_max))
