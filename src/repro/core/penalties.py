"""Gap-affine penalties and WFA score / diagonal-band bounds.

Convention (Marco-Sola et al. 2021): match = 0, mismatch = x, a gap of
length L costs o + L*e.  WFA propagates wavefronts in increasing score
order, so every buffer in the batched implementation is statically sized
from an upper bound on the final score (``s_max``) and on the reachable
diagonal range (``k_max``).  The bounds below are what the paper's regime
(reads of length L with edit-distance threshold E) implies.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Penalties:
    x: int = 4   # mismatch
    o: int = 6   # gap open
    e: int = 2   # gap extend

    def __post_init__(self):
        assert self.x > 0 and self.o >= 0 and self.e > 0, self

    @property
    def window(self) -> int:
        """Ring-buffer depth: wavefront s reads s-x, s-e and s-o-e."""
        return max(self.x, self.o + self.e) + 1

    def gap_cost(self, length: int) -> int:
        return 0 if length == 0 else self.o + length * self.e


DEFAULT = Penalties()


def score_bound(pen: Penalties, max_len: int, edit_frac: float,
                len_diff: int = 0, slack: int = 2) -> int:
    """Upper bound on the WFA score for a pair within ``edit_frac`` edits.

    Each of the <= ceil(E*L) edits costs at most max(x, o+e) (an isolated
    mismatch or a 1-long gap; longer gaps amortize cheaper per edit), and a
    length difference of d forces a gap of length >= d.
    """
    n_err = int(math.ceil(edit_frac * max_len))
    per = max(pen.x, pen.o + pen.e)
    return n_err * per + pen.o + abs(len_diff) * pen.e + slack


def band_bound(pen: Penalties, s_max: int) -> int:
    """Max |diagonal| reachable with score <= s_max.

    Moving one diagonal away from k=0 needs at least one gap extension, and
    leaving k=0 at all needs one gap opening:  |k| <= (s_max - o) / e.
    """
    if s_max <= pen.o + pen.e:
        return 1
    return (s_max - pen.o) // pen.e + 1


def problem_dims(pen: Penalties, max_len: int, edit_frac: float,
                 len_diff: int = 0):
    """-> (s_max, k_max, K) static buffer dims for a batch."""
    s_max = score_bound(pen, max_len, edit_frac, len_diff)
    k_max = min(band_bound(pen, s_max), max_len)
    return s_max, k_max, 2 * k_max + 1
