"""`AlignmentEngine` — the unified alignment façade.

The paper's throughput comes from keeping thousands of independent WFA
problems saturating the hardware with minimal host<->device overhead.  This
module owns every policy decision on that path, in one place:

* **backend registry** (``core.backends``) — ``ref`` / ``ring`` / ``kernel``
  / ``shardmap`` (and user plug-ins via ``register_backend``) are looked up
  by name; the engine never hard-codes a dispatch chain.
* **length-bucketed batching** — pairs are grouped by the power of two of
  ``max(plen, tlen)``, so short reads stop paying the longest pair's padded
  ``K`` band and score loop.  Each bucket gets its own static
  ``(L, s_max, k_max)`` problem shape.
* **executable caching** — compiled executables are cached per
  ``(backend, penalties, batch-shape, bounds)``.  Bucket dims are quantized
  (power-of-two lengths and pair counts, ``s_max`` rounded up) precisely so
  that serving-time traffic keeps hitting the same few shapes: repeated
  ``align()`` calls re-trace nothing.
* **adaptive two-pass bounds** — pass 1 runs with the optimistic
  ``edit_frac``-derived ``s_max`` (the paper's E-threshold regime); pairs
  that come back unresolved (``score == -1``) are re-run with the exact
  worst-case bound (the BIMSA "CPU recovery" analogue), so the common case
  stays fast while every pair still terminates with a true score.

The engine also owns the PIM phase accounting (scatter / kernel / gather
bytes and seconds — Fig. 1's *Total vs Kernel* decomposition) that used to
live in ``core.pim``.  ``WFAligner`` and ``PIMBatchAligner`` are thin
wrappers kept for compatibility.

Execution itself lives in ``core.session``: every ``align()`` call is one
blocking pass through an :class:`~repro.core.session.AlignmentSession`, and
``engine.stream()`` opens the same session in pipelined mode — async
``submit()``, host packing overlapped with in-flight device kernels, and
out-of-order ``as_completed()`` gather (the paper's transfer/compute
overlap, the 4.87x-vs-37.4x gap).

Every entry point takes an **output mode** — ``output="score"`` (the
default; throughput path) or ``output="cigar"`` (full alignments).  CIGAR
mode compiles each backend's *trace variant* (``core.backends``): ``ref``
keeps the full offset history, while ``ring``/``kernel``/``shardmap``
record the ~16x smaller packed 2-bit backtrace, so every backend emits
exact CIGARs — including pairs that overflow the optimistic bound and
re-run through the exact-bound recovery pass.

Quickstart::

    from repro.core.engine import AlignmentEngine

    eng = AlignmentEngine(backend="ring", edit_frac=0.04)
    res = eng.align(["ACGT...", ...], ["ACGA...", ...])
    res.scores        # [B] exact gap-affine costs (Gotoh-identical)
    res.stats         # buckets, cache hits, overflow recoveries, PIM phases

    full = eng.align(patterns, texts, output="cigar")
    full.cigar_strings()             # SAM 1.4 "="/"X" run-length CIGARs
    full.cigar_strings("classic")    # pre-1.4 "M" CIGARs

    from repro.core.scoring import Edit, AdaptiveBand
    eng.align(patterns, texts, penalties=Edit())        # Levenshtein mode
    eng.align(patterns, texts, heuristic=AdaptiveBand())  # WFA-adaptive
                                     # pruning; result.approximate == True

    with eng.stream(max_inflight_waves=2) as sess:   # pipelined serving
        tickets = [sess.submit(ps, ts, output="cigar") for ps, ts in chunks]
        for ticket in sess.as_completed():           # out-of-order gather
            consume(ticket.result().cigars)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cigar as cigar_mod
from repro.core import scoring
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core import wavefront as wf
from repro.core.backends import BackendSpec, get_backend, _accepts_kw
from repro.core.penalties import DEFAULT

Seq = Union[str, bytes, np.ndarray]


# ---------------------------------------------------------------------------
# Encoding / packing (canonical home; ``core.aligner`` re-exports).


def encode(seq: Seq) -> np.ndarray:
    if isinstance(seq, str):
        return np.frombuffer(seq.encode("ascii"), dtype=np.uint8).astype(np.int32)
    if isinstance(seq, bytes):
        return np.frombuffer(seq, dtype=np.uint8).astype(np.int32)
    return np.asarray(seq, dtype=np.int32)


def pack_batch(seqs: Sequence[Seq], pad_to: Optional[int] = None,
               multiple: int = 1):
    """-> (codes [B, L] int32, lens [B] int32). Padding value 0 (never read)."""
    enc = [encode(s) for s in seqs]
    lens = np.asarray([len(e) for e in enc], np.int32)
    L = max(1, pad_to if pad_to is not None else int(lens.max(initial=1)))
    L = ((L + multiple - 1) // multiple) * multiple
    out = np.zeros((len(enc), L), np.int32)
    for i, e in enumerate(enc):
        out[i, : len(e)] = e
    return out, lens


def problem_bounds(pen, plens: np.ndarray, tlens: np.ndarray,
                   edit_frac: Optional[float], s_max: Optional[int] = None,
                   k_max: Optional[int] = None) -> Tuple[int, int]:
    """Static (s_max, k_max) for a batch (``pen``: model or legacy triple).

    With ``edit_frac`` (the paper's E): the model's score bound over the
    batch max length.  Without it: the exact worst case (all-mismatch
    diagonal + one gap), which guarantees every pair terminates with a
    real score.
    """
    pen = scoring.as_model(pen)
    max_len = int(max(plens.max(initial=1), tlens.max(initial=1)))
    max_diff = int(np.abs(tlens - plens).max(initial=0))
    if s_max is None:
        if edit_frac is not None:
            s_max = pen.score_bound(max_len, edit_frac, len_diff=max_diff)
        else:
            s_max = _exact_worst_score(pen, plens, tlens)
    if k_max is None:
        k_max = min(pen.band_bound(s_max), max_len)
    k_max = max(k_max, max_diff, 1)
    return int(s_max), int(k_max)


def _exact_worst_score(pen, plens, tlens) -> int:
    """Batch-vectorized :meth:`scoring.PenaltyModel.worst_score`, maxed
    over the batch — the bound under which every pair terminates."""
    worst = (pen.x * np.minimum(plens, tlens)
             + np.where(plens != tlens,
                        pen.o + pen.e * np.abs(tlens - plens), 0))
    return int(worst.max(initial=0)) + 1


def pair_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    """Pair axis over ALL mesh axes — every chip is a 'DPU'."""
    if mesh is None:
        return None
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def _next_pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _quantize_rows(n: int, multiple: int) -> int:
    """Smallest 'round' pair count >= n — a power of two or 1.5x one
    (bounds padding waste at 25% while keeping the set of distinct batch
    shapes, and so the executable cache, small) — then rounded up to
    ``multiple`` (the worker count)."""
    p = _next_pow2(n)
    if p > 1 and 3 * p // 4 >= n:
        p = 3 * p // 4
    return _round_up(p, multiple)


def _fit_width(arr: np.ndarray, width: int) -> np.ndarray:
    """Pad or trim the column axis to ``width`` (padding never read)."""
    if arr.shape[1] == width:
        return arr
    if arr.shape[1] > width:
        return arr[:, :width]
    out = np.zeros((arr.shape[0], width), arr.dtype)
    out[:, : arr.shape[1]] = arr
    return out


def _pad_rows(arr: np.ndarray, to: int) -> np.ndarray:
    if arr.shape[0] == to:
        return arr
    pad = np.zeros((to - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)


# ---------------------------------------------------------------------------
# Stats / results.


@dataclasses.dataclass
class PIMStats:
    """Phase accounting of the paper's host<->device pipeline (Fig. 1)."""
    n_pairs: int
    n_workers: int
    bytes_in: int
    bytes_out: int
    t_scatter: float
    t_kernel: float
    t_gather: float

    @property
    def t_total(self) -> float:
        return self.t_scatter + self.t_kernel + self.t_gather

    def throughput_total(self) -> float:
        return self.n_pairs / max(self.t_total, 1e-12)

    def throughput_kernel(self) -> float:
        return self.n_pairs / max(self.t_kernel, 1e-12)


@dataclasses.dataclass(frozen=True)
class BucketInfo:
    """One executed problem shape: quantized length + static WFA bounds."""
    lmax: int
    s_max: int
    k_max: int
    n_pairs: int
    recovery: bool = False     # True for adaptive second-pass buckets


@dataclasses.dataclass
class EngineStats:
    """Telemetry for one ``align`` call."""
    n_pairs: int = 0
    n_workers: int = 1
    buckets: List[BucketInfo] = dataclasses.field(default_factory=list)
    n_overflow: int = 0        # pairs unresolved after pass 1
    n_recovered: int = 0       # of those, resolved by the exact-bound pass
    cache_hits: int = 0
    cache_misses: int = 0
    n_traces: int = 0          # fresh XLA traces triggered by this call
    rows_real: int = 0         # submitted rows actually dispatched in waves
    rows_padded: int = 0       # device rows incl. quantization padding
    bytes_in: int = 0
    bytes_out: int = 0
    t_scatter: float = 0.0
    t_kernel: float = 0.0
    t_gather: float = 0.0
    # BiWFA (trace_variant="bidir") telemetry
    n_meet_unmet: int = 0      # meet rows whose fronts never joined
    n_bidir_fallback: int = 0  # segments re-run via packed traceback
    peak_trace_bytes: int = 0  # largest trace buffer gathered for one wave
                               # (the resident trace-memory high-water mark)

    def merge(self, other: "EngineStats", *,
              count_pairs: bool = True) -> "EngineStats":
        """Fold ``other``'s telemetry into this one, in place -> self.

        Additive fields sum, ``buckets`` extend, high-water marks max.
        ``count_pairs=False`` skips ``n_pairs`` — for aggregating child
        tickets (BiWFA sub-problems, mapper extension rounds) whose rows
        re-process pairs the parent already counted.
        """
        if count_pairs:
            self.n_pairs += other.n_pairs
        self.n_workers = max(self.n_workers, other.n_workers)
        self.buckets.extend(other.buckets)
        for f in ("n_overflow", "n_recovered", "cache_hits", "cache_misses",
                  "n_traces", "rows_real", "rows_padded", "bytes_in",
                  "bytes_out", "t_scatter", "t_kernel", "t_gather",
                  "n_meet_unmet", "n_bidir_fallback"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.peak_trace_bytes = max(self.peak_trace_bytes,
                                    other.peak_trace_bytes)
        return self

    @property
    def n_buckets(self) -> int:
        return len([b for b in self.buckets if not b.recovery])

    @property
    def wave_occupancy(self) -> float:
        """Real rows / device rows across every dispatched wave (1.0 when
        nothing has been dispatched): how much of the padded rectangles the
        executable cache's quantized shapes actually carried."""
        return (self.rows_real / self.rows_padded if self.rows_padded
                else 1.0)

    @property
    def padding_waste_frac(self) -> float:
        """Fraction of dispatched device rows that were quantization
        padding — the batching-efficiency complement of
        :attr:`wave_occupancy`."""
        return 1.0 - self.wave_occupancy

    @property
    def pim(self) -> PIMStats:
        return PIMStats(n_pairs=self.n_pairs, n_workers=self.n_workers,
                        bytes_in=self.bytes_in, bytes_out=self.bytes_out,
                        t_scatter=self.t_scatter, t_kernel=self.t_kernel,
                        t_gather=self.t_gather)


@dataclasses.dataclass
class EngineResult:
    scores: np.ndarray                      # [B] int32; -1 = exceeded s_max
    cigars: Optional[List[np.ndarray]]      # per-pair op arrays, or None
    n_steps: int                            # score-loop trips (telemetry)
    s_max: int                              # largest bound used
    k_max: int
    stats: EngineStats = dataclasses.field(default_factory=EngineStats)
    # True when a non-exact wavefront heuristic produced these results:
    # scores are an upper bound on the optimal cost and divergent pairs may
    # stay unresolved (-1).
    approximate: bool = False

    def cigar_strings(self, mode: str = "extended") -> List[str]:
        """Run-length CIGAR strings (``mode``: SAM 1.4 'extended' ``=``/``X``
        or 'classic' ``M``)."""
        if self.cigars is None:
            raise ValueError("no CIGARs: align with output='cigar'")
        return [cigar_mod.cigar_string(c, mode) for c in self.cigars]

    def cigar_identities(self) -> np.ndarray:
        """[B] float fraction of matching alignment columns per pair.

        Unresolved pairs (``score == -1``: no alignment was produced) are
        NaN, not 1.0 — an empty op array only means "identical" when the
        pair actually resolved (both sequences empty).
        """
        if self.cigars is None:
            raise ValueError("no CIGARs: align with output='cigar'")
        return np.asarray([
            cigar_mod.cigar_identity(c) if s >= 0 else np.nan
            for s, c in zip(self.scores, self.cigars)])


class _Executable:
    """One compiled backend entry point for a fixed problem shape.

    Tracing happens at most once per (shape, bounds) key; ``n_traces``
    counts actual XLA traces so callers can assert cache effectiveness.
    ``call`` is the dispatch point shared by the sync path and the
    streaming session: it honors the backend's ``dispatch`` hook and is
    *non-blocking* — the returned ``WFAResult`` holds in-flight device
    arrays (JAX async dispatch), so callers choose when to synchronize.
    """

    def __init__(self, spec: BackendSpec, pen, s_max: int,
                 k_max: int, mesh: Optional[Mesh], output: str = "score",
                 heur=None, states: Tuple[str, str] = ("M", "M"),
                 opts: Tuple[Tuple[str, object], ...] = ()):
        self.s_max = s_max
        self.k_max = k_max
        self._traces = [0]
        traces = self._traces
        pen = scoring.as_model(pen)
        heur = scoring.as_heuristic(heur)
        states = tuple(states)
        if output == "bidir_meet":
            # the meet-in-the-middle breakpoint solver: backends may ship a
            # fused meet variant (the kernel runs both fronts' rings in
            # VMEM with per-block early exit); otherwise the shared jnp
            # solver serves every backend
            backend_fn = spec.meet_variant or wf.wfa_bidir_meet
            self._dispatch = None
            extra = {}
        else:
            backend_fn = spec.variant(output, pen.kind)
            self._dispatch = spec.dispatch
            extra = {"mesh": mesh} if spec.needs_mesh else {}
        # Backend tuning opts: ``band_cap="auto"`` resolves through the
        # heuristic's own cap for this problem's band width (exact
        # alignment has no pruning radius, so "auto" stays full-width).
        # Each opt is then threaded only into callables whose signature
        # takes it — the stateful-children ring substitution and the meet
        # path keep working with kernel-only knobs configured.
        opts = dict(opts)
        if opts.get("band_cap") == "auto":
            opts["band_cap"] = (None if heur.exact
                                else heur.band_cap(2 * k_max + 1))
        for kw, val in opts.items():
            if val is not None and _accepts_kw(backend_fn, kw):
                extra[kw] = val
        # Only pass heur when pruning is actually requested, so
        # heuristic-unaware plug-in backends keep serving exact alignment.
        if not heur.exact:
            if output != "bidir_meet" and not spec.accepts_heuristic(output):
                raise ValueError(
                    f"backend {spec.name!r} does not accept wavefront "
                    f"heuristics (no 'heur' keyword on its "
                    f"{output}-variant); use heuristic=None or a "
                    f"heuristic-aware backend")
            extra["heur"] = heur
        if states != ("M", "M"):
            # boundary-constrained sub-alignment (BiWFA recursion child);
            # the engine substitutes a state-capable trace path upstream
            extra["begin_state"], extra["end_state"] = states

        def _run(*arrays):
            traces[0] += 1            # trace-time side effect only
            return backend_fn(*arrays, pen=pen,
                              s_max=s_max, k_max=k_max, **extra)

        # Donation is a no-op (with a warning) on CPU; only apply it where
        # XLA can actually alias the buffers.
        donate = (spec.donate_args
                  if output != "bidir_meet"
                  and jax.default_backend() in ("gpu", "tpu") else ())
        self.fn = jax.jit(_run, donate_argnums=donate)

    def call(self, *arrays):
        if self._dispatch is not None:
            return self._dispatch(self.fn, *arrays)
        return self.fn(*arrays)

    @property
    def n_traces(self) -> int:
        return self._traces[0]


class AlignmentEngine:
    """Bucketed, cached, overflow-recovering batch aligner.

    Parameters
    ----------
    pen : default penalty model — any :class:`~repro.core.scoring.
        PenaltyModel` (``Edit`` / ``GapLinear`` / ``GapAffine``) or a
        legacy gap-affine :class:`Penalties` triple (normalized to
        ``GapAffine``).  Every ``align``/``submit`` can override per call
        via ``penalties=``; linear models run the cheaper one-matrix
        recurrence end to end.
    backend : registry name (``available_backends()``); plug-ins welcome.
    edit_frac : the paper's E — optimistic score budget for pass 1.  ``None``
        sizes buffers for the exact worst case up front (single pass).
    s_max / k_max : explicit static bounds; setting ``s_max`` pins the score
        cap (no adaptive recovery — unresolved pairs stay ``-1``).
    output : default output mode for calls that don't name one —
        ``"score"`` (throughput) or ``"cigar"`` (full alignments via the
        backend's trace variant).  Every ``align``/``submit`` can override
        per call.
    heuristic : default :class:`~repro.core.scoring.WavefrontHeuristic`
        (``None`` = exact).  ``AdaptiveBand``/``ZDrop`` prune wavefront
        lanes per score step; results are flagged ``approximate=True``.
        Per-call ``heuristic=`` overrides.
    with_cigar : deprecated spelling of ``output="cigar"`` (kept for
        compatibility; per-call ``output=`` is the API).
    mesh : device mesh for scatter/gather (and for ``needs_mesh`` backends).
    chunk_pairs : max pairs per device wave (the MRAM-capacity analogue).
    bucket_by_length : sort pairs into power-of-two length buckets.
    min_bucket_len : floor for bucket lengths (avoids tiny-shape churn).
    adaptive : enable the exact-bound recovery pass for overflow pairs.
    backend_opts : backend tuning knobs, threaded by keyword into each of
        the backend's callables that takes them.  Built-ins:
        ``band_cap`` (compacting-band ring width on ring/kernel/shardmap;
        ``"auto"`` derives it from the active heuristic's pruning radius
        via ``heur.band_cap`` — exact alignment stays full-width), plus
        ``block_pairs`` / ``gather`` / ``ext_stride`` on the kernel
        backend.  Unknown keys raise ``ValueError`` here, not at align
        time.
    """

    def __init__(self, pen=DEFAULT, *, backend: str = "ring",
                 edit_frac: Optional[float] = None,
                 s_max: Optional[int] = None, k_max: Optional[int] = None,
                 output: str = "score", heuristic=None,
                 with_cigar: bool = False,
                 mesh: Optional[Mesh] = None,
                 chunk_pairs: int = 1 << 16, bucket_by_length: bool = True,
                 min_bucket_len: int = 16, adaptive: bool = True,
                 trace_variant: str = "packed",
                 max_wave_cells: int = 1 << 24,
                 trace_budget: Optional[int] = None,
                 backend_opts: Optional[Dict[str, object]] = None):
        spec = get_backend(backend)
        self.backend_opts = dict(backend_opts or {})
        for kw in sorted(self.backend_opts):
            if not any(_accepts_kw(f, kw) for f in spec.callables()):
                raise ValueError(
                    f"backend {backend!r} accepts no backend_opts key "
                    f"{kw!r} on any of its callables")
        if with_cigar:
            output = "cigar"
        if output not in ("score", "cigar"):
            raise ValueError(f"unknown output mode {output!r}; "
                             "use 'score' or 'cigar'")
        if trace_variant not in ("packed", "bidir"):
            raise ValueError(f"unknown trace variant {trace_variant!r}; "
                             "use 'packed' or 'bidir'")
        if output == "cigar" and not spec.supports_cigar:
            raise ValueError(
                f"CIGAR output needs a backend with a trace variant; "
                f"{backend!r} is score-only")
        if spec.needs_mesh and mesh is None:
            raise ValueError(f"backend {backend!r} needs a device mesh")
        self.pen = scoring.as_model(pen)
        spec.variant("score", self.pen.kind)   # raises if model unsupported
        self.heuristic = scoring.as_heuristic(heuristic)
        self.backend = backend
        self.edit_frac = edit_frac
        self._s_max = s_max
        self._k_max = k_max
        self.default_output = output
        self.mesh = mesh
        self.chunk_pairs = int(chunk_pairs)
        self.bucket_by_length = bucket_by_length
        self.min_bucket_len = int(min_bucket_len)
        self.adaptive = adaptive
        self.trace_variant = trace_variant
        # long-read bucket ladder: cap rows-per-wave so wide buckets (100 kb
        # pairs) dispatch narrow waves instead of OOMing at chunk_pairs rows
        self.max_wave_cells = int(max_wave_cells)
        # bidir recursion base case: packed traceback allowed when a
        # sub-problem's s*(plen+tlen) fits this many cells (None = default)
        self.trace_budget = trace_budget
        self.n_workers = (int(np.prod(list(mesh.shape.values())))
                          if mesh is not None else jax.device_count())
        self._cache: Dict[tuple, _Executable] = {}

    @property
    def with_cigar(self) -> bool:
        """Deprecated: whether the *default* output mode emits CIGARs."""
        return self.default_output == "cigar"

    def resolve_output(self, output: Optional[str], pen=None) -> str:
        """Validate a per-call output mode (None -> the engine default).

        ``pen`` is the call's resolved penalty model (None -> the engine
        default): the cigar check must name the model kind actually in
        play, or a linear-only backend would be rejected for 'affine'.
        """
        out = self.default_output if output is None else output
        if out not in ("score", "cigar"):
            raise ValueError(f"unknown output mode {output!r}; "
                             "use 'score' or 'cigar'")
        if out == "cigar":
            kind = (self.pen if pen is None else pen).kind
            get_backend(self.backend).variant("cigar", kind)
        return out

    def resolve_trace_variant(self, trace_variant: Optional[str],
                              output: str = "score") -> str:
        """Validate a per-call trace variant (None -> the engine default).

        ``"bidir"`` selects the meet-in-the-middle BiWFA traceback
        (``repro.biwfa``) for CIGAR submissions: O(s) trace memory instead
        of the packed O(s^2) backtrace.  It only changes how CIGARs are
        produced, so score-only submissions normalize to ``"packed"``.
        """
        tv = self.trace_variant if trace_variant is None else trace_variant
        if tv not in ("packed", "bidir"):
            raise ValueError(f"unknown trace variant {trace_variant!r}; "
                             "use 'packed' or 'bidir'")
        return tv if output == "cigar" else "packed"

    def resolve_penalties(self, pen) -> "scoring.PenaltyModel":
        """Validate a per-call penalty model (None -> the engine default)."""
        model = self.pen if pen is None else scoring.as_model(pen)
        get_backend(self.backend).variant("score", model.kind)
        return model

    def resolve_heuristic(self, heur,
                          output: str = "score") -> "scoring.WavefrontHeuristic":
        """Validate a per-call heuristic (None -> the engine default).

        The backend-capability check happens here — before any ticket is
        created — so a rejected submit leaves the session clean instead of
        registering a ticket whose waves can never dispatch.
        """
        heur = self.heuristic if heur is None else scoring.as_heuristic(heur)
        if not heur.exact:
            spec = get_backend(self.backend)
            if not spec.accepts_heuristic(output):
                raise ValueError(
                    f"backend {self.backend!r} does not accept wavefront "
                    f"heuristics (no 'heur' keyword on its "
                    f"{output}-variant); use heuristic=None or a "
                    f"heuristic-aware backend")
        return heur

    # -- cache introspection -------------------------------------------------

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def cache_traces(self) -> int:
        """Total XLA traces across all cached executables."""
        return sum(e.n_traces for e in self._cache.values())

    # -- bounds --------------------------------------------------------------

    def _bounds_for_bucket(self, lmax: int, plen_b: np.ndarray,
                           tlen_b: np.ndarray, exact: bool,
                           pen=None, s_cap: Optional[int] = None
                           ) -> Tuple[int, int]:
        """Static (s_max, k_max) for one bucket.

        Pass-1 bounds depend only on (pen, lmax, edit_frac) — never on the
        data — so identical buckets across calls share one executable.  The
        exact path quantizes s_max up to a multiple of 32 for the same
        reason (the score loop exits early regardless).  ``pen`` is the
        per-call penalty model (None -> the engine default): cheaper models
        imply tighter E-derived score bounds (edit distance: ``s_max``
        close to the edit budget itself), so the score loop cap shrinks
        with the model.

        ``s_cap`` is a per-submit score ceiling: the BiWFA recursion
        dispatches sub-problems whose cost is already known, so their waves
        run far below the bucket's worst case (callers quantize the cap for
        cache reuse).
        """
        pen = self.pen if pen is None else pen
        max_diff = int(np.abs(tlen_b - plen_b).max(initial=0))
        if self._s_max is not None:
            s = int(self._s_max)
        elif not exact and self.edit_frac is not None:
            # regime bound: at most ceil(E*L) edits, so the length diff is
            # at most that many bases too — fully data-independent (no
            # max_diff bump: the band provably covers any within-budget
            # pair, and over-budget pairs go to the recovery pass anyway)
            n_err = int(math.ceil(self.edit_frac * lmax))
            s = int(pen.score_bound(lmax, self.edit_frac, len_diff=n_err))
            max_diff = 0
        else:
            s = _round_up(_exact_worst_score(pen, plen_b, tlen_b), 32)
        if s_cap is not None:
            s = max(min(s, int(s_cap)), 1)
        k = self._k_max if self._k_max is not None else \
            min(pen.band_bound(s), lmax)
        return int(s), max(int(k), max_diff, 1)

    # -- bucket planning -----------------------------------------------------

    def _plan_buckets(self, plen: np.ndarray, tlen: np.ndarray,
                      idx: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """-> [(bucket_len, original-row indices)] sorted by length."""
        lmax = np.maximum(plen[idx], tlen[idx])
        if not self.bucket_by_length:
            width = _next_pow2(max(int(lmax.max(initial=1)),
                                   self.min_bucket_len))
            return [(width, idx)]
        widths = np.maximum(lmax, self.min_bucket_len)
        widths = 2 ** np.ceil(np.log2(np.maximum(widths, 1))).astype(np.int64)
        out = []
        for w in np.unique(widths):
            out.append((int(w), idx[widths == w]))
        return out

    # -- execution -----------------------------------------------------------

    def _device_put(self, *arrays):
        sh = pair_sharding(self.mesh)
        if sh is not None:
            return tuple(jax.device_put(a, sh) for a in arrays)
        return tuple(jnp.asarray(a) for a in arrays)

    def _executable_for(self, pshape: tuple, tshape: tuple, s_max: int,
                        k_max: int, output: str = "score",
                        pen=None, heur=None,
                        states: Tuple[str, str] = ("M", "M")
                        ) -> Tuple["_Executable", bool]:
        """Cached executable for one rectangular problem shape -> (exe, hit)."""
        spec = get_backend(self.backend)
        states = tuple(states)
        if output == "cigar" and states != ("M", "M") \
                and not spec.accepts_states():
            # boundary-constrained children (BiWFA recursion) need a
            # state-aware trace path; fall back to the ring solver for
            # backends whose trace variant can't seed mid-gap fronts
            spec = get_backend("ring")
        pen = self.pen if pen is None else pen
        heur = self.heuristic if heur is None else heur
        # the whole spec in the key: re-registering a backend name (new fn,
        # donation or dispatch hooks) must not serve stale executables.
        # output mode, penalty model, heuristic, boundary states and
        # backend opts too: each compiles a different recurrence /
        # pruning / seeding / blocking step.
        opts = tuple(sorted(self.backend_opts.items()))
        key = (spec, pen, heur, pshape, tshape, s_max, k_max, output, states,
               opts)
        exe = self._cache.get(key)
        if exe is not None:
            obs_metrics.counter("engine_cache_hits_total",
                                "executable cache hits").inc()
            return exe, True
        obs_metrics.counter("engine_cache_misses_total",
                            "executable cache misses (fresh XLA trace "
                            "on first call)").inc()
        if obs_trace.enabled():
            obs_trace.instant("engine.retrace", args={
                "backend": spec.name, "shape": list(pshape),
                "s_max": s_max, "k_max": k_max, "output": output})
        exe = _Executable(spec, pen, s_max, k_max, self.mesh, output, heur,
                          states, opts)
        self._cache[key] = exe
        return exe, False

    # -- public entry points -------------------------------------------------

    def stream(self, *, max_inflight_waves: int = 2,
               wave_pairs: Optional[int] = None):
        """Open a pipelined :class:`~repro.core.session.AlignmentSession`.

        The session is the canonical submission path: ``submit()`` returns a
        :class:`~repro.core.session.Ticket` immediately, host-side packing of
        the next wave overlaps the in-flight device kernel (JAX async
        dispatch), at most ``max_inflight_waves`` waves are in flight
        (backpressure), and tickets complete out of order via
        ``as_completed()``.  ``wave_pairs`` defaults to the engine's
        ``chunk_pairs`` (the MRAM-capacity analogue).
        """
        from repro.core.session import AlignmentSession
        return AlignmentSession(self, max_inflight_waves=max_inflight_waves,
                                wave_pairs=wave_pairs)

    def align(self, patterns: Sequence[Seq], texts: Sequence[Seq], *,
              output: Optional[str] = None, penalties=None,
              heuristic=None, trace_variant: Optional[str] = None
              ) -> EngineResult:
        """Align python sequences (str/bytes/int arrays), pairwise.

        ``output="cigar"`` additionally emits exact per-pair CIGAR op
        arrays (``EngineResult.cigars``) via the backend's trace variant;
        ``penalties=`` selects a per-call penalty model and ``heuristic=``
        a per-call wavefront heuristic; ``trace_variant="bidir"`` produces
        the CIGARs through the O(s)-memory BiWFA recursion instead of the
        packed backtrace; ``None`` uses the engine defaults.
        """
        assert len(patterns) == len(texts)
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        return self.align_packed(p, plen, t, tlen, output=output,
                                 penalties=penalties, heuristic=heuristic,
                                 trace_variant=trace_variant)

    def align_packed(self, p: np.ndarray, plen: np.ndarray, t: np.ndarray,
                     tlen: np.ndarray, *, output: Optional[str] = None,
                     penalties=None, heuristic=None,
                     trace_variant: Optional[str] = None) -> EngineResult:
        """Align pre-packed rectangular batches ([B, L] codes + [B] lens).

        Thin blocking wrapper over one streaming session: a single
        ``submit`` followed by ``drain``, with per-phase (scatter / kernel /
        gather) blocking so the Fig. 1 decomposition stays measurable.
        """
        from repro.core.session import AlignmentSession
        sess = AlignmentSession(self, max_inflight_waves=1,
                                _sync_timing=True)
        ticket = sess.submit_packed(p, plen, t, tlen, output=output,
                                    penalties=penalties,
                                    heuristic=heuristic,
                                    trace_variant=trace_variant)
        sess.drain()
        return ticket.result()

    def align_pair(self, pattern: Seq, text: Seq, *,
                   output: Optional[str] = None, penalties=None,
                   heuristic=None, trace_variant: Optional[str] = None
                   ) -> EngineResult:
        return self.align([pattern], [text], output=output,
                          penalties=penalties, heuristic=heuristic,
                          trace_variant=trace_variant)
