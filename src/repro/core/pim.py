"""PIM-style distributed batch executor — compatibility shim.

.. deprecated::
    The scatter -> align -> gather pipeline, wave chunking, and Fig. 1 phase
    accounting now live in :class:`repro.core.engine.AlignmentEngine`, which
    adds length-bucketed batching, executable caching and adaptive overflow
    recovery on the same path.  ``PIMBatchAligner`` wraps an engine and
    returns the familiar ``(scores, PIMStats)`` tuple.

Paper mapping (unchanged): one CPU thread scatters read pairs across the
device mesh with the pair axis spread over **every** mesh axis (pure data
parallelism — the "no inter-DPU communication" property becomes "the lowered
HLO contains no collectives", which the dry-run asserts); devices align
independently; the host gathers results.  *Total* vs *Kernel* throughput is
reported exactly like Fig. 1.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence

from jax.sharding import Mesh

# Canonical homes moved to core.engine; re-exported for compatibility.
from repro.core.aligner import WFAligner, pack_batch
from repro.core.engine import AlignmentEngine, PIMStats, pair_sharding  # noqa: F401

__all__ = ["PIMBatchAligner", "PIMStats", "pair_sharding"]


class PIMBatchAligner:
    """Scatter -> align -> gather over a device mesh (session-backed).

    ``chunk_pairs`` bounds device memory per wave (the MRAM-capacity
    analogue: a DPU holds only so many pairs at once); large batches stream
    in waves.  ``run_arrays`` is one blocking pass through an
    :class:`~repro.core.session.AlignmentSession`.
    """

    def __init__(self, aligner: WFAligner, mesh: Optional[Mesh] = None,
                 chunk_pairs: int = 1 << 16, penalties=None):
        warnings.warn(
            "PIMBatchAligner is deprecated; use repro.core.engine."
            "AlignmentEngine (blocking align()) or AlignmentEngine.stream() "
            "/ repro.core.session.AlignmentSession (pipelined submission)",
            DeprecationWarning, stacklevel=2)
        self.aligner = aligner
        self.mesh = mesh
        self.chunk_pairs = chunk_pairs
        pen = aligner.pen
        if penalties is not None:
            # Engine-era spelling forwarded for convenience: accept it with
            # a warning instead of raising on an unknown kwarg.
            warnings.warn(
                "PIMBatchAligner(penalties=...) is the AlignmentEngine "
                "spelling; forwarding it as this executor's penalty model "
                "(gap-affine triples map to scoring.GapAffine)",
                DeprecationWarning, stacklevel=2)
            pen = penalties
        if mesh is None and penalties is None:
            # reuse the aligner's engine (and its warm executable cache);
            # this executor's per-wave cap applies via the session
            self._engine = aligner.engine
        else:
            self._engine = AlignmentEngine(
                pen, backend=aligner.backend,
                edit_frac=aligner.edit_frac, s_max=aligner._s_max,
                k_max=aligner._k_max, mesh=mesh, chunk_pairs=chunk_pairs)
        self.n_workers = self._engine.n_workers

    @property
    def engine(self) -> AlignmentEngine:
        return self._engine

    def run(self, patterns: Sequence, texts: Sequence):
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        return self.run_arrays(p, plen, t, tlen)

    def run_arrays(self, p, plen, t, tlen):
        from repro.core.session import AlignmentSession
        sess = AlignmentSession(self._engine, max_inflight_waves=1,
                                wave_pairs=int(self.chunk_pairs),
                                _sync_timing=True)
        ticket = sess.submit_packed(p, plen, t, tlen)
        sess.drain()
        res = ticket.result()
        return res.scores, res.stats.pim
