"""PIM-style distributed batch executor — the paper's host<->device pipeline.

Paper (UPMEM): one CPU thread scatters 5M read pairs across 2560 DPU MRAMs
with parallel transfers; DPUs align independently (no inter-DPU comm); the
CPU gathers results back.  Fig. 1 reports both *Total* (with transfers) and
*Kernel* (alignment only).

TPU mapping: the pair batch is device_put with a NamedSharding that spreads
the pair axis across **every** mesh axis (pure data parallelism — the "no
inter-DPU communication" property becomes "the lowered HLO contains no
collectives", which the dry-run asserts).  The executor times and accounts
the three phases exactly like the paper: scatter bytes in, kernel, gather
bytes out.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.aligner import WFAligner, pack_batch, problem_bounds


@dataclasses.dataclass
class PIMStats:
    n_pairs: int
    n_workers: int
    bytes_in: int
    bytes_out: int
    t_scatter: float
    t_kernel: float
    t_gather: float

    @property
    def t_total(self) -> float:
        return self.t_scatter + self.t_kernel + self.t_gather

    def throughput_total(self) -> float:
        return self.n_pairs / max(self.t_total, 1e-12)

    def throughput_kernel(self) -> float:
        return self.n_pairs / max(self.t_kernel, 1e-12)


def pair_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    """Pair axis over ALL mesh axes — every chip is a 'DPU'."""
    if mesh is None:
        return None
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def _pad_pairs(arr: np.ndarray, to: int) -> np.ndarray:
    if arr.shape[0] == to:
        return arr
    pad = np.zeros((to - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)


class PIMBatchAligner:
    """Scatter -> align -> gather over a device mesh.

    ``chunk_pairs`` bounds device memory per wave (the MRAM-capacity analogue:
    a DPU holds only so many pairs at once); large batches stream in waves.
    """

    def __init__(self, aligner: WFAligner, mesh: Optional[Mesh] = None,
                 chunk_pairs: int = 1 << 16):
        self.aligner = aligner
        self.mesh = mesh
        self.chunk_pairs = chunk_pairs
        self.n_workers = (int(np.prod(list(mesh.shape.values())))
                          if mesh is not None else jax.device_count())

    def _align_shard(self, p, t, plen, tlen, s_max, k_max):
        sh = pair_sharding(self.mesh)
        if sh is not None:
            p, t, plen, tlen = (jax.device_put(x, sh)
                                for x in (p, t, plen, tlen))
        else:
            p, t, plen, tlen = map(jnp.asarray, (p, t, plen, tlen))
        return (p, t, plen, tlen)

    def run(self, patterns: Sequence, texts: Sequence):
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        return self.run_arrays(p, plen, t, tlen)

    def run_arrays(self, p: np.ndarray, plen: np.ndarray, t: np.ndarray,
                   tlen: np.ndarray) -> tuple[np.ndarray, PIMStats]:
        n = p.shape[0]
        s_max, k_max = problem_bounds(self.aligner.pen, plen, tlen,
                                      self.aligner.edit_frac,
                                      self.aligner._s_max,
                                      self.aligner._k_max)
        mult = self.n_workers
        scores = np.empty((n,), np.int32)
        bytes_in = bytes_out = 0
        t_scatter = t_kernel = t_gather = 0.0

        for lo in range(0, n, self.chunk_pairs):
            hi = min(n, lo + self.chunk_pairs)
            nb = ((hi - lo + mult - 1) // mult) * mult
            pc = _pad_pairs(p[lo:hi], nb)
            tc = _pad_pairs(t[lo:hi], nb)
            plc = _pad_pairs(plen[lo:hi], nb)
            tlc = _pad_pairs(tlen[lo:hi], nb)
            # ensure padded pairs terminate instantly (empty vs empty)
            bytes_in += pc.nbytes + tc.nbytes + plc.nbytes + tlc.nbytes

            t0 = time.perf_counter()
            dp, dt_, dpl, dtl = self._align_shard(pc, tc, plc, tlc, s_max, k_max)
            jax.block_until_ready((dp, dt_, dpl, dtl))
            t1 = time.perf_counter()
            res = self.aligner.align_arrays(dp, dt_, dpl, dtl,
                                            s_max=s_max, k_max=k_max)
            jax.block_until_ready(res.score)
            t2 = time.perf_counter()
            out = np.asarray(res.score)
            t3 = time.perf_counter()

            scores[lo:hi] = out[: hi - lo]
            bytes_out += out.nbytes
            t_scatter += t1 - t0
            t_kernel += t2 - t1
            t_gather += t3 - t2

        stats = PIMStats(n_pairs=n, n_workers=self.n_workers,
                         bytes_in=bytes_in, bytes_out=bytes_out,
                         t_scatter=t_scatter, t_kernel=t_kernel,
                         t_gather=t_gather)
        return scores, stats
