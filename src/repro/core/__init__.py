"""The paper's primary contribution: batched WFA pairwise alignment,
PIM-style (scatter / align-without-communication / gather), adapted to TPU.
"""
from repro.core.penalties import DEFAULT, Penalties, band_bound, problem_dims, score_bound  # noqa: F401
from repro.core.scoring import (AdaptiveBand, Edit, GapAffine, GapLinear,  # noqa: F401
                                NoHeuristic, PenaltyModel, WavefrontHeuristic,
                                ZDrop, as_heuristic, as_model,
                                parse_heuristic, parse_penalties)
from repro.core.wavefront import WFAResult, wfa_forward, wfa_scores, wfa_scores_packed  # noqa: F401
from repro.core.backends import available_backends, cigar_backends, get_backend, register_backend  # noqa: F401
from repro.core.cigar import TracebackError, cigar_identity, cigar_string  # noqa: F401
from repro.core.engine import (AlignmentEngine, EngineResult, EngineStats,  # noqa: F401
                               encode, pack_batch, problem_bounds)
from repro.core.session import AlignmentSession, SessionStats, Ticket  # noqa: F401
from repro.core.aligner import AlignResult, WFAligner  # noqa: F401
from repro.core.pim import PIMBatchAligner, PIMStats, pair_sharding  # noqa: F401
from repro.core.gotoh import gotoh_score, gotoh_score_vec, score_cigar  # noqa: F401
