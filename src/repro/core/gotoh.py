"""Dense gap-affine DP (Needleman-Wunsch-Gotoh), minimizing cost.

This is the independent correctness oracle for the WFA implementation:
WFA is an *exact* algorithm, so its score must equal the Gotoh global
gap-affine cost for every pair — that equality is the paper's own
correctness contract.  Kept in plain numpy (O(n*m)) on purpose: it shares
no code with the wavefront path.

It also plays the role of the "classical CPU DP" in benchmark ablations
(WFA's O(n*s) vs the dense O(n*m) is the reason WFA is the state of the
art that the paper accelerates).
"""
from __future__ import annotations

import numpy as np

from repro.core.penalties import Penalties

BIG = 1 << 28


def gotoh_score(pattern, text, pen: Penalties) -> int:
    """Global gap-affine alignment cost (match=0, mismatch=x, gap o+L*e).

    pattern/text: 1-D integer (or byte) arrays / sequences.
    """
    p = np.asarray(pattern)
    t = np.asarray(text)
    n, m = len(p), len(t)
    # H[i,j]: best cost at cell (= WFA's folded M wavefront); I: gap
    # consuming text (insertion); D: gap consuming pattern (deletion).
    # Gaps open from H (so I-after-D chains are allowed, as in WFA where
    # M_s[k] folds I_s/D_s before feeding the next open).
    H = np.full((n + 1, m + 1), BIG, dtype=np.int64)
    I = np.full((n + 1, m + 1), BIG, dtype=np.int64)
    D = np.full((n + 1, m + 1), BIG, dtype=np.int64)
    H[0, 0] = 0
    for j in range(1, m + 1):
        I[0, j] = pen.o + j * pen.e
        H[0, j] = I[0, j]
    for i in range(1, n + 1):
        D[i, 0] = pen.o + i * pen.e
        H[i, 0] = D[i, 0]
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            sub = pen.x if p[i - 1] != t[j - 1] else 0
            I[i, j] = min(H[i, j - 1] + pen.o + pen.e, I[i, j - 1] + pen.e)
            D[i, j] = min(H[i - 1, j] + pen.o + pen.e, D[i - 1, j] + pen.e)
            H[i, j] = min(H[i - 1, j - 1] + sub, I[i, j], D[i, j])
    return int(H[n, m])


def gotoh_score_vec(pattern, text, pen: Penalties) -> int:
    """Anti-diagonal-free vectorized Gotoh (row-wise numpy; faster oracle).

    Row sweep with I computed by running-min trick along the row:
    I[i,j] = min over j' < j of (M[i,j'] + o + (j-j')e, ...) — expressed as
    a prefix scan so each row is O(m) numpy ops instead of a Python loop.
    """
    p = np.asarray(pattern)
    t = np.asarray(text)
    n, m = len(p), len(t)
    j_idx = np.arange(m + 1, dtype=np.int64)
    H_prev = np.full(m + 1, BIG, np.int64)          # row i-1 of H
    D_prev = np.full(m + 1, BIG, np.int64)
    H_prev[0] = 0
    H_prev[1:] = pen.o + j_idx[1:] * pen.e           # row 0 = all-insertion
    for i in range(1, n + 1):
        sub = np.where(p[i - 1] != t, pen.x, 0).astype(np.int64)    # [m]
        M_row = np.full(m + 1, BIG, np.int64)        # diagonal (sub) component
        M_row[1:] = H_prev[:-1] + sub
        D_row = np.minimum(H_prev + pen.o + pen.e, D_prev + pen.e)
        D_row[0] = pen.o + i * pen.e
        # I_row[j] = min over j' < j of  min(M,D)_row[j'] + o + (j-j')*e
        # (open-from-I is dominated by extension, so H can be replaced by
        # min(M, D) inside the scan) — a prefix-min over g[j'] - j'*e.
        g = np.minimum(M_row, D_row) + pen.o - j_idx * pen.e         # [m+1]
        run = np.minimum.accumulate(g)
        I_row = np.full(m + 1, BIG, np.int64)
        I_row[1:] = run[:-1] + j_idx[1:] * pen.e
        H_row = np.minimum(np.minimum(M_row, I_row), D_row)
        H_row[0] = D_row[0]
        H_prev, D_prev = H_row, D_row
    return int(H_prev[m])


def score_cigar(cigar_ops, pattern, text, pen: Penalties):
    """Validate + cost a CIGAR op sequence (0=M,1=X,2=I,3=D; -1 padding).

    Returns (cost, consumed_pattern, consumed_text, ok) where ok checks the
    claimed match/mismatch ops against the actual characters.
    """
    p = np.asarray(pattern)
    t = np.asarray(text)
    i = j = 0
    cost = 0
    ok = True
    prev = -1
    for op in np.asarray(cigar_ops):
        op = int(op)
        if op < 0:
            continue
        if op == 0:      # match
            ok &= i < len(p) and j < len(t) and p[i] == t[j]
            i, j = i + 1, j + 1
        elif op == 1:    # mismatch
            ok &= i < len(p) and j < len(t) and p[i] != t[j]
            cost += pen.x
            i, j = i + 1, j + 1
        elif op == 2:    # insertion (consumes text)
            cost += pen.e + (pen.o if prev != 2 else 0)
            j += 1
        elif op == 3:    # deletion (consumes pattern)
            cost += pen.e + (pen.o if prev != 3 else 0)
            i += 1
        else:
            ok = False
        prev = op
    return cost, i, j, ok
