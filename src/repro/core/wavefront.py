"""Batched pure-JAX Wavefront Algorithm (WFA, Marco-Sola et al. 2021).

This is the paper's algorithm, expressed so a *batch* of pairs advances in
lock-step (the TPU analogue of the paper's "each DPU thread aligns a pair
independently" — see DESIGN.md §2).  All buffers are statically sized from
``(s_max, k_max)`` bounds (``core.penalties`` / ``core.scoring``).

Conventions
-----------
pattern ``p`` (length ``n``, vertical axis), text ``t`` (length ``m``,
horizontal).  A wavefront cell on diagonal ``k = h - v`` stores the furthest
reaching *offset* ``h`` (text chars consumed) attainable with cost exactly
``s``; ``v = h - k`` is the pattern position.

Every solver takes a ``pen`` that may be a legacy gap-affine
:class:`~repro.core.penalties.Penalties` triple or any
:class:`~repro.core.scoring.PenaltyModel`; the model's ``kind`` statically
selects the recurrence:

* ``"affine"`` (gap cost o + L*e) — the classic three-matrix scheme:

      I_s[k] = max(M_{s-o-e}[k-1], I_{s-e}[k-1]) + 1    (gap consuming text)
      D_s[k] = max(M_{s-o-e}[k+1], D_{s-e}[k+1])        (gap consuming pat)
      M_s[k] = max(M_{s-x}[k] + 1, I_s[k], D_s[k])      (mismatch/close gap)

* ``"linear"`` (gap cost L*e; includes ``Edit`` where x = e = 1) — with no
  open cost the I/D fronts are redundant and the whole recurrence collapses
  to **one matrix** (one ring buffer, one backtrace plane, ~3x less state):

      M_s[k] = max(M_{s-x}[k] + 1, M_{s-e}[k-1] + 1, M_{s-e}[k+1])

Both kinds share the extend step ``M_s[k] += LCP(t[h:], p[v:])`` (free
matches) and terminate at the first ``s`` with ``M_s[m-n] == m``.  Invalid
cells hold ``NEG`` and all candidates are masked against the rectangle
``0 <= h <= m, 0 <= v <= n`` so out-of-board offsets never propagate.

A :class:`~repro.core.scoring.WavefrontHeuristic` (``heur=``) optionally
prunes k-lanes after each score step (WFA-adaptive band / z-drop): pruned
lanes are written back as ``NEG`` so they cost no extension work on any
later step and their provenance chains die.  On the step where a pair
*reaches* its target, that lane cannot be pruned under either built-in
policy (its remaining-distance estimate is 0 / its antidiagonal progress
maximal), so a reached score is always traceable — but mid-run the lane
carrying the eventual optimal path *can* lag and be pruned, which is
precisely how heuristic scores become approximate (an upper bound;
divergent pairs may stay unresolved at ``-1``).

Three modes:

* ``wfa_forward(..., keep_history=True)`` — full ``[s_max+1, B, K]``
  offset history (M/I/D for affine, M only for linear), enabling exact
  traceback (``core.cigar``).
* ``wfa_scores`` — ring buffer of depth ``window`` (the paper's
  WRAM-resident working set), score-only throughput mode.
* ``wfa_scores_packed`` — the ring buffer *plus* a packed backtrace: 2-bit
  per-cell provenance codes (which predecessor produced each
  furthest-reaching offset) packed 16 cells to an int32 word along the
  score axis.  ``core.cigar`` re-derives the exact alignment from the
  codes alone by replaying the provenance chain forward and re-extending
  matches against the sequences, so full CIGARs cost
  ``ceil((s_max+1)/16) * B * K`` int32 words per plane (3 planes for
  affine, 1 for linear) — ~16x less memory than the full history.

Provenance code values (2 bits each, 0 = invalid/never-written):

    affine M cell: 1 = from mismatch (M_{s-x}[k]+1), 2 = folded I_s[k],
                   3 = folded D_s[k]
    affine I cell: 1 = gap open (M_{s-o-e}[k-1]+1), 2 = extend (I_{s-e}[k-1]+1)
    affine D cell: 1 = gap open (M_{s-o-e}[k+1]),   2 = extend (D_{s-e}[k+1])
    linear M cell: 1 = mismatch (M_{s-x}[k]+1), 2 = insertion
                   (M_{s-e}[k-1]+1), 3 = deletion (M_{s-e}[k+1])
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import scoring
from repro.core.scoring import AdaptiveBand, ZDrop

NEG = -(1 << 20)  # invalid-cell sentinel; survives +1 arithmetic harmlessly
_VALID_THRESH = NEG // 2
_BIG = 1 << 20

# Packed-backtrace provenance codes (2 bits per cell; 0 = invalid).
BT_NONE = 0
BT_M_FROM_X, BT_M_FROM_I, BT_M_FROM_D = 1, 2, 3   # M-cell origins
BT_GAP_OPEN, BT_GAP_EXT = 1, 2                     # I/D-cell origins
TRACE_CELLS_PER_WORD = 16                          # 2-bit cells in an int32


def n_trace_words(s_max: int) -> int:
    """int32 words along the packed score axis covering s in [0, s_max]."""
    return (int(s_max) + TRACE_CELLS_PER_WORD) // TRACE_CELLS_PER_WORD


# Boundary states for sub-alignments (BiWFA recursion, ``repro.biwfa``).
# ``begin_state="I"`` means an insertion gap is already open when the
# alignment starts (continuing it pays only ``e`` per base, no open);
# ``end_state="I"`` means the alignment must end inside an insertion run
# (its cost is the I-matrix value: the final run's open IS charged).
# ``"M"`` on either side is the ordinary full-alignment boundary.
STATES = ("M", "I", "D")


def _check_states(model, begin_state: str, end_state: str) -> None:
    if begin_state not in STATES or end_state not in STATES:
        raise ValueError(f"boundary states must be one of {STATES}; got "
                         f"({begin_state!r}, {end_state!r})")
    if model.kind != "affine" and (begin_state != "M" or end_state != "M"):
        raise ValueError(
            "gap-linear/edit models have no I/D states; boundary-state "
            "sub-alignments need a gap-affine penalty model")


def _resolve(pen, heur):
    """Normalize (pen, heur) to (PenaltyModel, WavefrontHeuristic)."""
    return scoring.as_model(pen), scoring.as_heuristic(heur)


class WFAResult(NamedTuple):
    score: jax.Array            # [B] int32 alignment cost, -1 if > s_max
    m_hist: Optional[jax.Array]  # [s_max+1, B, K] or None
    i_hist: Optional[jax.Array]  # None for linear models (no I/D fronts)
    d_hist: Optional[jax.Array]
    n_steps: jax.Array          # [] int32: score loop trips taken (telemetry)
    m_bt: Optional[jax.Array] = None  # [n_trace_words, B, K] packed 2-bit
    i_bt: Optional[jax.Array] = None  # provenance codes, or None (score mode
    d_bt: Optional[jax.Array] = None  # / linear models)


def _shift_from_km1(w):
    """w[..., k] <- w[..., k-1]  (diagonal k reads its left neighbour)."""
    neg = jnp.full(w.shape[:-1] + (1,), NEG, w.dtype)
    return jnp.concatenate([neg, w[..., :-1]], axis=-1)


def _shift_from_kp1(w):
    """w[..., k] <- w[..., k+1]."""
    neg = jnp.full(w.shape[:-1] + (1,), NEG, w.dtype)
    return jnp.concatenate([w[..., 1:], neg], axis=-1)


def _extend(M, pattern, text, plen, tlen, ks):
    """Greedy diagonal extension, all (pair, diagonal) lanes in lock-step.

    One matched character per while-trip across the whole [B, K] front — the
    vectorized counterpart of the DPU's scalar per-diagonal extend loop.
    """
    Lt = text.shape[1]
    Lp = pattern.shape[1]
    ks2 = ks if ks.ndim == 2 else ks[None, :]   # [B, K] under a compact band

    def trip(state):
        M, _ = state
        h = M
        v = M - ks2
        can = ((M > _VALID_THRESH)
               & (h >= 0) & (h < tlen[:, None])
               & (v >= 0) & (v < plen[:, None]))
        tc = jnp.take_along_axis(text, jnp.clip(h, 0, Lt - 1), axis=1)
        pc = jnp.take_along_axis(pattern, jnp.clip(v, 0, Lp - 1), axis=1)
        adv = can & (tc == pc)
        return M + adv.astype(M.dtype), jnp.any(adv)

    def cond(state):
        return state[1]

    M, _ = lax.while_loop(cond, trip, trip((M, jnp.bool_(True))))
    return M


def keep_mask(heur, M, plen, tlen, ks):
    """[B, K] bool: lanes the heuristic keeps live after this score step.

    ``M`` is the post-extend M wavefront; ``plen``/``tlen`` must be
    column-broadcastable (``[B, 1]``) and ``ks`` row-broadcastable
    (``[1, K]`` or ``[B, K]``) against it — the shared implementation for
    the jnp solvers *and* the Pallas kernel (whose inputs are natively
    ``[BP, 1]`` / ``[BP, K]``), so a new heuristic lands here once and
    every backend prunes identically.

    Exact heuristics keep every lane; :class:`AdaptiveBand` prunes lanes
    whose remaining-distance estimate ``max(m - h, n - v)`` exceeds the
    front's best by more than ``max_distance_diff`` (only once more than
    ``min_wf_len`` lanes are live); :class:`ZDrop` prunes lanes whose
    antidiagonal progress ``h + v`` trails the front's best by more than
    ``zdrop``.  On its *reaching* step the target lane estimates 0 /
    progresses furthest and so survives (reached scores stay traceable);
    on earlier steps it can lag and be pruned — that is the
    approximation.
    """
    if heur.exact:
        return None
    valid = M > _VALID_THRESH
    h = M
    v = M - ks
    if isinstance(heur, AdaptiveBand):
        d = jnp.maximum(tlen - h, plen - v)
        d = jnp.where(valid, d, _BIG)
        d_min = jnp.min(d, axis=-1, keepdims=True)
        n_live = jnp.sum(valid.astype(jnp.int32), axis=-1, keepdims=True)
        return valid & ((n_live <= heur.min_wf_len)
                        | (d - d_min <= heur.max_distance_diff))
    if isinstance(heur, ZDrop):
        a = jnp.where(valid, h + v, -_BIG)
        best = jnp.max(a, axis=-1, keepdims=True)
        return valid & (best - a <= heur.zdrop)
    raise TypeError(f"unknown heuristic {heur!r}")


def _pruned(keep, *fronts):
    """Apply a keep mask to each non-None wavefront (None mask = exact)."""
    if keep is None:
        return fronts if len(fronts) > 1 else fronts[0]
    out = tuple(w if w is None else jnp.where(keep, w, NEG) for w in fronts)
    return out if len(out) > 1 else out[0]


def _prune_step(heur, plen, tlen, ks, *fronts):
    """One solver-side pruning step: mask from M (``fronts[0]``), applied
    to every front.  Broadcasts the solvers' [B]/[K] layout into
    :func:`keep_mask`'s 2-D convention."""
    keep = keep_mask(heur, fronts[0], plen[:, None], tlen[:, None],
                     ks[None, :])
    return _pruned(keep, *fronts)


# ---------------------------------------------------------------------------
# Compacting band (WFA-adaptive style).
#
# Under a pruning heuristic only a bounded span of diagonals stays live, so
# instead of masking dead lanes at full width K the solvers can carry the
# wavefronts at a *compact* width Kc and slide the window along the diagonal
# axis: each ring row stores, besides the Kc offsets, the absolute K-index of
# its lane 0 (``off``).  Per step the window re-centers on the live span of
# the previous front, reads from older rows realign by gathering with the
# offset delta, the target test and the ks plane shift by ``off``, and (in
# packed mode) provenance codes scatter back to absolute k before packing —
# so ``core.cigar`` decodes them unchanged.  Lanes that fall outside the
# window are pruned exactly as if the heuristic had killed them: when the
# heuristic's live span fits in Kc (see ``WavefrontHeuristic.band_cap``)
# results are bit-identical to the full-width solver; when it does not, the
# window truncation is just additional (heuristic-grade) pruning.
# ---------------------------------------------------------------------------


def _band_recenter(valid, prev_off, Kc, K):
    """New window offset centered on the live compact lanes ``valid`` [B,Kc].

    Keeps the previous offset when nothing is live (finished / diverged
    pairs just coast to loop exit)."""
    jidx = jnp.arange(Kc, dtype=jnp.int32)[None, :]
    lo = jnp.min(jnp.where(valid, jidx, Kc), axis=1)
    hi = jnp.max(jnp.where(valid, jidx, -1), axis=1)
    off = jnp.clip(prev_off + (lo + hi) // 2 - Kc // 2, 0, K - Kc)
    return jnp.where(hi >= lo, off, prev_off)


def _band_read(ring, off_hist, s, delta, off, W):
    """Ring row at score ``s - delta`` realigned to window offset ``off``."""
    row = lax.rem(jnp.maximum(s - delta, 0), W)
    r = lax.dynamic_index_in_dim(ring, row, keepdims=False)        # [B, Kc]
    roff = lax.dynamic_index_in_dim(off_hist, row, keepdims=False)  # [B]
    Kc = r.shape[-1]
    idx = jnp.arange(Kc, dtype=jnp.int32)[None, :] + (off - roff)[:, None]
    ok = (idx >= 0) & (idx < Kc) & (s >= delta)
    return jnp.where(ok, jnp.take_along_axis(r, jnp.clip(idx, 0, Kc - 1),
                                             axis=1), NEG)


def _band_reached(M, plen, tlen, k_max, off):
    """[B] bool: target diagonal reached, window-offset-aware."""
    k_final = tlen - plen + k_max - off            # compact index
    Kc = M.shape[-1]
    in_band = (k_final >= 0) & (k_final < Kc)
    idx = jnp.clip(k_final, 0, Kc - 1)
    val = jnp.take_along_axis(M, idx[:, None], axis=1)[:, 0]
    return in_band & (val >= tlen) & (val > _VALID_THRESH)


def _band_scatter(code, off, K):
    """Spread a compact [B, Kc] code plane to absolute width [B, K]."""
    Kc = code.shape[-1]
    idx = jnp.arange(K, dtype=jnp.int32)[None, :] - off[:, None]
    ok = (idx >= 0) & (idx < Kc)
    return jnp.where(ok, jnp.take_along_axis(code, jnp.clip(idx, 0, Kc - 1),
                                             axis=1), 0)


def _scores_band(pattern, text, plen, tlen, model, heur, s_max, k_max, Kc,
                 packed, begin_state, end_state):
    """Compacting-band ring solver (score-only or packed-backtrace).

    Shared implementation behind ``wfa_scores(..., band_cap=)`` and
    ``wfa_scores_packed(..., band_cap=)``; see the block comment above for
    the window discipline.  Backtrace planes stay full width so traceback
    is oblivious to the band."""
    B = pattern.shape[0]
    K = 2 * k_max + 1
    W = model.window
    affine = model.kind == "affine"

    taint = (plen.reshape(-1)[0] * 0).astype(jnp.int32)
    off0s = min(max(k_max - Kc // 2, 0), K - Kc)
    j0 = k_max - off0s                              # seed lane, in [0, Kc)

    def ks_of(off):
        return off[:, None] + jnp.arange(Kc, dtype=jnp.int32)[None, :] - k_max

    off0 = jnp.full((B,), off0s, jnp.int32) + taint
    seed0 = jnp.full((B, Kc), NEG, jnp.int32).at[:, j0].set(0)
    M0 = _extend(seed0, pattern, text, plen, tlen, ks_of(off0))

    m_ring = (jnp.full((W, B, Kc), NEG, jnp.int32) + taint).at[0].set(M0)
    off_hist = jnp.full((W, B), off0s, jnp.int32) + taint
    negBK = jnp.full((B, Kc), NEG, jnp.int32)
    I0 = seed0 if (affine and begin_state == "I") else negBK
    D0 = seed0 if (affine and begin_state == "D") else negBK
    if affine:
        i_ring = (jnp.full((W, B, Kc), NEG, jnp.int32) + taint).at[0].set(I0)
        d_ring = (jnp.full((W, B, Kc), NEG, jnp.int32) + taint).at[0].set(D0)

    def end_front(M, I, D):
        return {"M": M, "I": I, "D": D}[end_state]

    front0 = M0 if not affine else end_front(M0, I0, D0)
    score0 = _band_reached(front0, plen, tlen, k_max, off0)
    score0 = jnp.where(score0, 0, -1)

    NW = n_trace_words(s_max)
    if packed:
        m_bt = jnp.zeros((NW, B, K), jnp.int32) + taint
        if affine:
            i_bt = jnp.zeros((NW, B, K), jnp.int32) + taint
            d_bt = jnp.zeros((NW, B, K), jnp.int32) + taint

    def pack(bt, s, code, off):
        w = s // TRACE_CELLS_PER_WORD
        sh = 2 * lax.rem(s, TRACE_CELLS_PER_WORD)
        word = lax.dynamic_index_in_dim(bt, w, keepdims=False)
        full = _band_scatter(code, off, K)
        return lax.dynamic_update_index_in_dim(
            bt, word | jnp.left_shift(full, sh), w, axis=0)

    def body(carry):
        if affine:
            (s, score, m_ring, i_ring, d_ring, off_hist, *bts) = carry
        else:
            (s, score, m_ring, off_hist, *bts) = carry
        prow = lax.rem(s - 1, W)
        prev_m = lax.dynamic_index_in_dim(m_ring, prow, keepdims=False)
        prev_off = lax.dynamic_index_in_dim(off_hist, prow, keepdims=False)
        live = prev_m > _VALID_THRESH
        if affine:
            # I/D fronts can outrun M between prunes; center on the union
            live = (live
                    | (lax.dynamic_index_in_dim(i_ring, prow, keepdims=False)
                       > _VALID_THRESH)
                    | (lax.dynamic_index_in_dim(d_ring, prow, keepdims=False)
                       > _VALID_THRESH))
        off = _band_recenter(live, prev_off, Kc, K)
        ks_c = ks_of(off)

        def rd(ring):
            return lambda d: _band_read(ring, off_hist, s, d, off, W)

        if affine:
            out = _next_affine(model, rd(m_ring), pattern, text, plen, tlen,
                               ks_c, rd(i_ring), rd(d_ring),
                               with_codes=packed)
            M_new, I_new, D_new = out[:3]
            reached = _band_reached(end_front(M_new, I_new, D_new),
                                    plen, tlen, k_max, off)
        else:
            out = _next_linear(model, rd(m_ring), pattern, text, plen, tlen,
                               ks_c, with_codes=packed)
            M_new = out[0] if packed else out
            reached = _band_reached(M_new, plen, tlen, k_max, off)
        score = jnp.where((score < 0) & reached, s, score)

        keep = keep_mask(heur, M_new, plen[:, None], tlen[:, None], ks_c)
        if affine:
            M_new, I_new, D_new = _pruned(keep, M_new, I_new, D_new)
        else:
            M_new = _pruned(keep, M_new)

        row = lax.rem(s, W)
        m_ring = lax.dynamic_update_index_in_dim(m_ring, M_new, row, axis=0)
        off_hist = lax.dynamic_update_index_in_dim(off_hist, off, row, axis=0)
        if affine:
            i_ring = lax.dynamic_update_index_in_dim(i_ring, I_new, row,
                                                     axis=0)
            d_ring = lax.dynamic_update_index_in_dim(d_ring, D_new, row,
                                                     axis=0)
        if packed and affine:
            m_bt, i_bt, d_bt = bts
            cm, ci, cd = out[3:]
            bts = (pack(m_bt, s, cm, off), pack(i_bt, s, ci, off),
                   pack(d_bt, s, cd, off))
        elif packed:
            (m_bt,) = bts
            bts = (pack(m_bt, s, out[1], off),)
        if affine:
            return (s + 1, score, m_ring, i_ring, d_ring, off_hist, *bts)
        return (s + 1, score, m_ring, off_hist, *bts)

    def cond(carry):
        s, score = carry[0], carry[1]
        return (s <= s_max) & jnp.any(score < 0)

    if affine:
        init = (jnp.int32(1), score0, m_ring, i_ring, d_ring, off_hist)
        if packed:
            init += (m_bt, i_bt, d_bt)
        fin = lax.while_loop(cond, body, init)
        s, score = fin[0], fin[1]
        if packed:
            return WFAResult(score, None, None, None, s, *fin[6:9])
        return WFAResult(score, None, None, None, s)
    init = (jnp.int32(1), score0, m_ring, off_hist)
    if packed:
        init += (m_bt,)
    fin = lax.while_loop(cond, body, init)
    s, score = fin[0], fin[1]
    if packed:
        return WFAResult(score, None, None, None, s, fin[4], None, None)
    return WFAResult(score, None, None, None, s)


def _band_width(band_cap, K):
    """Validated compact width, or None to run full width."""
    if band_cap is None:
        return None
    Kc = max(int(band_cap), 9)     # floor keeps shifts/seed well-defined
    return Kc if Kc < K else None


def _next_affine(model, read_m, pattern, text, plen, tlen, ks,
                 read_i, read_d, with_codes=False, with_pre=False):
    """One gap-affine step: (M_s, I_s, D_s) from history accessors.

    ``read_m/read_i/read_d(delta)`` return the wavefront at score
    ``s - delta`` (NEG-filled when s - delta < 0).  With ``with_codes``
    also returns the 2-bit provenance code planes ``(code_m, code_i,
    code_d)`` recording which predecessor produced each cell (the
    packed-backtrace payload).
    """
    x, o, e = model.x, model.o, model.e
    m_owe = read_m(o + e)
    m_x = read_m(x)
    i_e = read_i(e)
    d_e = read_d(e)

    tl = tlen[:, None]
    pl = plen[:, None]
    ks2 = ks if ks.ndim == 2 else ks[None, :]

    # Insertion: source on diagonal k-1, offset +1; needs new h <= m.
    i_open = _shift_from_km1(m_owe)
    i_ext = _shift_from_km1(i_e)
    i_src = jnp.maximum(i_open, i_ext)
    I_new = i_src + 1
    I_new = jnp.where((i_src > _VALID_THRESH) & (I_new <= tl), I_new, NEG)

    # Deletion: source on diagonal k+1, offset unchanged; needs new v <= n.
    d_open = _shift_from_kp1(m_owe)
    d_ext = _shift_from_kp1(d_e)
    d_src = jnp.maximum(d_open, d_ext)
    D_new = jnp.where((d_src > _VALID_THRESH)
                      & (d_src - ks2 <= pl), d_src, NEG)

    # Mismatch: same diagonal, offset +1; consumes one char of each sequence.
    X_new = m_x + 1
    X_new = jnp.where((m_x > _VALID_THRESH) & (X_new <= tl)
                      & (X_new - ks2 <= pl), X_new, NEG)

    M_pre = jnp.maximum(jnp.maximum(X_new, I_new), D_new)
    M_new = _extend(M_pre, pattern, text, plen, tlen, ks)
    if with_pre:
        # pre-extension M wanted (bidir meet): the split-safety interval
        # needs both endpoints of each cell's free-match extension run.
        return M_new, I_new, D_new, M_pre
    if not with_codes:
        return M_new, I_new, D_new
    # Any candidate achieving the max is a valid optimal predecessor; the
    # tie-break (X, then I, then D; extend over open) is fixed so forward
    # and traceback agree deterministically.
    code_m = jnp.where(
        M_pre > _VALID_THRESH,
        jnp.where(M_pre == X_new, BT_M_FROM_X,
                  jnp.where(M_pre == I_new, BT_M_FROM_I, BT_M_FROM_D)),
        BT_NONE).astype(jnp.int32)
    code_i = jnp.where(
        I_new > _VALID_THRESH,
        jnp.where(i_ext >= i_open, BT_GAP_EXT, BT_GAP_OPEN),
        BT_NONE).astype(jnp.int32)
    code_d = jnp.where(
        D_new > _VALID_THRESH,
        jnp.where(d_ext >= d_open, BT_GAP_EXT, BT_GAP_OPEN),
        BT_NONE).astype(jnp.int32)
    return M_new, I_new, D_new, code_m, code_i, code_d


def _next_linear(model, read_m, pattern, text, plen, tlen, ks,
                 with_codes=False, with_pre=False):
    """One gap-linear step: M_s from the single M-history accessor.

    The one-matrix recurrence (module doc): gaps open and extend at the
    same cost, so insertions/deletions source directly from M at
    ``s - e``.  With ``with_codes`` also returns the M provenance plane
    (1 = mismatch, 2 = insertion, 3 = deletion).
    """
    x, e = model.x, model.e
    m_x = read_m(x)
    m_e = m_x if x == e else read_m(e)

    tl = tlen[:, None]
    pl = plen[:, None]
    ks2 = ks if ks.ndim == 2 else ks[None, :]

    i_src = _shift_from_km1(m_e)
    I_new = i_src + 1
    I_new = jnp.where((i_src > _VALID_THRESH) & (I_new <= tl), I_new, NEG)

    d_src = _shift_from_kp1(m_e)
    D_new = jnp.where((d_src > _VALID_THRESH)
                      & (d_src - ks2 <= pl), d_src, NEG)

    X_new = m_x + 1
    X_new = jnp.where((m_x > _VALID_THRESH) & (X_new <= tl)
                      & (X_new - ks2 <= pl), X_new, NEG)

    M_pre = jnp.maximum(jnp.maximum(X_new, I_new), D_new)
    M_new = _extend(M_pre, pattern, text, plen, tlen, ks)
    if with_pre:
        return M_new, M_pre
    if not with_codes:
        return M_new
    code_m = jnp.where(
        M_pre > _VALID_THRESH,
        jnp.where(M_pre == X_new, BT_M_FROM_X,
                  jnp.where(M_pre == I_new, BT_M_FROM_I, BT_M_FROM_D)),
        BT_NONE).astype(jnp.int32)
    return M_new, code_m


def _target_reached(M, plen, tlen, k_max):
    """[B] bool: does M hold offset == tlen on the final diagonal?"""
    k_final = tlen - plen + k_max                   # index into K axis
    K = M.shape[-1]
    in_band = (k_final >= 0) & (k_final < K)
    idx = jnp.clip(k_final, 0, K - 1)
    val = jnp.take_along_axis(M, idx[:, None], axis=1)[:, 0]
    return in_band & (val >= tlen) & (val > _VALID_THRESH)


def _prep(pattern, text, plen, tlen):
    pattern = jnp.asarray(pattern)
    text = jnp.asarray(text)
    if pattern.dtype != jnp.int32:
        pattern = pattern.astype(jnp.int32)
    if text.dtype != jnp.int32:
        text = text.astype(jnp.int32)
    return pattern, text, jnp.asarray(plen, jnp.int32), jnp.asarray(tlen, jnp.int32)


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_max",
                                             "keep_history", "heur",
                                             "begin_state", "end_state"))
def wfa_forward(pattern, text, plen, tlen, *, pen, s_max: int,
                k_max: int, keep_history: bool = True,
                heur=None, begin_state: str = "M",
                end_state: str = "M") -> WFAResult:
    """Full-history batched WFA.

    pattern/text: [B, Lp]/[B, Lt] integer codes (padding values arbitrary —
    bounds masking never reads past plen/tlen).  Returns per-pair cost and
    the wavefront history for traceback (M/I/D for affine models, M only
    for linear ones).

    ``begin_state``/``end_state`` select boundary states for BiWFA
    sub-alignments (affine only): begin ``"I"``/``"D"`` seeds the gap
    front at the origin with an already-open gap (continuation pays only
    ``e``); end ``"I"``/``"D"`` terminates on the gap front reaching the
    final cell (the alignment must end mid-gap).
    """
    model, heur = _resolve(pen, heur)
    _check_states(model, begin_state, end_state)
    pattern, text, plen, tlen = _prep(pattern, text, plen, tlen)
    B = pattern.shape[0]
    K = 2 * k_max + 1
    ks = jnp.arange(K, dtype=jnp.int32) - k_max
    affine = model.kind == "affine"

    hist_shape = (s_max + 1, B, K)
    m_hist = jnp.full(hist_shape, NEG, jnp.int32)
    i_hist = jnp.full(hist_shape, NEG, jnp.int32) if affine else None
    d_hist = jnp.full(hist_shape, NEG, jnp.int32) if affine else None

    # s = 0: M_0[k=0] = LCP(p, t); I/D invalid unless an open gap is
    # inherited from the caller (begin-state seeding).
    seed = jnp.full((B, K), NEG, jnp.int32).at[:, k_max].set(0)
    M0 = _extend(seed, pattern, text, plen, tlen, ks)
    m_hist = m_hist.at[0].set(M0)
    if affine:
        I0 = seed if begin_state == "I" else jnp.full((B, K), NEG, jnp.int32)
        D0 = seed if begin_state == "D" else jnp.full((B, K), NEG, jnp.int32)
        i_hist = i_hist.at[0].set(I0)
        d_hist = d_hist.at[0].set(D0)

    def end_front(M, I, D):
        return {"M": M, "I": I, "D": D}[end_state]

    front0 = M0 if not affine else end_front(M0, I0, D0)
    score0 = jnp.where(_target_reached(front0, plen, tlen, k_max), 0, -1)

    def read(hist, s, delta):
        row = lax.dynamic_index_in_dim(hist, jnp.maximum(s - delta, 0),
                                       keepdims=False)
        return jnp.where(s >= delta, row, NEG)

    if affine:
        def body(carry):
            s, score, m_hist, i_hist, d_hist = carry
            M_new, I_new, D_new = _next_affine(
                model, lambda d: read(m_hist, s, d), pattern, text,
                plen, tlen, ks, lambda d: read(i_hist, s, d),
                lambda d: read(d_hist, s, d))
            reached = _target_reached(end_front(M_new, I_new, D_new),
                                      plen, tlen, k_max)
            score = jnp.where((score < 0) & reached, s, score)
            M_new, I_new, D_new = _prune_step(heur, plen, tlen, ks,
                                              M_new, I_new, D_new)
            m_hist = lax.dynamic_update_index_in_dim(m_hist, M_new, s, axis=0)
            i_hist = lax.dynamic_update_index_in_dim(i_hist, I_new, s, axis=0)
            d_hist = lax.dynamic_update_index_in_dim(d_hist, D_new, s, axis=0)
            return s + 1, score, m_hist, i_hist, d_hist

        def cond(carry):
            s, score, *_ = carry
            return (s <= s_max) & jnp.any(score < 0)

        s, score, m_hist, i_hist, d_hist = lax.while_loop(
            cond, body, (jnp.int32(1), score0, m_hist, i_hist, d_hist))
    else:
        def body(carry):
            s, score, m_hist = carry
            M_new = _next_linear(model, lambda d: read(m_hist, s, d),
                                 pattern, text, plen, tlen, ks)
            reached = _target_reached(M_new, plen, tlen, k_max)
            score = jnp.where((score < 0) & reached, s, score)
            M_new = _prune_step(heur, plen, tlen, ks, M_new)
            m_hist = lax.dynamic_update_index_in_dim(m_hist, M_new, s, axis=0)
            return s + 1, score, m_hist

        def cond(carry):
            s, score, _ = carry
            return (s <= s_max) & jnp.any(score < 0)

        s, score, m_hist = lax.while_loop(
            cond, body, (jnp.int32(1), score0, m_hist))

    if keep_history:
        return WFAResult(score, m_hist, i_hist, d_hist, s)
    return WFAResult(score, None, None, None, s)


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_max", "heur",
                                             "band_cap"))
def wfa_scores(pattern, text, plen, tlen, *, pen, s_max: int,
               k_max: int, heur=None, band_cap=None) -> WFAResult:
    """Ring-buffer batched WFA — score-only throughput mode.

    Memory: rings of ``[window, B, K]`` (3 for affine, 1 for linear) with
    ``window = max(x, o+e) + 1``, the WFA metadata the paper keeps hot in
    WRAM.  This is the jnp reference for the Pallas kernel (same rolling-
    window discipline).

    ``band_cap`` (static int) switches on the compacting band: wavefronts
    are carried at width ``min(band_cap, K)`` in a window that re-centers
    on the live diagonal span each step (see the compacting-band block
    comment).  Identical results to full width whenever the live span fits
    the window; otherwise the truncation acts as extra heuristic pruning —
    so pass it only alongside a non-exact ``heur`` (or when a plain banded
    approximation is explicitly wanted).
    """
    model, heur = _resolve(pen, heur)
    pattern, text, plen, tlen = _prep(pattern, text, plen, tlen)
    B = pattern.shape[0]
    K = 2 * k_max + 1
    Kc = _band_width(band_cap, K)
    if Kc is not None:
        return _scores_band(pattern, text, plen, tlen, model, heur,
                            s_max, k_max, Kc, False, "M", "M")
    W = model.window
    ks = jnp.arange(K, dtype=jnp.int32) - k_max
    affine = model.kind == "affine"

    # data-dependent zero: keeps the while-loop carries' varying-manual-axes
    # consistent when this solver runs inside shard_map (per-shard loops)
    taint = (plen.reshape(-1)[0] * 0).astype(jnp.int32)
    m_ring = jnp.full((W, B, K), NEG, jnp.int32) + taint

    M0 = jnp.full((B, K), NEG, jnp.int32).at[:, k_max].set(0)
    M0 = _extend(M0, pattern, text, plen, tlen, ks)
    m_ring = m_ring.at[0].set(M0)
    score0 = jnp.where(_target_reached(M0, plen, tlen, k_max), 0, -1)

    def read(ring, s, delta):
        row = lax.dynamic_index_in_dim(ring, lax.rem(jnp.maximum(s - delta, 0),
                                                     W), keepdims=False)
        return jnp.where(s >= delta, row, NEG)

    if affine:
        i_ring = jnp.full((W, B, K), NEG, jnp.int32) + taint
        d_ring = jnp.full((W, B, K), NEG, jnp.int32) + taint

        def body(carry):
            s, score, m_ring, i_ring, d_ring = carry
            M_new, I_new, D_new = _next_affine(
                model, lambda d: read(m_ring, s, d), pattern, text,
                plen, tlen, ks, lambda d: read(i_ring, s, d),
                lambda d: read(d_ring, s, d))
            reached = _target_reached(M_new, plen, tlen, k_max)
            score = jnp.where((score < 0) & reached, s, score)
            M_new, I_new, D_new = _prune_step(heur, plen, tlen, ks,
                                              M_new, I_new, D_new)
            row = lax.rem(s, W)
            m_ring = lax.dynamic_update_index_in_dim(m_ring, M_new, row, axis=0)
            i_ring = lax.dynamic_update_index_in_dim(i_ring, I_new, row, axis=0)
            d_ring = lax.dynamic_update_index_in_dim(d_ring, D_new, row, axis=0)
            return s + 1, score, m_ring, i_ring, d_ring

        def cond(carry):
            s, score, *_ = carry
            return (s <= s_max) & jnp.any(score < 0)

        s, score, *_ = lax.while_loop(
            cond, body, (jnp.int32(1), score0, m_ring, i_ring, d_ring))
    else:
        def body(carry):
            s, score, m_ring = carry
            M_new = _next_linear(model, lambda d: read(m_ring, s, d),
                                 pattern, text, plen, tlen, ks)
            reached = _target_reached(M_new, plen, tlen, k_max)
            score = jnp.where((score < 0) & reached, s, score)
            M_new = _prune_step(heur, plen, tlen, ks, M_new)
            m_ring = lax.dynamic_update_index_in_dim(m_ring, M_new,
                                                     lax.rem(s, W), axis=0)
            return s + 1, score, m_ring

        def cond(carry):
            s, score, _ = carry
            return (s <= s_max) & jnp.any(score < 0)

        s, score, _ = lax.while_loop(
            cond, body, (jnp.int32(1), score0, m_ring))
    return WFAResult(score, None, None, None, s)


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_max", "heur",
                                             "begin_state", "end_state",
                                             "band_cap"))
def wfa_scores_packed(pattern, text, plen, tlen, *, pen,
                      s_max: int, k_max: int, heur=None,
                      begin_state: str = "M",
                      end_state: str = "M", band_cap=None) -> WFAResult:
    """Ring-buffer batched WFA *with* a packed backtrace.

    Identical wavefront recurrence and rolling-window memory discipline as
    :func:`wfa_scores`, plus ``[n_trace_words, B, K]`` int32 arrays of
    2-bit provenance codes (16 score steps per word, OR-accumulated in the
    score loop) — three planes for affine models, one for linear.
    ``core.cigar`` decodes them into exact CIGARs without ever
    materializing the full offset history.

    ``begin_state``/``end_state`` as in :func:`wfa_forward` (BiWFA
    sub-alignment boundaries, affine only).  The gap seed cell carries no
    provenance code; the traceback walker terminates on it directly.

    ``band_cap`` as in :func:`wfa_scores` — the backtrace planes stay full
    width (codes scatter to absolute k before packing), so ``core.cigar``
    decodes band-mode traces unchanged.
    """
    model, heur = _resolve(pen, heur)
    _check_states(model, begin_state, end_state)
    pattern, text, plen, tlen = _prep(pattern, text, plen, tlen)
    B = pattern.shape[0]
    K = 2 * k_max + 1
    Kc = _band_width(band_cap, K)
    if Kc is not None:
        return _scores_band(pattern, text, plen, tlen, model, heur,
                            s_max, k_max, Kc, True, begin_state, end_state)
    W = model.window
    NW = n_trace_words(s_max)
    ks = jnp.arange(K, dtype=jnp.int32) - k_max
    affine = model.kind == "affine"

    # data-dependent zero: keeps while-loop carries shard_map-compatible
    # (same trick as wfa_scores)
    taint = (plen.reshape(-1)[0] * 0).astype(jnp.int32)
    m_ring = jnp.full((W, B, K), NEG, jnp.int32) + taint
    m_bt = jnp.zeros((NW, B, K), jnp.int32) + taint

    seed0 = jnp.full((B, K), NEG, jnp.int32).at[:, k_max].set(0)
    M0 = _extend(seed0, pattern, text, plen, tlen, ks)
    m_ring = m_ring.at[0].set(M0)
    negBK = jnp.full((B, K), NEG, jnp.int32)
    I0 = seed0 if (affine and begin_state == "I") else negBK
    D0 = seed0 if (affine and begin_state == "D") else negBK

    def end_front(M, I, D):
        return {"M": M, "I": I, "D": D}[end_state]

    front0 = M0 if not affine else end_front(M0, I0, D0)
    score0 = jnp.where(_target_reached(front0, plen, tlen, k_max), 0, -1)

    def read(ring, s, delta):
        row = lax.dynamic_index_in_dim(ring, lax.rem(jnp.maximum(s - delta, 0),
                                                     W), keepdims=False)
        return jnp.where(s >= delta, row, NEG)

    def pack(bt, s, code):
        """OR the [B, K] code plane into word s//16 at bit offset 2*(s%16)."""
        w = s // TRACE_CELLS_PER_WORD
        off = 2 * lax.rem(s, TRACE_CELLS_PER_WORD)
        word = lax.dynamic_index_in_dim(bt, w, keepdims=False)
        return lax.dynamic_update_index_in_dim(
            bt, word | jnp.left_shift(code, off), w, axis=0)

    if affine:
        i_ring = (jnp.full((W, B, K), NEG, jnp.int32) + taint).at[0].set(I0)
        d_ring = (jnp.full((W, B, K), NEG, jnp.int32) + taint).at[0].set(D0)
        i_bt = jnp.zeros((NW, B, K), jnp.int32) + taint
        d_bt = jnp.zeros((NW, B, K), jnp.int32) + taint

        def body(carry):
            s, score, m_ring, i_ring, d_ring, m_bt, i_bt, d_bt = carry
            M_new, I_new, D_new, cm, ci, cd = _next_affine(
                model, lambda d: read(m_ring, s, d), pattern, text,
                plen, tlen, ks, lambda d: read(i_ring, s, d),
                lambda d: read(d_ring, s, d), with_codes=True)
            reached = _target_reached(end_front(M_new, I_new, D_new),
                                      plen, tlen, k_max)
            score = jnp.where((score < 0) & reached, s, score)
            M_new, I_new, D_new = _prune_step(heur, plen, tlen, ks,
                                              M_new, I_new, D_new)
            row = lax.rem(s, W)
            m_ring = lax.dynamic_update_index_in_dim(m_ring, M_new, row, axis=0)
            i_ring = lax.dynamic_update_index_in_dim(i_ring, I_new, row, axis=0)
            d_ring = lax.dynamic_update_index_in_dim(d_ring, D_new, row, axis=0)
            m_bt = pack(m_bt, s, cm)
            i_bt = pack(i_bt, s, ci)
            d_bt = pack(d_bt, s, cd)
            return s + 1, score, m_ring, i_ring, d_ring, m_bt, i_bt, d_bt

        def cond(carry):
            s, score, *_ = carry
            return (s <= s_max) & jnp.any(score < 0)

        s, score, _, _, _, m_bt, i_bt, d_bt = lax.while_loop(
            cond, body, (jnp.int32(1), score0, m_ring, i_ring, d_ring,
                         m_bt, i_bt, d_bt))
        return WFAResult(score, None, None, None, s, m_bt, i_bt, d_bt)

    def body(carry):
        s, score, m_ring, m_bt = carry
        M_new, cm = _next_linear(model, lambda d: read(m_ring, s, d),
                                 pattern, text, plen, tlen, ks,
                                 with_codes=True)
        reached = _target_reached(M_new, plen, tlen, k_max)
        score = jnp.where((score < 0) & reached, s, score)
        M_new = _prune_step(heur, plen, tlen, ks, M_new)
        m_ring = lax.dynamic_update_index_in_dim(m_ring, M_new,
                                                 lax.rem(s, W), axis=0)
        m_bt = pack(m_bt, s, cm)
        return s + 1, score, m_ring, m_bt

    def cond(carry):
        s, score, *_ = carry
        return (s <= s_max) & jnp.any(score < 0)

    s, score, _, m_bt = lax.while_loop(
        cond, body, (jnp.int32(1), score0, m_ring, m_bt))
    return WFAResult(score, None, None, None, s, m_bt, None, None)


class BidirMeetResult(NamedTuple):
    """Per-pair breakpoint from the meet-in-the-middle solver.

    ``score`` mirrors :class:`WFAResult` (``starget`` where a breakpoint
    was found, ``-1`` where the fronts never joined) so the session's
    retirement path can block on / store it unchanged.
    """
    score: jax.Array       # [B] int32: starget if met, -1 if not
    n_steps: jax.Array     # [] int32 lockstep trips taken (telemetry)
    meet_state: jax.Array  # [B] 0 = M/M, 1 = I/I, 2 = D/D; -1 unmet
    meet_a: jax.Array      # [B] prefix-side cost at the breakpoint (the
                           #     forward cost convention; the suffix side is
                           #     always starget - meet_a)
    meet_b: jax.Array      # [B] detector-internal reverse-side cost (gap
                           #     joins re-charge the open; end-state I/D
                           #     shifts by -o) — use starget - meet_a for
                           #     the suffix child's cost
    meet_k: jax.Array      # [B] forward diagonal k = h - v of the breakpoint
    meet_h: jax.Array      # [B] text offset h of the breakpoint
    meet_safe: jax.Array   # [B] 1 = provably cost-exact split, 0 = accepted
                           #     opportunistically (recurse.py re-verifies)


def _reverse_rows(codes, lens):
    """Per-row suffix reversal: out[b, i] = codes[b, lens[b]-1-i], 0-padded.

    Padding value is irrelevant downstream — every solver masks reads
    beyond plen/tlen."""
    L = codes.shape[1]
    idx = lens[:, None] - 1 - jnp.arange(L, dtype=jnp.int32)[None, :]
    ok = idx >= 0
    g = jnp.take_along_axis(codes, jnp.clip(idx, 0, L - 1), axis=1)
    return jnp.where(ok, g, 0)


@functools.partial(jax.jit, static_argnames=("pen", "s_max", "k_max", "heur",
                                             "begin_state", "end_state"))
def wfa_bidir_meet(pattern, text, plen, tlen, starget, *, pen, s_max: int,
                   k_max: int, heur=None, begin_state: str = "M",
                   end_state: str = "M") -> BidirMeetResult:
    """Meet-in-the-middle BiWFA breakpoint solver (O(s) memory).

    Runs a forward wavefront on ``(p, t)`` and a reverse wavefront on the
    reversed pair in lockstep score steps, keeping only rolling windows of
    depth ``Wd = max(window, 2*max(x, o+e) + 2)`` — never a full history.
    ``starget`` ([B] int32) is each pair's known optimal cost (from a
    prior score-only pass); the solver looks for a *breakpoint*: a cell
    reached by the forward front at cost ``a`` and by the reverse front at
    cost ``b`` with

    * ``a + b == starget``          meeting in match/mismatch state (M/M)
    * ``a + b == starget + o``      meeting inside one gap run (I/I, D/D)
      — the gap open is charged by both halves, so the sum overshoots by
      exactly ``o``; the suffix half's true cost is ``b - o``.

    Forward diagonal ``k`` and reverse diagonal ``k' = (m-n) - k`` address
    the same cell; coverage ``h_f + h_r == m`` on complementary diagonals
    joins both coordinates at once (the pattern side follows from the
    diagonal identity).  Per step ``s`` the candidate cost splits
    ``(s, T-s)`` and ``(T-s, s)`` are examined, so every split with
    ``|a - b| < Wd`` is eventually checked — and along an optimal path
    some operation boundary (or in-gap position) always lands within
    ``max(x, o+e)`` of the half-cost point, which the window covers.

    An M/M candidate is *provably exact* when the split offset can be
    placed on both furthest-reaching match runs (pre-extension forward
    value ``<= m - h_rev``): then prefix cost ``a`` and suffix cost ``b``
    are simultaneously realized and ``a + b = starget`` forces both halves
    optimal.  Gap joins are exact at exact coverage.  Remaining coverage
    overshoots are accepted opportunistically with ``meet_safe = 0`` —
    ``repro.biwfa.recurse`` re-scores every stitched CIGAR and falls back
    to the packed-trace path on any mismatch, so end-to-end exactness
    never rests on the detector.

    With a non-exact heuristic both fronts prune identically to the
    forward solvers and breakpoints become approximate (or unmet);
    unresolved pairs surface as ``score = -1``.
    """
    model, heur = _resolve(pen, heur)
    _check_states(model, begin_state, end_state)
    pattern, text, plen, tlen = _prep(pattern, text, plen, tlen)
    starget = jnp.asarray(starget, jnp.int32)
    B = pattern.shape[0]
    K = 2 * k_max + 1
    affine = model.kind == "affine"
    o = model.o if affine else 0
    # end_state "I"/"D" segments charge the trailing run's gap open in the
    # forward cost convention, but the reverse rings seed that run at 0 (it
    # is the reversed problem's *leading* gap), so every reverse cost sits
    # exactly o below the forward-convention suffix cost — shift the
    # detection target once instead of special-casing every class
    oend = o if end_state != "M" else 0
    maxop = max(model.x, model.o + model.e) if affine \
        else max(model.x, model.e)
    Wd = max(model.window, 2 * maxop + 2)
    ks = jnp.arange(K, dtype=jnp.int32) - k_max
    bidx = jnp.arange(B)

    pr = _reverse_rows(pattern, plen)
    tr = _reverse_rows(text, tlen)

    seed = jnp.full((B, K), NEG, jnp.int32).at[:, k_max].set(0)
    negBK = jnp.full((B, K), NEG, jnp.int32)
    M0f = _extend(seed, pattern, text, plen, tlen, ks)
    M0r = _extend(seed, pr, tr, plen, tlen, ks)

    def ring0(row0):
        return jnp.full((Wd, B, K), NEG, jnp.int32).at[0].set(row0)

    fm, fmp, rm = ring0(M0f), ring0(seed), ring0(M0r)
    if affine:
        fi = ring0(seed if begin_state == "I" else negBK)
        fd = ring0(seed if begin_state == "D" else negBK)
        ri = ring0(seed if end_state == "I" else negBK)
        rd = ring0(seed if end_state == "D" else negBK)

    def read(ring, s, delta):
        row = lax.dynamic_index_in_dim(
            ring, lax.rem(jnp.maximum(s - delta, 0), Wd), keepdims=False)
        return jnp.where(s >= delta, row, NEG)

    # complement-diagonal gather: rev K-index addressing the same cell
    jj = jnp.arange(K, dtype=jnp.int32)[None, :]
    jprime = (tlen - plen)[:, None] + 2 * k_max - jj
    jpok = (jprime >= 0) & (jprime < K)
    jpc = jnp.clip(jprime, 0, K - 1)

    def comp(arr):
        return jnp.where(jpok, jnp.take_along_axis(arr, jpc, axis=1), NEG)

    m2 = tlen[:, None]
    low = jnp.maximum(ks[None, :], 0)

    def body(carry):
        s, met, jst, ja, jb, jk, jh, jsf, rings = carry
        if affine:
            fm, fmp, fi, fd, rm, ri, rd = rings
            Mf, If, Df, Mfp = _next_affine(
                model, lambda d: read(fm, s, d), pattern, text, plen, tlen,
                ks, lambda d: read(fi, s, d), lambda d: read(fd, s, d),
                with_pre=True)
            Mr, Ir, Dr = _next_affine(
                model, lambda d: read(rm, s, d), pr, tr, plen, tlen,
                ks, lambda d: read(ri, s, d), lambda d: read(rd, s, d))
            Mf, If, Df, Mfp = _prune_step(heur, plen, tlen, ks,
                                          Mf, If, Df, Mfp)
            Mr, Ir, Dr = _prune_step(heur, plen, tlen, ks, Mr, Ir, Dr)
        else:
            fm, fmp, rm = rings
            Mf, Mfp = _next_linear(model, lambda d: read(fm, s, d),
                                   pattern, text, plen, tlen, ks,
                                   with_pre=True)
            Mr = _next_linear(model, lambda d: read(rm, s, d),
                              pr, tr, plen, tlen, ks)
            Mf, Mfp = _prune_step(heur, plen, tlen, ks, Mf, Mfp)
            Mr = _prune_step(heur, plen, tlen, ks, Mr)
        row = lax.rem(s, Wd)

        def put(ring, w):
            return lax.dynamic_update_index_in_dim(ring, w, row, axis=0)

        fm, fmp, rm = put(fm, Mf), put(fmp, Mfp), put(rm, Mr)
        if affine:
            fi, fd = put(fi, If), put(fd, Df)
            ri, rd = put(ri, Ir), put(rd, Dr)
            rings = (fm, fmp, fi, fd, rm, ri, rd)
        else:
            rings = (fm, fmp, rm)

        def at(ring, c):
            ok = (c >= 0) & (c <= s) & (c > s - Wd)
            sel = ring[lax.rem(jnp.maximum(c, 0), Wd), bidx]
            return jnp.where(ok[:, None], sel, NEG)

        def orient(a_m, a_g, b_m, b_g):
            """Candidate classes for prefix costs a_*, suffix costs b_*.

            Returns {name: (mask2d, state, a, b, h_plane, safe)} — a_m/b_m
            sum to starget (M/M), a_g/b_g to starget + o (gap joins)."""
            fa_m, fa_mp = at(fm, a_m), at(fmp, a_m)
            rb_m = comp(at(rm, b_m))
            vmm = (fa_m > _VALID_THRESH) & (rb_m > _VALID_THRESH)
            cov = vmm & (fa_m + rb_m >= m2)
            h_mm = jnp.clip(m2 - rb_m, low, jnp.maximum(fa_m, low))
            out = {"mm_safe": (cov & (fa_mp + rb_m <= m2), 0, a_m, b_m,
                               h_mm, 1),
                   "mm_cov": (cov, 0, a_m, b_m, h_mm, 0)}
            if affine:
                fa_i, rb_i = at(fi, a_g), comp(at(ri, b_g))
                fa_d, rb_d = at(fd, a_g), comp(at(rd, b_g))
                vii = (fa_i > _VALID_THRESH) & (rb_i > _VALID_THRESH)
                vdd = (fa_d > _VALID_THRESH) & (rb_d > _VALID_THRESH)
                out["ii0"] = (vii & (fa_i + rb_i == m2), 1, a_g, b_g,
                              fa_i, 1)
                out["dd0"] = (vdd & (fa_d + rb_d == m2), 2, a_g, b_g,
                              fa_d, 1)
                out["ii_cov"] = (vii & (fa_i + rb_i >= m2), 1, a_g, b_g,
                                 fa_i, 0)
                out["dd_cov"] = (vdd & (fa_d + rb_d >= m2), 2, a_g, b_g,
                                 fa_d, 0)
            return out

        sb = jnp.broadcast_to(s, (B,)).astype(jnp.int32)
        st2 = starget - oend
        A = orient(sb, sb, st2 - s, st2 + o - s)
        Bo = orient(st2 - s, st2 + o - s, sb, sb)
        names = ["mm_safe"] + (["ii0", "dd0"] if affine else []) \
            + ["mm_cov"] + (["ii_cov", "dd_cov"] if affine else [])
        for name in names:
            for side in (A, Bo):
                mask2d, stc, a_arr, b_arr, hplane, sf = side[name]
                anyk = jnp.any(mask2d, axis=1)
                kidx = jnp.argmax(mask2d, axis=1).astype(jnp.int32)
                hsel = jnp.take_along_axis(hplane, kidx[:, None],
                                           axis=1)[:, 0]
                take = (~met) & anyk
                met = met | take
                jst = jnp.where(take, stc, jst)
                ja = jnp.where(take, a_arr, ja)
                jb = jnp.where(take, b_arr, jb)
                jk = jnp.where(take, kidx - k_max, jk)
                jh = jnp.where(take, hsel, jh)
                jsf = jnp.where(take, sf, jsf)
        return s + 1, met, jst, ja, jb, jk, jh, jsf, rings

    def cond(carry):
        s, met, *_ = carry
        return (s <= s_max) & ~jnp.all(met)

    z = jnp.zeros((B,), jnp.int32)
    rings = (fm, fmp, fi, fd, rm, ri, rd) if affine else (fm, fmp, rm)
    s, met, jst, ja, jb, jk, jh, jsf, _ = lax.while_loop(
        cond, body, (jnp.int32(1), jnp.zeros((B,), bool), z - 1, z, z, z,
                     z, z, rings))
    return BidirMeetResult(jnp.where(met, starget, -1), s,
                           jnp.where(met, jst, -1), ja, jb, jk, jh, jsf)


def wfa_trace_shardmap(pattern, text, plen, tlen, *, pen,
                       s_max: int, k_max: int, mesh, axis_names=None,
                       heur=None, band_cap=None):
    """Per-shard packed-backtrace WFA under ``shard_map``.

    The shardmap backend's CIGAR fallback: each shard runs the packed ring
    solver to local termination (no collectives, per-shard early exit — same
    discipline as :func:`wfa_scores_shardmap`) and the packed provenance
    words come back sharded on the pair axis for host-side traceback.
    Returns ``(score, m_bt, i_bt, d_bt)`` with ``i_bt = d_bt = None`` for
    linear models.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    model = scoring.as_model(pen)
    names = tuple(axis_names if axis_names is not None else mesh.axis_names)
    spec2 = P(names, None)
    spec1 = P(names)
    spec_bt = P(None, names, None)
    affine = model.kind == "affine"

    if affine:
        def local(p, t, pl, tl):
            r = wfa_scores_packed(p, t, pl, tl, pen=pen, s_max=s_max,
                                  k_max=k_max, heur=heur, band_cap=band_cap)
            return r.score, r.m_bt, r.i_bt, r.d_bt

        out_specs = (spec1, spec_bt, spec_bt, spec_bt)
    else:
        def local(p, t, pl, tl):
            r = wfa_scores_packed(p, t, pl, tl, pen=pen, s_max=s_max,
                                  k_max=k_max, heur=heur, band_cap=band_cap)
            return r.score, r.m_bt

        out_specs = (spec1, spec_bt)

    kwargs = dict(mesh=mesh, in_specs=(spec2, spec2, spec1, spec1),
                  out_specs=out_specs)
    try:
        fn = shard_map(local, check_rep=False, **kwargs)
    except TypeError:  # newer jax dropped the check_rep kwarg
        fn = shard_map(local, **kwargs)
    out = fn(pattern, text, plen, tlen)
    if affine:
        return out
    return out[0], out[1], None, None


def wfa_scores_shardmap(pattern, text, plen, tlen, *, pen,
                        s_max: int, k_max: int, mesh, axis_names=None,
                        heur=None, band_cap=None):
    """PIM-faithful distributed WFA: per-shard termination via shard_map.

    The pjit formulation's while-condition ``any(score < 0)`` spans the
    GLOBAL batch, so SPMD inserts a small all-reduce every score iteration
    and every shard runs until the globally-slowest pair finishes.  Wrapping
    the ring-buffer solver in ``shard_map`` gives each shard its own loop —
    exactly the paper's "no inter-DPU communication": zero collectives in
    the lowered HLO (asserted by tests) and per-shard early exit.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    names = tuple(axis_names if axis_names is not None else mesh.axis_names)
    spec2 = P(names, None)
    spec1 = P(names)

    def local(p, t, pl, tl):
        return wfa_scores(p, t, pl, tl, pen=pen, s_max=s_max,
                          k_max=k_max, heur=heur, band_cap=band_cap).score

    kwargs = dict(mesh=mesh, in_specs=(spec2, spec2, spec1, spec1),
                  out_specs=spec1)
    try:
        # older jax has no replication rule for while_loop; the per-shard
        # score loop is replication-safe by construction, so opt out
        fn = shard_map(local, check_rep=False, **kwargs)
    except TypeError:  # newer jax dropped the check_rep kwarg
        fn = shard_map(local, **kwargs)
    return fn(pattern, text, plen, tlen)
