"""Streaming alignment sessions — async submission, pipelined dispatch,
out-of-order gather.

The paper's second headline number is the transfer gap: 4.87x speedup with
CPU<->DPU transfers vs 37.4x without (E=2%), closed on UPMEM by overlapping
parallel transfers with kernel execution.  The blocking ``align()`` path
cannot overlap anything: it packs, copies, runs and gathers one wave at a
time.  :class:`AlignmentSession` is the pipelined execution model behind
:meth:`AlignmentEngine.stream`:

* ``submit(patterns, texts) -> Ticket`` returns immediately.  Pairs are
  bucketed and cut into *waves* (``wave_pairs`` — the MRAM-capacity
  analogue); each wave is packed on the host and dispatched without
  blocking, so JAX async dispatch runs the device kernel of wave *N* while
  the host packs and enqueues wave *N+1* (double-buffered ``device_put``).
* at most ``max_inflight_waves`` waves are in flight — **backpressure**:
  when the pipeline is full, the oldest wave is retired (gathered) before
  the next is packed, bounding host and device memory.
* waves retire **out of order** across buckets and submissions; a
  :class:`Ticket` completes as soon as its own waves (and any recovery
  re-runs) have retired.  ``as_completed()`` yields tickets in completion
  order, ``results()`` in submission order, ``drain()`` flushes everything.
* pairs that overflow the optimistic ``edit_frac`` bound are **recycled
  into a recovery queue** instead of stalling their wave — they re-run with
  exact worst-case bounds when a full recovery wave accumulates or at
  drain, exactly like the engine's two-pass scheme (BIMSA's CPU recovery).
* each submit carries its own **output mode**: ``submit(..., output=
  "cigar")`` dispatches the backend's trace variant for that ticket's
  waves (packed backtrace on ``ring``/``kernel``/``shardmap``, full
  history on ``ref``), tracebacks run at retirement (host-side, under the
  in-flight kernels), and recovery re-runs go through the traced path too
  — so out-of-order gather and overflow recycling hand back full
  alignments, not just scores.

The sync ``engine.align()`` is itself one blocking pass through this class
(``max_inflight_waves=1`` + per-phase blocking for the Fig. 1 scatter /
kernel / gather decomposition), so there is a single execution path to
test, profile and extend.

Quickstart::

    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    with eng.stream(max_inflight_waves=2) as sess:
        tickets = [sess.submit(ps, ts) for ps, ts in chunks]
        for t in sess.as_completed():        # completion order
            consume(t.result().scores)
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Iterator, List, Optional, Sequence

import jax
import numpy as np

from repro.core import cigar as cigar_mod
from repro.core.engine import (AlignmentEngine, BucketInfo, EngineResult,
                               EngineStats, Seq, _fit_width, _pad_rows,
                               _quantize_rows, _round_up, pack_batch)
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import record as obs_record
from repro.obs import trace as obs_trace

__all__ = ["AlignmentSession", "SessionStats", "Ticket", "run_streamed"]


@dataclasses.dataclass
class SessionStats(EngineStats):
    """Aggregate telemetry across every submit of one session."""
    n_submits: int = 0
    n_waves: int = 0
    max_inflight: int = 0      # configured backpressure bound
    peak_inflight: int = 0     # highest observed in-flight wave count


class Ticket:
    """Handle for one ``submit()`` call.

    Fills in as its waves retire (possibly interleaved with other tickets'
    waves); ``done()`` is non-blocking, ``result()`` drives the session
    until this ticket is complete and returns its :class:`EngineResult`
    (scores in submission row order, per-ticket stats).
    """

    def __init__(self, session: "AlignmentSession", index: int, n_pairs: int,
                 output: str = "score", pen=None, heur=None, meta=None,
                 trace_variant: str = "packed", states=("M", "M"),
                 s_cap=None, internal: bool = False, on_done=None):
        eng = session.engine
        self.index = index
        self.n_pairs = n_pairs
        self.output = output
        # opaque caller payload (e.g. repro.mapping's (read, locus, strand)
        # records): rides the ticket through out-of-order retirement so
        # as_completed() consumers can interpret rows without a side table
        self.meta = meta
        self.pen = eng.pen if pen is None else pen          # PenaltyModel
        self.heur = eng.heuristic if heur is None else heur
        self.trace_variant = trace_variant   # "packed" | "bidir"
        # boundary states for BiWFA recursion children: "I"/"D" pins the
        # alignment start/end inside an open gap run
        self.states = tuple(states)
        # per-submit score ceiling (BiWFA children dispatch at their known
        # cost, far below the bucket worst case); None = engine bounds.
        # Capped tickets are single-pass: an unresolved row means "over the
        # cap", not "over the optimistic bound", so no recovery re-run.
        self._s_cap = s_cap
        # internal tickets (BiWFA sub-problems) never surface through
        # poll()/as_completed()/results(); on_done fires at finalization
        self.internal = internal
        self._on_done = on_done
        # trace-flow IDs riding this ticket: each connects one logical
        # request's spans (submit -> dispatch -> kernel -> retire -> done)
        # across threads.  _own_flows marks IDs this ticket allocated (it
        # ends them at finalize); externally-passed flows (serve requests,
        # BiWFA parents) are only stepped.
        self.flows: tuple = ()
        self._own_flows = False
        self.stats = EngineStats(n_pairs=n_pairs, n_workers=eng.n_workers)
        self._session = session
        self._scores = np.full((n_pairs,), -1, np.int32)
        self._cigars: Optional[dict] = {} if output == "cigar" else None
        # breakpoint fields for output="bidir_meet" rows:
        # (state, a, b, k, h, safe) per pair, -1 until the wave retires
        self._meet = (np.full((n_pairs, 6), -1, np.int32)
                      if output == "bidir_meet" else None)
        self._starget = None             # [n] known costs for meet waves
        self._p = self._t = self._plen = self._tlen = None
        self._outstanding = n_pairs      # rows without a final score yet
        self._recovery_rows: List[np.ndarray] = []   # overflow awaiting re-run
        self._steps = 0
        self._s_hi = 0
        self._k_hi = 0
        self._done = False
        self._result: Optional[EngineResult] = None

    def done(self) -> bool:
        return self._done

    def result(self) -> EngineResult:
        if not self._done:
            self._session._wait_for(self)
        return self._result


@dataclasses.dataclass
class _Wave:
    """One dispatched rectangular chunk whose device result is in flight."""
    ticket: Ticket
    rows: np.ndarray            # ticket-local row indices (un-padded count)
    res: object                 # WFAResult of in-flight device arrays
    plc: np.ndarray             # padded lens kept for CIGAR traceback
    tlc: np.ndarray
    k_max: int
    recovery: bool
    pc: Optional[np.ndarray] = None   # padded codes, kept only for CIGAR
    tc: Optional[np.ndarray] = None   # waves (packed-backtrace replay)


class AlignmentSession:
    """Pipelined submit/drain front-end over one :class:`AlignmentEngine`.

    Created via :meth:`AlignmentEngine.stream` (or directly).  Shares the
    engine's executable cache, so a warm engine streams with zero retraces.

    **Thread safety**: every public entry point (``submit*``, ``poll``,
    ``as_completed``, ``drain``, ``Ticket.result``) serializes on one
    internal re-entrant lock, so multiple worker threads may feed and
    drain one shared session — the contract ``repro.serve``'s
    :class:`~repro.serve.loop.ServeLoop` relies on.  The lock is held per
    pipeline step (one wave packed or retired), never across a blocking
    iteration, so producers are not starved by a consumer driving the
    pipe.  One session is still one logical submission stream; open
    several sessions over the same engine for independent streams.

    ``_sync_timing`` is the engine-internal blocking mode used by
    ``align()``: each wave blocks per phase so scatter/kernel/gather stay
    separable (the streaming default instead attributes host dispatch time
    to scatter and wait-time at retirement to kernel).
    """

    def __init__(self, engine: AlignmentEngine, *,
                 max_inflight_waves: int = 2,
                 wave_pairs: Optional[int] = None,
                 _sync_timing: bool = False):
        if max_inflight_waves < 1:
            raise ValueError("max_inflight_waves must be >= 1")
        self.engine = engine
        self.max_inflight = int(max_inflight_waves)
        self.wave_pairs = int(wave_pairs if wave_pairs is not None
                              else engine.chunk_pairs)
        if self.wave_pairs < 1:
            raise ValueError("wave_pairs must be >= 1")
        self._sync = bool(_sync_timing)
        self.stats = SessionStats(n_workers=engine.n_workers,
                                  max_inflight=self.max_inflight)
        self._tickets: List[Ticket] = []
        self._inflight: Deque[_Wave] = collections.deque()
        self._completed: Deque[Ticket] = collections.deque()
        self._error: Optional[BaseException] = None
        self._closed = False
        # re-entrant: a locked step may recurse (backpressure retirement
        # inside a locked dispatch, recovery flush inside a retirement)
        self._lock = threading.RLock()

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self) -> "AlignmentSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.close()
        else:
            # don't drain a failing block, but settle dispatched waves so
            # no in-flight computation outlives the session
            self._abandon_inflight()
            self._closed = True
        return False

    def close(self) -> None:
        """Drain outstanding work and refuse further submissions."""
        if not self._closed:
            try:
                self.drain()
            finally:
                self._closed = True

    @property
    def n_inflight(self) -> int:
        return len(self._inflight)

    @property
    def tickets(self) -> List[Ticket]:
        return list(self._tickets)

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("session is closed")
        if self._error is not None:
            raise RuntimeError(
                "session failed; no further submissions") from self._error

    # -- submission ----------------------------------------------------------

    def submit(self, patterns: Sequence[Seq], texts: Sequence[Seq], *,
               output: Optional[str] = None, penalties=None,
               heuristic=None, meta=None,
               trace_variant: Optional[str] = None) -> Ticket:
        """Enqueue one batch of python sequences; returns immediately.

        ``output="cigar"`` makes this ticket's waves run the backend's
        trace variant and its result carry per-pair CIGAR op arrays;
        ``penalties=``/``heuristic=`` select this ticket's penalty model
        and wavefront heuristic (tickets with different models coexist in
        one session — each compiles and caches its own executables);
        ``trace_variant="bidir"`` produces this ticket's CIGARs through the
        O(s)-memory BiWFA recursion (``repro.biwfa``) instead of the packed
        backtrace; ``None`` uses the engine defaults.  ``meta`` is an
        opaque payload stored on the returned ticket (``ticket.meta``) —
        the session never reads it.
        """
        assert len(patterns) == len(texts)
        p, plen = pack_batch(patterns)
        t, tlen = pack_batch(texts)
        return self.submit_packed(p, plen, t, tlen, output=output,
                                  penalties=penalties, heuristic=heuristic,
                                  meta=meta, trace_variant=trace_variant)

    def submit_packed(self, p: np.ndarray, plen: np.ndarray, t: np.ndarray,
                      tlen: np.ndarray, *, output: Optional[str] = None,
                      penalties=None, heuristic=None, meta=None,
                      trace_variant: Optional[str] = None,
                      _s_cap=None, _states=("M", "M"), _starget=None,
                      _internal: bool = False, _on_done=None,
                      _flows=None) -> Ticket:
        """Enqueue pre-packed [B, L] codes + [B] lens; returns immediately.

        The underscore keywords are the BiWFA driver's internal seam
        (``repro.biwfa.recurse``): sub-problems resubmit through the same
        session so they batch with live traffic.  ``_starget`` (known
        per-pair costs) flips the ticket to the engine-level
        ``"bidir_meet"`` output — a breakpoint wave, not a score/trace one.
        ``_flows`` hands the ticket externally-owned trace-flow IDs (serve
        requests, BiWFA parent tickets) to step through its spans instead
        of allocating its own.
        """
        with self._lock:
            self._check_open()
            n = int(p.shape[0])
            # resolve everything before the Ticket exists: a rejected submit
            # must leave the session clean (no permanently-incomplete ticket)
            pen = self.engine.resolve_penalties(penalties)
            if _starget is not None:
                out = "bidir_meet"
            else:
                out = self.engine.resolve_output(output, pen)
            heur = self.engine.resolve_heuristic(heuristic, out)
            tv = self.engine.resolve_trace_variant(trace_variant, out)
            ticket = Ticket(self, len(self._tickets), n, out, pen=pen,
                            heur=heur, meta=meta, trace_variant=tv,
                            states=_states, s_cap=_s_cap,
                            internal=_internal, on_done=_on_done)
            self._tickets.append(ticket)
            if _flows is not None:
                ticket.flows = tuple(_flows)
            elif obs_trace.enabled():
                # one flow per ticket: the arrow chain a Perfetto timeline
                # draws from this submit through every wave to finalize
                ticket.flows = (obs_trace.new_flow(),)
                ticket._own_flows = True
            if not _internal:
                self.stats.n_submits += 1
                self.stats.n_pairs += n
            with obs_trace.span(
                    "session.submit", cat="session",
                    args={"ticket": ticket.index, "pairs": n, "output": out}
                    if obs_trace.enabled() else None) as sp:
                for fid in ticket.flows:
                    (sp.flow_start if ticket._own_flows
                     else sp.flow_step)(fid)
                if n == 0:
                    self._finalize(ticket)
                    return ticket
                ticket._p = np.asarray(p)
                ticket._t = np.asarray(t)
                ticket._plen = np.asarray(plen, np.int32)
                ticket._tlen = np.asarray(tlen, np.int32)
                if _starget is not None:
                    ticket._starget = np.asarray(_starget, np.int32)
                if tv == "bidir" and out == "cigar" and not _internal:
                    # meet-in-the-middle traceback: a host-side driver owns
                    # this ticket — it resolves scores first, then
                    # recursively splits each pair via breakpoint waves and
                    # internal sub-tickets, all batched through this same
                    # session
                    from repro.biwfa.recurse import BidirDriver
                    BidirDriver(self, ticket).start()
                    return ticket
                eng = self.engine
                # capped tickets (BiWFA children) are single-pass: the cap
                # is already an exact bound, so skip the optimistic first
                # pass
                optimistic = (eng.edit_frac is not None
                              and eng._s_max is None and _s_cap is None)
                self._enqueue_pass(ticket, np.arange(n),
                                   exact=not optimistic, recovery=False)
                return ticket

    def _enqueue_pass(self, ticket: Ticket, idx: np.ndarray, *, exact: bool,
                      recovery: bool) -> None:
        """Bucket ``idx`` rows of ``ticket`` and dispatch them as waves."""
        eng = self.engine
        for width, bidx in eng._plan_buckets(ticket._plen, ticket._tlen, idx):
            s_max, k_max = eng._bounds_for_bucket(
                width, ticket._plen[bidx], ticket._tlen[bidx], exact,
                pen=ticket.pen, s_cap=ticket._s_cap)
            ticket._s_hi = max(ticket._s_hi, s_max)
            ticket._k_hi = max(ticket._k_hi, k_max)
            info = BucketInfo(width, s_max, k_max, len(bidx),
                              recovery=recovery)
            ticket.stats.buckets.append(info)
            self.stats.buckets.append(info)
            # long-read bucket ladder: wide buckets cap rows-per-wave so a
            # 100 kb bucket dispatches narrow waves instead of OOMing at
            # wave_pairs rows (max_wave_cells bounds rows*width per wave)
            step = min(self.wave_pairs,
                       max(eng.max_wave_cells // max(width, 1),
                           eng.n_workers, 1))
            for lo in range(0, len(bidx), step):
                self._dispatch(ticket, bidx[lo:lo + step], width,
                               s_max, k_max, recovery)

    def _dispatch(self, ticket: Ticket, rows: np.ndarray, width: int,
                  s_max: int, k_max: int, recovery: bool) -> None:
        """Pack one wave and launch it without waiting for the result."""
        # Backpressure first: retiring *before* packing keeps the remaining
        # in-flight kernels running under this wave's host-side work.
        while len(self._inflight) >= self.max_inflight:
            self._retire_one()
        eng = self.engine
        with obs_trace.span(
                "wave.scatter", cat="wave",
                args={"ticket": ticket.index, "rows": len(rows),
                      "width": width, "s_max": s_max,
                      "recovery": recovery}
                if obs_trace.enabled() else None) as sp:
            for fid in ticket.flows:
                sp.flow_step(fid)
            t0 = time.perf_counter()
            # quantized for cache reuse, but never above the per-wave
            # memory cap
            nb = min(_quantize_rows(len(rows), eng.n_workers),
                     _round_up(self.wave_pairs, eng.n_workers))
            pc = _pad_rows(_fit_width(ticket._p[rows], width), nb)
            tc = _pad_rows(_fit_width(ticket._t[rows], width), nb)
            plc = _pad_rows(ticket._plen[rows], nb)
            tlc = _pad_rows(ticket._tlen[rows], nb)
            arrays = [pc, tc, plc, tlc]
            if ticket.output == "bidir_meet":
                # breakpoint waves carry each pair's known cost as a 5th
                # input
                arrays.append(_pad_rows(ticket._starget[rows], nb))
            exe, hit = eng._executable_for(pc.shape, tc.shape, s_max, k_max,
                                           ticket.output, pen=ticket.pen,
                                           heur=ticket.heur,
                                           states=ticket.states)
            for st in (ticket.stats, self.stats):
                if hit:
                    st.cache_hits += 1
                else:
                    st.cache_misses += 1
                st.bytes_in += (pc.nbytes + tc.nbytes + plc.nbytes
                                + tlc.nbytes)
            for st in (ticket.stats, self.stats):
                st.rows_real += len(rows)
                st.rows_padded += nb
            pre = exe.n_traces
            try:
                with obs_profile.annotation("wfa.kernel.dispatch"):
                    dev = eng._device_put(*arrays)
                    if self._sync:
                        jax.block_until_ready(dev)
                        t1 = time.perf_counter()
                        for st in (ticket.stats, self.stats):
                            st.t_scatter += t1 - t0
                    res = exe.call(*dev)
                if self._sync:
                    res.score.block_until_ready()
                    t2 = time.perf_counter()
                    for st in (ticket.stats, self.stats):
                        st.t_kernel += t2 - t1
                else:
                    # async: pack + enqueue cost only; the copy and kernel
                    # are both still in flight behind this wave
                    t1 = time.perf_counter()
                    for st in (ticket.stats, self.stats):
                        st.t_scatter += t1 - t0
            except Exception as e:
                self._error = e
                self._abandon_inflight()
                raise
            n_tr = exe.n_traces - pre
            for st in (ticket.stats, self.stats):
                st.n_traces += n_tr
            keep = ticket.output == "cigar"
            self._inflight.append(_Wave(ticket, rows, res, plc, tlc, k_max,
                                        recovery,
                                        pc=pc if keep else None,
                                        tc=tc if keep else None))
        self.stats.n_waves += 1
        self.stats.peak_inflight = max(self.stats.peak_inflight,
                                       len(self._inflight))
        self._sample_inflight()
        if self._sync:
            self._retire_one()

    # -- retirement ----------------------------------------------------------

    def _sample_inflight(self) -> None:
        """Record the in-flight wave count on the gauge + counter track."""
        n = len(self._inflight)
        obs_metrics.gauge("session_inflight_waves",
                          "waves dispatched but not yet retired").set(n)
        obs_trace.counter("inflight_waves", n, cat="session")

    def _retire_one(self) -> None:
        """Gather the oldest in-flight wave and scatter its results."""
        wave = self._inflight.popleft()
        ticket = wave.ticket
        self._sample_inflight()
        _on = obs_trace.enabled()
        _args = ({"ticket": ticket.index, "rows": len(wave.rows),
                  "recovery": wave.recovery} if _on else None)
        t0 = time.perf_counter()
        with obs_trace.span("wave.kernel", cat="wave", args=_args) as sp:
            for fid in ticket.flows:
                sp.flow_step(fid)
            try:
                with obs_profile.annotation("wfa.kernel.wait"):
                    wave.res.score.block_until_ready()
            except Exception as e:
                self._error = e
                self._abandon_inflight()
                raise
        t1 = time.perf_counter()
        sp = obs_trace.span("wave.gather", cat="wave", args=_args)
        sp.__enter__()
        for fid in ticket.flows:
            sp.flow_step(fid)
        full = np.asarray(wave.res.score)
        out = full[: len(wave.rows)]
        steps = int(wave.res.n_steps)
        t2 = time.perf_counter()
        if not self._sync:       # sync mode billed the kernel at dispatch
            for st in (ticket.stats, self.stats):
                st.t_kernel += t1 - t0
        for st in (ticket.stats, self.stats):
            st.t_gather += t2 - t1
            st.bytes_out += full.nbytes
        ticket._scores[wave.rows] = out
        ticket._steps += steps
        if ticket._meet is not None:
            r = wave.res
            nr = len(wave.rows)
            ticket._meet[wave.rows] = np.stack(
                [np.asarray(r.meet_state)[:nr], np.asarray(r.meet_a)[:nr],
                 np.asarray(r.meet_b)[:nr], np.asarray(r.meet_k)[:nr],
                 np.asarray(r.meet_h)[:nr], np.asarray(r.meet_safe)[:nr]],
                axis=1).astype(np.int32)
            n_unmet = int((out < 0).sum())
            for st in (ticket.stats, self.stats):
                st.n_meet_unmet += n_unmet
        sp.__exit__(None, None, None)        # close the gather span
        if ticket._cigars is not None:
            with obs_trace.span("wave.traceback", cat="wave",
                                args=_args) as tsp:
                for fid in ticket.flows:
                    tsp.flow_step(fid)
                t3 = time.perf_counter()
                ops = cigar_mod.traceback_result(
                    wave.res, ticket.pen, pattern=wave.pc, text=wave.tc,
                    plen=wave.plc, tlen=wave.tlc, k_max=wave.k_max,
                    begin_state=ticket.states[0],
                    end_state=ticket.states[1])
                dt = time.perf_counter() - t3
                nbytes = cigar_mod.trace_nbytes(wave.res)
                for st in (ticket.stats, self.stats):
                    st.t_gather += dt
                    st.bytes_out += nbytes
                    st.peak_trace_bytes = max(st.peak_trace_bytes, nbytes)
                for j, orig in enumerate(wave.rows):
                    ticket._cigars[int(orig)] = ops[j]

        eng = self.engine
        optimistic = (eng.edit_frac is not None and eng._s_max is None
                      and ticket._s_cap is None)
        settled = len(wave.rows)     # rows this wave resolved for good
        if wave.recovery:
            n_rec = int((out >= 0).sum())
            for st in (ticket.stats, self.stats):
                st.n_recovered += n_rec
        elif optimistic:
            overflow = wave.rows[out < 0]
            if len(overflow):
                for st in (ticket.stats, self.stats):
                    st.n_overflow += len(overflow)
                obs_metrics.counter("session_overflow_pairs_total",
                                    "pairs past the optimistic bound, "
                                    "queued for exact re-run"
                                    ).inc(len(overflow))
                if obs_trace.enabled():
                    obs_trace.instant("session.overflow", cat="session",
                                      args={"ticket": ticket.index,
                                            "rows": len(overflow)})
                if eng.adaptive:
                    # recycle into the recovery queue rather than blocking
                    # the pipeline for one straggler
                    ticket._recovery_rows.append(overflow)
                    settled -= len(overflow)
        ticket._outstanding -= settled
        self._maybe_finish(ticket)
        if (ticket._recovery_rows and
                sum(len(r) for r in ticket._recovery_rows)
                >= self.wave_pairs):
            self._flush_recovery(ticket)    # a full recovery wave is ready

    def _abandon_inflight(self) -> None:
        """Settle and drop every in-flight wave after the session failed.

        The first error poisons the session; the remaining dispatched waves
        are synchronized (their errors swallowed — the first one is the one
        reported) so no in-flight computation outlives the session to raise
        at interpreter exit.
        """
        obs_record.dump("session_failure",
                        {"error": repr(self._error) if self._error else None,
                         "inflight_waves": len(self._inflight)})
        with self._lock:
            inflight, self._inflight = list(self._inflight), \
                collections.deque()
        for wave in inflight:
            try:
                wave.res.score.block_until_ready()
            except Exception:
                pass
        try:
            # drain runtime-token errors too (e.g. a failed callback inside
            # a backend) so nothing re-raises at interpreter exit
            jax.effects_barrier()
        except Exception:
            # a poisoned token makes effects_barrier raise *before* it
            # clears the token set, so jax's atexit barrier would re-raise
            # the same error; every wave is already settled above, so the
            # tokens are safe to drop
            try:
                from jax._src import dispatch as _dispatch
                _dispatch.runtime_tokens.clear()
            except Exception:            # pragma: no cover - jax internals
                pass

    def _maybe_finish(self, ticket: Ticket) -> None:
        if not ticket._done and ticket._outstanding == 0:
            self._finalize(ticket)

    def _finalize(self, ticket: Ticket) -> None:
        cig = None
        if ticket._cigars is not None:
            cig = [ticket._cigars[i] for i in range(ticket.n_pairs)]
        ticket._result = EngineResult(ticket._scores, cig, ticket._steps,
                                      ticket._s_hi, ticket._k_hi,
                                      ticket.stats,
                                      approximate=not ticket.heur.exact)
        ticket._p = ticket._t = ticket._plen = ticket._tlen = None
        ticket._done = True
        if ticket._own_flows and ticket.flows:
            # terminate the arrow chain: a zero-length span hosts the flow
            # end so viewers bind the arrowhead to this thread's timeline
            with obs_trace.span("session.ticket_done", cat="session",
                                args={"ticket": ticket.index}
                                if obs_trace.enabled() else None) as sp:
                for fid in ticket.flows:
                    sp.flow_end(fid)
        if ticket.internal:
            # BiWFA sub-problem: hand the result to the driver (which may
            # re-enter submit_packed — the lock is re-entrant) instead of
            # surfacing through poll()/as_completed()
            if ticket._on_done is not None:
                ticket._on_done(ticket)
        else:
            self._completed.append(ticket)

    def _flush_recovery(self, ticket: Optional[Ticket] = None) -> None:
        """Re-run queued overflow pairs with exact worst-case bounds."""
        for t in ([ticket] if ticket is not None else list(self._tickets)):
            if t._recovery_rows:
                rows = np.concatenate(t._recovery_rows)
                t._recovery_rows = []
                if obs_trace.enabled():
                    obs_trace.instant("session.recovery_flush",
                                      cat="session",
                                      args={"ticket": t.index,
                                            "rows": len(rows)})
                self._enqueue_pass(t, rows, exact=True, recovery=True)

    # -- gather --------------------------------------------------------------

    def _step(self, ticket: Optional[Ticket] = None) -> None:
        """Make one unit of progress (retire a wave or launch recovery)."""
        if self._error is not None:
            raise RuntimeError("session failed") from self._error
        if self._inflight:
            self._retire_one()
        elif ticket is not None and ticket._recovery_rows:
            self._flush_recovery(ticket)
        elif any(t._recovery_rows for t in self._tickets):
            self._flush_recovery()
        else:
            raise RuntimeError("session stalled: incomplete tickets with "
                               "no in-flight waves")        # pragma: no cover

    def _wait_for(self, ticket: Ticket) -> None:
        """Drive the pipeline until ``ticket`` is complete."""
        while not ticket._done:
            with self._lock:
                if not ticket._done:
                    self._step(ticket)

    @staticmethod
    def _wave_ready(wave: _Wave) -> bool:
        """True when the wave's device result can be gathered without
        blocking.  Results that don't expose ``is_ready`` (plug-in
        backends returning exotic array types) count as ready, so
        retirement falls back to blocking rather than never progressing.
        """
        probe = getattr(wave.res.score, "is_ready", None)
        return True if probe is None else bool(probe())

    def _inflight_diagnostics(self) -> str:
        """One-line pipeline state for TimeoutError messages."""
        with self._lock:
            waves = [f"ticket {w.ticket.index}:{len(w.rows)} rows"
                     + (" (recovery)" if w.recovery else "")
                     for w in self._inflight]
            n_open = sum(1 for t in self._tickets if not t._done)
            n_rec = sum(len(r) for t in self._tickets
                        for r in t._recovery_rows)
        return (f"{len(waves)} wave(s) in flight [{'; '.join(waves)}], "
                f"{n_open} ticket(s) incomplete, "
                f"{n_rec} recovery row(s) queued")

    def _step_timed(self, deadline: float) -> None:
        """Make one unit of progress before ``deadline`` or raise
        ``TimeoutError`` (with pipeline diagnostics) — never yields a
        partial step."""
        while True:
            with self._lock:
                if self._error is not None:
                    raise RuntimeError("session failed") from self._error
                if self._completed or all(t._done for t in self._tickets):
                    return
                if self._inflight:
                    if self._wave_ready(self._inflight[0]):
                        self._retire_one()
                        return
                elif any(t._recovery_rows for t in self._tickets):
                    self._flush_recovery()
                    return
                else:
                    raise RuntimeError(
                        "session stalled: incomplete tickets with no "
                        "in-flight waves")          # pragma: no cover
            now = time.monotonic()
            if now >= deadline:
                diag = self._inflight_diagnostics()
                obs_record.dump("as_completed_timeout", {"detail": diag})
                raise TimeoutError("as_completed timed out: " + diag)
            # oldest wave still running: nap outside the lock so producers
            # keep submitting while we wait
            time.sleep(min(1e-3, deadline - now))

    def poll(self, *, flush_recovery: bool = True) -> List[Ticket]:
        """Non-blocking progress probe -> tickets that newly completed.

        Retires every in-flight wave whose device result is already ready
        (``jax.Array.is_ready``), never blocking on a running kernel; when
        the pipeline is otherwise empty and ``flush_recovery`` is set,
        queued overflow rows are re-dispatched immediately (a server loop
        cannot wait for a full recovery wave to accumulate — stragglers
        would stall forever at low load).  Returns the completed-ticket
        backlog (the same queue ``as_completed()`` consumes), possibly
        empty.  This is the probe ``repro.serve``'s worker loop runs
        between admissions.
        """
        with self._lock:
            if self._error is not None:
                raise RuntimeError("session failed") from self._error
            while self._inflight and self._wave_ready(self._inflight[0]):
                self._retire_one()
            if flush_recovery and not self._inflight:
                self._flush_recovery()
                while self._inflight and self._wave_ready(self._inflight[0]):
                    self._retire_one()
            out = list(self._completed)
            self._completed.clear()
            return out

    def as_completed(self, timeout: Optional[float] = None) -> Iterator[Ticket]:
        """Yield tickets as they finish — out of order, minimal latency.

        Keeps driving the pipeline between yields; tickets submitted while
        iterating are picked up too.  Each completed ticket is yielded
        exactly once per session (``poll()`` consumes the same backlog).

        ``timeout`` bounds the **total** wait across the iteration (like
        ``concurrent.futures.as_completed``): if the deadline passes while
        a wave is still running, ``TimeoutError`` is raised with in-flight
        diagnostics (which tickets' waves are stuck, how many recovery
        rows are queued) instead of blocking forever on a stalled kernel.
        """
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        while True:
            while True:
                with self._lock:
                    ticket = (self._completed.popleft()
                              if self._completed else None)
                if ticket is None:
                    break
                yield ticket
            with self._lock:
                if self._completed:         # another thread raced a wave in
                    continue
                if all(t._done for t in self._tickets):
                    return
                if deadline is None:
                    self._step()
                    continue
            self._step_timed(deadline)

    def results(self) -> Iterator[EngineResult]:
        """Yield each submit's :class:`EngineResult` in submission order
        (internal BiWFA sub-tickets excluded)."""
        i = 0
        while i < len(self._tickets):
            if not self._tickets[i].internal:
                yield self._tickets[i].result()
            i += 1

    def drain(self) -> SessionStats:
        """Block until every submitted pair (incl. recovery) has a result."""
        while True:
            with self._lock:
                if not (self._inflight
                        or any(t._recovery_rows for t in self._tickets)):
                    return self.stats
                self._step()


def run_streamed(engine: AlignmentEngine, p: np.ndarray, plen: np.ndarray,
                 t: np.ndarray, tlen: np.ndarray, *, submit_pairs: int,
                 max_inflight_waves: int = 4,
                 output: Optional[str] = None, penalties=None,
                 heuristic=None, trace_variant: Optional[str] = None):
    """Stream one packed batch through a fresh session in ``submit_pairs``
    chunks with out-of-order gather
    -> (scores, cigars-or-None, SessionStats, wall_seconds).

    The shared harness behind the launcher's ``--mode stream`` and the
    transfer-overhead benchmark's streamed column.  ``output="cigar"``
    gathers per-pair op arrays (in submission row order) alongside scores.
    """
    n = int(p.shape[0])
    out_mode = engine.resolve_output(output,
                                     engine.resolve_penalties(penalties))
    scores = np.empty((n,), np.int32)
    cigars: Optional[List[np.ndarray]] = \
        [None] * n if out_mode == "cigar" else None
    t0 = time.perf_counter()
    with engine.stream(max_inflight_waves=max_inflight_waves) as sess:
        offset = {}
        for lo in range(0, n, submit_pairs):
            hi = min(n, lo + submit_pairs)
            ticket = sess.submit_packed(p[lo:hi], plen[lo:hi],
                                        t[lo:hi], tlen[lo:hi],
                                        output=out_mode,
                                        penalties=penalties,
                                        heuristic=heuristic,
                                        trace_variant=trace_variant)
            offset[ticket.index] = lo
        for ticket in sess.as_completed():
            lo = offset[ticket.index]
            res = ticket.result()
            scores[lo:lo + ticket.n_pairs] = res.scores
            if cigars is not None:
                cigars[lo:lo + ticket.n_pairs] = res.cigars
        stats = sess.stats
    return scores, cigars, stats, time.perf_counter() - t0
