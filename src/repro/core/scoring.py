"""Scoring models and wavefront heuristics — the distance-metric seam.

The source paper evaluates one gap-affine setting; its follow-up framework
paper (arXiv:2208.01243) shows the same PIM pipeline pays off across
multiple distance metrics plus a WFA-adaptive band heuristic.  This module
is that seam: a :class:`PenaltyModel` hierarchy selecting the wavefront
*recurrence* and a :class:`WavefrontHeuristic` family selecting the
*pruning* policy.  Both are frozen/hashable dataclasses so they ride as
static jit arguments straight into the solvers (``core.wavefront``) and the
Pallas kernel (``kernels.wfa``).

Penalty models (match always costs 0):

* :class:`GapAffine` ``(x, o, e)`` — mismatch ``x``, gap ``o + L*e``.  The
  classic three-matrix M/I/D recurrence (the repo's historic default; a
  plain :class:`~repro.core.penalties.Penalties` normalizes to this).
* :class:`GapLinear` ``(x, e)`` — mismatch ``x``, gap ``L*e``.  With no
  open cost, I/D wavefronts are redundant: gaps chain straight through M,
  so the solvers run a cheaper **one-matrix** recurrence

      M_s[k] = max(M_{s-x}[k] + 1, M_{s-e}[k-1] + 1, M_{s-e}[k+1])

  — one ring buffer instead of three, one packed-backtrace plane instead
  of three, fewer VPU ops per score step.
* :class:`Edit` — Levenshtein distance (``x = e = 1``): the one-matrix
  recurrence with every delta equal to 1, the cheapest variant (window of
  2 wavefronts, score == edit distance).

Wavefront heuristics (the follow-up paper's WFA-adaptive story):

* :class:`NoHeuristic` — exact scores, the default.
* :class:`AdaptiveBand` ``(min_wf_len, max_distance_diff)`` — WFA-adaptive
  (Marco-Sola et al. 2021 §2.5): once a wavefront holds more than
  ``min_wf_len`` live diagonals, prune those whose estimated remaining
  distance to the target cell exceeds the best estimate by more than
  ``max_distance_diff``.  Pruned k-lanes hold the invalid sentinel, so
  they cost no further extension work and their provenance chains die.
* :class:`ZDrop` ``(zdrop)`` — X-drop/Z-drop style: prune diagonals whose
  antidiagonal progress ``h + v`` trails the current front's best by more
  than ``zdrop``.

Heuristic results are **approximate**: scores are an upper bound on (and
with sane parameters on read-like data almost always equal to) the exact
cost, and badly divergent pairs may come back unresolved (``-1``).  Every
result produced under a non-exact heuristic is flagged
``approximate=True`` so downstream consumers can tell.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core import penalties as penalties_mod
from repro.core.penalties import Penalties

__all__ = [
    "PenaltyModel", "GapAffine", "GapLinear", "Edit",
    "WavefrontHeuristic", "NoHeuristic", "AdaptiveBand", "ZDrop",
    "EXACT", "as_model", "as_heuristic", "parse_penalties",
    "parse_heuristic",
]


# ---------------------------------------------------------------------------
# Penalty models.


@dataclasses.dataclass(frozen=True)
class PenaltyModel:
    """Base class: a scoring scheme the wavefront solvers can compile.

    Subclasses pin the effective ``(x, o, e)`` triple and the recurrence
    ``kind`` — ``"affine"`` (three-matrix M/I/D) or ``"linear"``
    (one-matrix M).  Instances are frozen and hashable: they are jit
    static arguments and executable-cache key components.
    """

    @property
    def kind(self) -> str:
        raise NotImplementedError

    # Effective penalty triple; linear models report o == 0.
    @property
    def x(self) -> int:
        raise NotImplementedError

    @property
    def o(self) -> int:
        return 0

    @property
    def e(self) -> int:
        raise NotImplementedError

    @property
    def window(self) -> int:
        """Ring-buffer depth: wavefront s reads back at most this far."""
        return max(self.x, self.o + self.e) + 1

    def gap_cost(self, length: int) -> int:
        return 0 if length == 0 else self.o + length * self.e

    def unit_cost(self) -> int:
        """Max cost of one isolated edit (mismatch or 1-long gap)."""
        return max(self.x, self.o + self.e)

    def as_penalties(self) -> Penalties:
        """The equivalent ``(x, o, e)`` triple for oracle/rescoring code
        (``gotoh_score*``/``score_cigar`` price any model through it)."""
        return Penalties(x=self.x, o=self.o, e=self.e)

    # The bound formulas are duck-typed on (x, o, e) and canonically live
    # in core.penalties; delegating keeps exactly one copy of the math the
    # engine sizes buffers with.
    def score_bound(self, max_len: int, edit_frac: float,
                    len_diff: int = 0, slack: int = 2) -> int:
        """Upper bound on the score of a pair within ``edit_frac`` edits."""
        return penalties_mod.score_bound(self, max_len, edit_frac,
                                         len_diff=len_diff, slack=slack)

    def band_bound(self, s_max: int) -> int:
        """Max |diagonal| reachable with score <= s_max."""
        return penalties_mod.band_bound(self, s_max)

    def worst_score(self, plen: int, tlen: int) -> int:
        """Exact worst case: all-mismatch diagonal plus one closing gap."""
        return self.x * min(plen, tlen) + self.gap_cost(abs(tlen - plen))


@dataclasses.dataclass(frozen=True)
class GapAffine(PenaltyModel):
    """Gap-affine (Gotoh): mismatch ``x``, gap of length L costs o + L*e."""
    mismatch: int = 4
    gap_open: int = 6
    gap_extend: int = 2

    def __post_init__(self):
        # ValueError, not assert: CLI-reachable (parse_penalties) and must
        # survive python -O (x=0 would read the in-flight ring row)
        if not (self.mismatch > 0 and self.gap_open >= 0
                and self.gap_extend > 0):
            raise ValueError(f"need mismatch > 0, gap_open >= 0, "
                             f"gap_extend > 0: {self}")

    @property
    def kind(self) -> str:
        return "affine"

    @property
    def x(self) -> int:
        return self.mismatch

    @property
    def o(self) -> int:
        return self.gap_open

    @property
    def e(self) -> int:
        return self.gap_extend


@dataclasses.dataclass(frozen=True)
class GapLinear(PenaltyModel):
    """Gap-linear: mismatch ``x``, gap of length L costs L*e (no open)."""
    mismatch: int = 4
    gap_extend: int = 2

    def __post_init__(self):
        if not (self.mismatch > 0 and self.gap_extend > 0):
            raise ValueError(
                f"need mismatch > 0, gap_extend > 0: {self}")

    @property
    def kind(self) -> str:
        return "linear"

    @property
    def x(self) -> int:
        return self.mismatch

    @property
    def e(self) -> int:
        return self.gap_extend


@dataclasses.dataclass(frozen=True)
class Edit(PenaltyModel):
    """Levenshtein distance: every edit costs 1 (x = e = 1, no open)."""

    @property
    def kind(self) -> str:
        return "linear"

    @property
    def x(self) -> int:
        return 1

    @property
    def e(self) -> int:
        return 1


def as_model(pen: Union[PenaltyModel, Penalties, None]) -> PenaltyModel:
    """Normalize to a :class:`PenaltyModel`.

    ``Penalties`` (the historic gap-affine triple) maps to
    :class:`GapAffine`; ``None`` maps to the default gap-affine model.
    """
    if pen is None:
        return GapAffine()
    if isinstance(pen, PenaltyModel):
        return pen
    if isinstance(pen, Penalties):
        return GapAffine(mismatch=pen.x, gap_open=pen.o, gap_extend=pen.e)
    raise TypeError(f"expected PenaltyModel or Penalties, got {pen!r}")


# ---------------------------------------------------------------------------
# Wavefront heuristics.


@dataclasses.dataclass(frozen=True)
class WavefrontHeuristic:
    """Base class for per-score-step wavefront pruning policies."""

    @property
    def exact(self) -> bool:
        """True when results under this heuristic are provably optimal."""
        return False

    def band_cap(self, K: int) -> "int | None":
        """Static compact-band width for a ``K``-diagonal problem, or None.

        A heuristic that keeps its live diagonals inside a bounded span can
        return a cap ``Kc < K``: the solvers then run the whole score loop
        on a ``Kc``-wide *compacting band* that re-centers on the live range
        each step — every per-step vector op shrinks from ``K`` to ``Kc``
        lanes (WFA-adaptive style) instead of masking dead lanes at full
        width.  Lanes that drift outside the compact window are pruned
        exactly as if the heuristic had killed them, so results stay the
        usual heuristic upper bound.  ``None`` (the default) means the
        heuristic gives no useful bound and solvers run full width.
        """
        return None


@dataclasses.dataclass(frozen=True)
class NoHeuristic(WavefrontHeuristic):
    """Keep every diagonal — exact WFA."""

    @property
    def exact(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class AdaptiveBand(WavefrontHeuristic):
    """WFA-adaptive: prune diagonals far from the best remaining-distance
    estimate once the wavefront is longer than ``min_wf_len``."""
    min_wf_len: int = 10
    max_distance_diff: int = 50

    def __post_init__(self):
        if not (self.min_wf_len >= 1 and self.max_distance_diff >= 1):
            raise ValueError(
                f"need min_wf_len >= 1, max_distance_diff >= 1: {self}")

    def band_cap(self, K: int) -> "int | None":
        # live lanes sit within max_distance_diff of the best remaining-
        # distance estimate; adjacent diagonals change the estimate by >= 1
        # each, so the live span is bounded by max_distance_diff lanes on
        # EACH side of the best (two-sided), plus the min_wf_len floor.
        # The +2 margin absorbs the per-step +-1 band growth between
        # re-centerings.
        cap = _round_up(2 * self.max_distance_diff + self.min_wf_len + 2,
                        8) + 1
        return cap if cap < K else None


@dataclasses.dataclass(frozen=True)
class ZDrop(WavefrontHeuristic):
    """Prune diagonals whose antidiagonal progress trails the front's best
    by more than ``zdrop``."""
    zdrop: int = 100

    def __post_init__(self):
        if self.zdrop < 1:
            raise ValueError(f"need zdrop >= 1: {self}")

    def band_cap(self, K: int) -> "int | None":
        # antidiagonal progress h+v drops by >= 1 per diagonal away from
        # the best lane, so live lanes sit within zdrop of it on EITHER
        # side: the live span is two-sided, up to 2*zdrop + 1 lanes
        cap = _round_up(2 * self.zdrop + 2, 8) + 1
        return cap if cap < K else None


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


EXACT = NoHeuristic()


def as_heuristic(h: Union[WavefrontHeuristic, None]) -> WavefrontHeuristic:
    if h is None:
        return EXACT
    if isinstance(h, WavefrontHeuristic):
        return h
    raise TypeError(f"expected WavefrontHeuristic, got {h!r}")


# ---------------------------------------------------------------------------
# CLI spellings (launch/align.py and benchmarks).


def parse_penalties(spec: str) -> PenaltyModel:
    """Parse a CLI penalty spec.

    Accepted forms: ``edit`` | ``linear:x,e`` | ``affine:x,o,e`` | the bare
    triple ``x,o,e`` (historic gap-affine spelling).
    """
    s = spec.strip().lower()
    if s == "edit":
        return Edit()
    if s in ("affine", "gap-affine"):
        return GapAffine()
    if s in ("linear", "gap-linear"):
        return GapLinear()
    if ":" in s:
        head, _, args = s.partition(":")
        nums = [int(v) for v in args.split(",") if v.strip()]
        if head in ("linear", "gap-linear") and len(nums) == 2:
            return GapLinear(mismatch=nums[0], gap_extend=nums[1])
        if head in ("affine", "gap-affine") and len(nums) == 3:
            return GapAffine(*nums)
        raise ValueError(f"bad penalty spec {spec!r}; use 'edit', "
                         "'linear:x,e', 'affine:x,o,e' or 'x,o,e'")
    nums = [int(v) for v in s.split(",") if v.strip()]
    if len(nums) == 3:
        return GapAffine(*nums)
    raise ValueError(f"bad penalty spec {spec!r}; use 'edit', "
                     "'linear:x,e', 'affine:x,o,e' or 'x,o,e'")


def parse_heuristic(spec: str) -> WavefrontHeuristic:
    """Parse a CLI heuristic spec.

    Accepted forms: ``none`` | ``adaptive`` | ``adaptive:min_wf_len,
    max_distance_diff`` | ``zdrop`` | ``zdrop:z``.
    """
    s = spec.strip().lower()
    if s in ("none", "exact", "off"):
        return EXACT
    head, _, args = s.partition(":")
    nums = [int(v) for v in args.split(",") if v.strip()] if args else []
    if head == "adaptive":
        if not nums:
            return AdaptiveBand()
        if len(nums) == 2:
            return AdaptiveBand(min_wf_len=nums[0], max_distance_diff=nums[1])
    elif head == "zdrop":
        if not nums:
            return ZDrop()
        if len(nums) == 1:
            return ZDrop(zdrop=nums[0])
    raise ValueError(f"bad heuristic spec {spec!r}; use 'none', "
                     "'adaptive[:min_wf_len,max_distance_diff]' or "
                     "'zdrop[:z]'")
