"""The paper's scenario end-to-end: generate read pairs at an edit threshold,
scatter them PIM-style over every device, align, gather, report Total vs
Kernel throughput (Fig. 1's decomposition).

    PYTHONPATH=src python examples/align_reads.py --pairs 20000 --edit-frac 0.02
    PYTHONPATH=src python examples/align_reads.py --backend kernel --pairs 512
"""
import sys

from repro.launch.align import main

if __name__ == "__main__":
    sys.exit(main())
