"""The paper's scenario end-to-end: generate read pairs at an edit threshold,
stream them through the engine's AlignmentSession (async submits, pipelined
waves, out-of-order gather — the paper's transfer/compute overlap), and
report Total vs Kernel throughput (Fig. 1's decomposition).  ``--output
cigar`` streams full alignments (packed backtrace + identity stats);
``--output sam`` writes SAM-style records.

    PYTHONPATH=src python examples/align_reads.py --pairs 20000 --edit-frac 0.02
    PYTHONPATH=src python examples/align_reads.py --mode both --pairs 8192
    PYTHONPATH=src python examples/align_reads.py --backend kernel --pairs 512
    PYTHONPATH=src python examples/align_reads.py --output cigar --verify 128
    PYTHONPATH=src python examples/align_reads.py --output sam --sam-out out.sam
    PYTHONPATH=src python examples/align_reads.py --no-bucket --no-adaptive
    PYTHONPATH=src python examples/align_reads.py --penalties edit --verify 64
    PYTHONPATH=src python examples/align_reads.py --heuristic adaptive:10,50
    PYTHONPATH=src python examples/align_reads.py --reads r.fq.gz --refs r.fa
"""
import sys

from repro.launch.align import main

if __name__ == "__main__":
    sys.exit(main())
