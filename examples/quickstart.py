"""Quickstart: align sequence pairs with the WFA core, get scores + CIGARs.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DEFAULT, Penalties, WFAligner
from repro.core.gotoh import gotoh_score

# -- 1. score + CIGAR for a handful of pairs ------------------------------
aligner = WFAligner(DEFAULT, backend="ref", with_cigar=True)
patterns = ["ACGTTAGCCA", "GATTACA", "TTTTTTTT"]
texts = ["ACGTCAGCCA", "GATTTACA", "TTTT"]
res = aligner.align(patterns, texts)

print("gap-affine penalties:", DEFAULT)
for p, t, s, c in zip(patterns, texts, res.scores, res.cigar_strings()):
    print(f"  {p:12s} vs {t:12s} -> cost {s:3d}  cigar {c}")

# -- 2. exactness: WFA == dense Gotoh DP (the paper's correctness contract)
for p, t, s in zip(patterns, texts, res.scores):
    g = gotoh_score(np.frombuffer(p.encode(), np.uint8),
                    np.frombuffer(t.encode(), np.uint8), DEFAULT)
    assert s == g, (p, t, s, g)
print("all scores match the dense DP oracle")

# -- 3. throughput mode: batch of 1000 pairs, score-only ring buffers ------
rng = np.random.default_rng(0)
bases = np.frombuffer(b"ACGT", np.uint8)
refs = ["".join(map(chr, bases[rng.integers(0, 4, 100)])) for _ in range(1000)]
mates = [r[:50] + ("A" if r[50] != "A" else "C") + r[51:] for r in refs]

fast = WFAligner(DEFAULT, backend="ring", edit_frac=0.04)
res = fast.align(refs, mates)
print(f"batch of {len(refs)}: mean cost {res.scores.mean():.2f}, "
      f"{res.n_steps} lock-step score iterations")

# -- 4. edit distance is just another penalty setting ----------------------
ed = WFAligner(Penalties(x=1, o=0, e=1), backend="ring")
print("edit('kitten','sitting') =", ed.align(["kitten"], ["sitting"]).scores[0])
