"""Quickstart: the unified AlignmentEngine API.

One object covers every alignment scenario:

* ``AlignmentEngine(backend=...)`` picks an execution strategy from the
  backend registry — ``"ref"`` (pure-jnp reference), ``"ring"``
  (rolling-window throughput), ``"kernel"`` (Pallas TPU kernel),
  ``"shardmap"`` (per-shard termination on a mesh) — and plug-ins can
  ``register_backend`` their own without touching core code.
* Every call picks an output mode: ``output="score"`` (default) or
  ``output="cigar"`` — full alignments on *any* built-in backend, via the
  packed 2-bit backtrace (``ring``/``kernel``/``shardmap``) or the full
  history (``ref``).
* Mixed-length batches are split into power-of-two length buckets, so short
  pairs never pay the longest pair's padded band; compiled executables are
  cached per bucket shape, so serving-time calls re-trace nothing.
* With ``edit_frac`` (the paper's E), buffers are sized optimistically and
  the rare over-budget pair is transparently re-run with exact worst-case
  bounds — every score is real, the common case stays fast.

* ``engine.stream()`` opens an ``AlignmentSession`` — async ``submit()``,
  pipelined dispatch (host packing overlaps the in-flight device kernel),
  out-of-order ``as_completed()`` gather.  The blocking ``align()`` is a
  thin wrapper over the same session.

    PYTHONPATH=src python examples/quickstart.py

(The old ``WFAligner`` / ``PIMBatchAligner`` names still work as deprecated
thin wrappers over the engine.)
"""
import numpy as np

from repro.core import DEFAULT, AlignmentEngine, Penalties, available_backends
from repro.core.gotoh import gotoh_score

print("registered backends:", available_backends())

# -- 1. score + CIGAR for a handful of pairs ------------------------------
# output="cigar" works on every built-in backend: "ring"/"kernel" record a
# packed 2-bit backtrace (~16x smaller than "ref"'s full history)
engine = AlignmentEngine(DEFAULT, backend="ring")
patterns = ["ACGTTAGCCA", "GATTACA", "TTTTTTTT"]
texts = ["ACGTCAGCCA", "GATTTACA", "TTTT"]
res = engine.align(patterns, texts, output="cigar")

print("gap-affine penalties:", DEFAULT)
for p, t, s, c, cc in zip(patterns, texts, res.scores, res.cigar_strings(),
                          res.cigar_strings("classic")):
    print(f"  {p:12s} vs {t:12s} -> cost {s:3d}  cigar {c}  ({cc})")

# -- 2. exactness: WFA == dense Gotoh DP (the paper's correctness contract)
for p, t, s in zip(patterns, texts, res.scores):
    g = gotoh_score(np.frombuffer(p.encode(), np.uint8),
                    np.frombuffer(t.encode(), np.uint8), DEFAULT)
    assert s == g, (p, t, s, g)
print("all scores match the dense DP oracle")

# -- 3. throughput mode: mixed-length batch, bucketed + cached -------------
rng = np.random.default_rng(0)
bases = np.frombuffer(b"ACGT", np.uint8)
refs = ["".join(map(chr, bases[rng.integers(0, 4, int(L))]))
        for L in rng.integers(64, 512, size=1000)]
mates = [r[:10] + ("A" if r[10] != "A" else "C") + r[11:] for r in refs]

fast = AlignmentEngine(DEFAULT, backend="ring", edit_frac=0.04)
res = fast.align(refs, mates)
print(f"batch of {len(refs)}: mean cost {res.scores.mean():.2f} across "
      f"{res.stats.n_buckets} length buckets "
      f"({res.stats.n_overflow} overflow -> {res.stats.n_recovered} recovered)")

res2 = fast.align(refs, mates)   # serving-time call: all executables cached
print(f"second call: {res2.stats.cache_hits} cache hits, "
      f"{res2.stats.n_traces} retraces")

# -- 4. streaming: async submit, pipelined waves, out-of-order gather ------
with fast.stream(max_inflight_waves=4) as sess:
    tickets = [sess.submit(refs[lo:lo + 250], mates[lo:lo + 250])
               for lo in range(0, len(refs), 250)]
    done_order = [t.index for t in sess.as_completed()]
print(f"streamed {sess.stats.n_submits} submits as {sess.stats.n_waves} waves "
      f"(peak {sess.stats.peak_inflight} in flight, "
      f"{sess.stats.n_traces} retraces); completion order {done_order}")
streamed = np.concatenate([t.result().scores for t in tickets])
assert streamed.tolist() == res.scores.tolist()
print("streamed scores identical to the blocking path")

# -- 5. edit distance is just another penalty setting ----------------------
ed = AlignmentEngine(Penalties(x=1, o=0, e=1), backend="ring")
print("edit('kitten','sitting') =", ed.align(["kitten"], ["sitting"]).scores[0])
