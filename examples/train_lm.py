"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on the synthetic motif stream, with periodic async checkpoints and a
resumable loop (the CPU-scale instance of the production train path).

    PYTHONPATH=src python examples/train_lm.py                  # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --steps 50       # quicker look
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b-smoke

The loss must drop well below ln(vocab) — the stream has learnable motif
structure.  Try the fault drill:

    PYTHONPATH=src python examples/train_lm.py --steps 100 \
        --ckpt-dir /tmp/ck --simulate-failure 60
    PYTHONPATH=src python examples/train_lm.py --steps 100 \
        --ckpt-dir /tmp/ck --resume
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] if len(sys.argv) > 1 else
                  ["--steps", "300", "--global-batch", "8", "--seq", "256",
                   "--ckpt-dir", "/tmp/repro_train_100m", "--ckpt-every", "100"]))
