"""Serve a small model with batched requests: prefill + lock-step decode
waves with greedy sampling (the CPU-scale instance of the decode cells the
dry-run lowers at 32k/500k context).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m-smoke --max-new 16
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] if len(sys.argv) > 1 else
                  ["--arch", "qwen3-0.6b-smoke", "--batch", "4",
                   "--requests", "8", "--max-new", "24"]))
