"""Optimizer unit tests: AdamW dynamics, clipping, schedule."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm, schedule)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    step = jnp.int32(0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, _ = adamw_update(cfg, params, grads, opt, step)
        step = step + 1
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, params, grads, opt, jnp.int32(0))
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_floor():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lr0 = float(schedule(cfg, jnp.int32(0)))
    lr_peak = float(schedule(cfg, jnp.int32(10)))
    lr_end = float(schedule(cfg, jnp.int32(100)))
    assert lr0 < lr_peak
    assert abs(lr_peak - 1e-3) / 1e-3 < 0.15
    assert lr_end >= 0.1 * 1e-3 - 1e-9


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
    assert abs(float(global_norm(t)) - np.sqrt(7.0)) < 1e-6


def test_weight_decay_only_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1,
                      total_steps=10, clip_norm=1e9)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    opt = adamw_init(params)
    zero_grads = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    new, _, _ = adamw_update(cfg, params, zero_grads, opt, jnp.int32(0))
    assert float(jnp.max(new["w"])) < 1.0   # decayed
    assert float(jnp.max(jnp.abs(new["b"] - 1.0))) < 1e-6  # not decayed
