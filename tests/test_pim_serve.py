"""PIM batch executor + serving loop behaviour."""
import numpy as np
import pytest

from repro.core.aligner import WFAligner
from repro.core.gotoh import gotoh_score_vec
from repro.core.penalties import DEFAULT
from repro.core.pim import PIMBatchAligner
from repro.data.reads import ReadPairSpec, generate_pairs


def test_pim_matches_direct(rng):
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=37, read_len=60, edit_frac=0.05, seed=1))
    al = WFAligner(backend="ring", edit_frac=0.05)
    ex = PIMBatchAligner(al, chunk_pairs=16)  # forces multi-wave streaming
    scores, stats = ex.run_arrays(P, plen, T, tlen)
    assert stats.n_pairs == 37
    assert stats.bytes_in > 0 and stats.bytes_out >= 37 * 4
    for i in range(37):
        g = gotoh_score_vec(P[i, : plen[i]], T[i, : tlen[i]], DEFAULT)
        if scores[i] >= 0:
            assert scores[i] == g, i
        else:
            # unresolved only if the true cost exceeds the E-derived budget
            assert g > 0


def test_pim_pads_to_worker_multiple():
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=5, read_len=30, edit_frac=0.1, seed=2))
    al = WFAligner(backend="ring")
    ex = PIMBatchAligner(al)
    scores, stats = ex.run_arrays(P, plen, T, tlen)
    assert scores.shape == (5,)
    assert (scores >= 0).all()


def test_pim_stats_throughput_consistency():
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=8, read_len=30, edit_frac=0.1, seed=3))
    al = WFAligner(backend="ring")
    _, stats = PIMBatchAligner(al).run_arrays(P, plen, T, tlen)
    assert stats.t_total >= stats.t_kernel
    assert stats.throughput_kernel() >= stats.throughput_total()


@pytest.mark.slow
def test_serve_batchserver_generates():
    import jax
    from repro.configs import smoke_config
    from repro.launch.serve import BatchServer
    from repro.models import get_model_fns

    cfg = smoke_config("qwen3-0.6b").replace(n_layers=2)
    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    server = BatchServer(cfg, state["params"], batch=2, max_seq=64)
    prompts = [np.arange(5, dtype=np.int32), np.arange(3, dtype=np.int32)]
    outs = server.generate(prompts, max_new=6)
    assert len(outs) == 2
    assert len(outs[0]) == 5 + 6 and len(outs[1]) == 3 + 6
