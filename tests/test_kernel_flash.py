"""Flash-attention Pallas kernel vs jnp oracle: shape/dtype/blocking sweeps
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, ref_attention_gqa


def _qkv(B, Sq, Sk, H, KV, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = (jax.random.normal(ks[0], (B, Sq, H, dh), jnp.float32) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Sk, KV, dh), jnp.float32) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Sk, KV, dh), jnp.float32) * 0.5).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("H,KV", [(8, 8), (8, 2), (16, 1)],
                         ids=["mha", "gqa", "mqa"])
@pytest.mark.parametrize("S", [128, 256, 250])
def test_flash_matches_ref_fp32(H, KV, S):
    q, k, v = _qkv(2, S, S, H, KV, 64, jnp.float32)
    got = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = ref_attention_gqa(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


def test_flash_bf16_tolerance():
    q, k, v = _qkv(1, 256, 256, 4, 2, 128, jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = ref_attention_gqa(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


def test_flash_block_size_invariance():
    q, k, v = _qkv(1, 512, 512, 4, 2, 64, jnp.float32, seed=3)
    a = flash_attention(q, k, v, block_q=128, block_k=128)
    b = flash_attention(q, k, v, block_q=256, block_k=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_flash_causality():
    """Changing a future key must not change past outputs."""
    q, k, v = _qkv(1, 256, 256, 4, 2, 64, jnp.float32, seed=4)
    base = flash_attention(q, k, v, block_q=128, block_k=128)
    k2 = k.at[:, 200].add(7.0)
    v2 = v.at[:, 200].add(7.0)
    pert = flash_attention(q, k2, v2, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(base[:, :200]),
                               np.asarray(pert[:, :200]), atol=3e-5)
    assert not np.allclose(np.asarray(base[:, 201:]),
                           np.asarray(pert[:, 201:]))


def test_flash_long_context_streaming():
    """KV much longer than one block: online softmax must stay exact."""
    q, k, v = _qkv(1, 128, 1024, 4, 4, 64, jnp.float32, seed=5)
    # decode-like: causal with query block at the END of the kv range is not
    # expressible without offsets; test the non-causal full-window variant
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128)
    ref = ref_attention_gqa(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)
