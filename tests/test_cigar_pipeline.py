"""Packed-backtrace CIGAR pipeline: every cigar-capable backend must emit
alignments that re-score to the Gotoh oracle cost — through blocking
``align(output="cigar")`` and streamed ``as_completed()``, across random
length-skewed pairs, empty-sequence edges, and exact-bound recovery —
plus the TracebackError hardening and the CIGAR formatting helpers."""
import numpy as np
import pytest
from conftest import gotoh_oracle as _oracle
from conftest import random_pairs as _random_pairs

from repro.core import cigar as cigar_mod
from repro.core.backends import cigar_backends, get_backend
from repro.core.cigar import (OP_D, OP_I, OP_M, OP_X, TracebackError,
                              cigar_identity, cigar_string, trace_nbytes,
                              traceback_packed_one, unpack_codes)
from repro.core.engine import AlignmentEngine, pack_batch, problem_bounds
from repro.core.gotoh import score_cigar
from repro.core.penalties import DEFAULT, Penalties

BACKENDS = ["ref", "ring", "kernel"]


def _skewed_pairs(rng, n):
    """Length-skewed mix: short/long pairs plus unrelated (overflow bait)."""
    pats, txts = _random_pairs(rng, n, lo=3, hi=60)
    p2, t2 = _random_pairs(rng, n // 2, lo=80, hi=150)
    pats += p2
    txts += t2
    pats += ["A" * 40, "GATTACA" * 5]       # divergent: exact-bound recovery
    txts += ["T" * 40, "CTAATGT" * 5]
    return pats, txts


def _assert_cigars_rescore(res, pats, txts, pen):
    assert res.cigars is not None and len(res.cigars) == len(pats)
    oracle = _oracle(pats, txts, pen)
    np.testing.assert_array_equal(res.scores, oracle)
    for i, (p, t) in enumerate(zip(pats, txts)):
        pa = np.frombuffer(p.encode(), np.uint8)
        ta = np.frombuffer(t.encode(), np.uint8)
        cost, ci, cj, ok = score_cigar(res.cigars[i], pa, ta, pen)
        assert ok, (i, p, t)
        assert cost == oracle[i], (i, cost, oracle[i])
        assert ci == len(p) and cj == len(t), (i, ci, cj)


# ------------------------------------------------ backend parity suite ----


@pytest.mark.parametrize("backend", ["ref", "ring"])
def test_align_cigar_rescoring_to_oracle(rng, backend):
    pats, txts = _skewed_pairs(rng, 10)
    eng = AlignmentEngine(backend=backend, edit_frac=0.05)
    res = eng.align(pats, txts, output="cigar")
    assert res.stats.n_recovered >= 2        # recovery pairs traced too
    _assert_cigars_rescore(res, pats, txts, DEFAULT)


def test_kernel_cigar_rescoring_to_oracle(rng):
    # one bucket shape: pallas interpret-mode compiles are the cost here,
    # not the alignment itself — the code path is identical per shape
    pats, txts = _random_pairs(rng, 8, lo=8, hi=56)
    pats += ["A" * 30]                       # divergent: exact-bound recovery
    txts += ["T" * 30]
    eng = AlignmentEngine(backend="kernel", edit_frac=0.05,
                          bucket_by_length=False)
    res = eng.align(pats, txts, output="cigar")
    assert res.stats.n_recovered >= 1
    _assert_cigars_rescore(res, pats, txts, DEFAULT)


def test_streamed_cigar_out_of_order(rng):
    pats, txts = _skewed_pairs(rng, 8)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=16)
    chunks = [(pats[i::2], txts[i::2]) for i in range(2)]
    with eng.stream(max_inflight_waves=2) as sess:
        tickets = {sess.submit(p, t, output="cigar").index: (p, t)
                   for p, t in chunks}
        for tk in sess.as_completed():
            p, t = tickets[tk.index]
            _assert_cigars_rescore(tk.result(), p, t, DEFAULT)


def test_mixed_output_tickets_share_session(rng):
    pats, txts = _random_pairs(rng, 10, lo=10, hi=80)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    with eng.stream() as sess:
        traced = sess.submit(pats, txts, output="cigar")
        plain = sess.submit(pats, txts)      # engine default: score
        _assert_cigars_rescore(traced.result(), pats, txts, DEFAULT)
        assert plain.result().cigars is None
    np.testing.assert_array_equal(traced.result().scores,
                                  plain.result().scores)


@pytest.mark.parametrize("backend", ["ref", "ring"])
def test_nondefault_penalties_cigar(rng, backend):
    pen = Penalties(x=3, o=4, e=1)
    pats, txts = _random_pairs(rng, 10, lo=4, hi=100)
    eng = AlignmentEngine(pen, backend=backend, edit_frac=0.1)
    res = eng.align(pats, txts, output="cigar")
    _assert_cigars_rescore(res, pats, txts, pen)


def test_shardmap_backend_cigar(rng):
    import jax
    from repro.distributed.compat import make_mesh
    mesh = make_mesh((jax.device_count(),), ("pairs",))
    pats, txts = _random_pairs(rng, 8, lo=10, hi=60)
    eng = AlignmentEngine(backend="shardmap", edit_frac=0.1, mesh=mesh)
    res = eng.align(pats, txts, output="cigar")
    _assert_cigars_rescore(res, pats, txts, DEFAULT)


def test_cigar_backends_listed():
    for name in BACKENDS + ["shardmap"]:
        assert name in cigar_backends()


# ------------------------------------------------ empty-sequence edges ----


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_sequence_cigars(backend):
    pats = ["", "ACGT", "", "A"]
    txts = ["ACGT", "", "", "A"]
    eng = AlignmentEngine(backend=backend, edit_frac=0.05)
    res = eng.align(pats, txts, output="cigar")
    _assert_cigars_rescore(res, pats, txts, DEFAULT)
    assert list(res.cigars[0]) == [OP_I] * 4      # plen == 0: all-insert
    assert list(res.cigars[1]) == [OP_D] * 4      # tlen == 0: all-delete
    assert len(res.cigars[2]) == 0                # both empty
    assert res.cigar_strings()[2] == ""
    np.testing.assert_allclose(res.cigar_identities(), [0, 0, 1, 1])


# ------------------------------------------------ traceback hardening ----


def test_traceback_error_carries_coordinates():
    # corrupted provenance words must raise TracebackError (never a bare
    # assert, which python -O strips), pinpointing the failing cell
    NW, K = 4, 9
    garbage = np.zeros((NW, K), np.int32)        # all codes invalid
    with pytest.raises(TracebackError) as ei:
        traceback_packed_one(garbage, garbage, garbage, DEFAULT, score=8,
                             pattern=np.zeros(4, np.int32),
                             text=np.zeros(4, np.int32), plen=4, tlen=4,
                             pair=7)
    err = ei.value
    assert err.pair == 7 and err.s == 8 and err.k == 0
    assert "pair=7" in str(err)
    assert isinstance(err, RuntimeError)          # legacy except-clause compat


def test_traceback_error_on_corrupt_full_history():
    from repro.core.cigar import traceback_one
    from repro.core.wavefront import NEG
    hist = np.full((6, 9), NEG, np.int64)
    with pytest.raises(TracebackError, match="pair=3"):
        traceback_one(hist, hist, hist, DEFAULT, score=5, plen=3, tlen=3,
                      k_max=4, pair=3)


def test_negative_score_yields_empty_ops():
    out = traceback_packed_one(np.zeros((1, 3), np.int32),
                               np.zeros((1, 3), np.int32),
                               np.zeros((1, 3), np.int32), DEFAULT,
                               score=-1, pattern=np.zeros(2, np.int32),
                               text=np.zeros(2, np.int32), plen=2, tlen=2)
    assert out.size == 0


# ------------------------------------------------ packed encoding ----


def test_unpack_codes_roundtrip(rng):
    from repro.core.wavefront import wfa_scores_packed
    pats, txts = _random_pairs(rng, 6, lo=10, hi=50)
    P, plen = pack_batch(pats)
    T, tlen = pack_batch(txts)
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, None)
    res = wfa_scores_packed(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                            k_max=k_max)
    codes = unpack_codes(np.asarray(res.m_bt), s_max)
    assert codes.shape == (s_max + 1, len(pats), 2 * k_max + 1)
    assert codes.max() <= 3
    # s = 0 is the origin row: no provenance is ever written there
    assert (codes[0] == 0).all()


def test_packed_trace_memory_at_least_8x_smaller(rng):
    pats, txts = _random_pairs(rng, 16, lo=60, hi=100)
    P, plen = pack_batch(pats)
    T, tlen = pack_batch(txts)
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, 0.05)
    kw = dict(pen=DEFAULT, s_max=s_max, k_max=k_max)
    full = get_backend("ref").variant("cigar")(P, T, plen, tlen, **kw)
    packed = get_backend("ring").variant("cigar")(P, T, plen, tlen, **kw)
    assert trace_nbytes(full) >= 8 * trace_nbytes(packed)


# ------------------------------------------------ formatting helpers ----


def test_cigar_string_modes():
    ops = np.asarray([OP_M, OP_M, OP_X, OP_M, OP_I, OP_I, OP_D, -1],
                     np.int8)
    assert cigar_string(ops) == "2=1X1=2I1D"               # SAM 1.4
    assert cigar_string(ops, "extended") == "2=1X1=2I1D"
    assert cigar_string(ops, "classic") == "4M2I1D"        # =/X fold into M
    with pytest.raises(ValueError, match="mode"):
        cigar_string(ops, "nope")


def test_cigar_identity():
    assert cigar_identity(np.asarray([OP_M] * 9 + [OP_X])) == 0.9
    assert cigar_identity(np.asarray([OP_M, OP_I, OP_D, OP_M])) == 0.5
    assert cigar_identity(np.empty(0, np.int8)) == 1.0
    assert cigar_identity(np.asarray([-1, -1])) == 1.0


def test_unresolved_pairs_identity_is_nan():
    # pinned s_max, no recovery: the divergent pair stays -1 and must not
    # report a perfect identity
    eng = AlignmentEngine(backend="ring", s_max=3)
    res = eng.align(["AAAA", "ACGT"], ["TTTT", "ACGT"], output="cigar")
    assert res.scores[0] == -1 and res.scores[1] == 0
    ident = res.cigar_identities()
    assert np.isnan(ident[0]) and ident[1] == 1.0


def test_legacy_shim_cigar_strings_frozen(rng):
    # the deprecated WFAligner API always emitted 'M'(match)/'X'(mismatch);
    # the new extended/classic modes must not leak into it
    import warnings
    from repro.core.aligner import WFAligner
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        al = WFAligner(backend="ref", with_cigar=True)
    res = al.align(["ACGTACGT", "AAAA"], ["ACGAACGT", "AAGA"])
    assert res.cigar_strings() == ["3M1X4M", "2M1X1M"]


def test_legacy_supports_cigar_plugin_kwarg():
    # pre-output-mode plug-ins declared supports_cigar=True on a full-
    # history fn; that fn must double as the trace variant
    from repro.core.backends import register_backend, unregister_backend
    from repro.core.wavefront import wfa_forward

    @register_backend("legacy-full", supports_cigar=True)
    def _full(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return wfa_forward(pattern, text, plen, tlen, pen=pen, s_max=s_max,
                           k_max=k_max, keep_history=True)

    try:
        eng = AlignmentEngine(backend="legacy-full", edit_frac=0.1)
        res = eng.align(["ACGT"], ["AGGT"], output="cigar")
        assert res.scores[0] == DEFAULT.x
        _assert_cigars_rescore(res, ["ACGT"], ["AGGT"], DEFAULT)
    finally:
        unregister_backend("legacy-full")


def test_score_only_result_refuses_trace():
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    res = eng.align(["ACGT"], ["ACGT"])
    with pytest.raises(ValueError, match="output='cigar'"):
        res.cigar_strings()
    with pytest.raises(ValueError, match="trace"):
        cigar_mod.traceback_result(
            type("R", (), {"m_hist": None, "m_bt": None})(), DEFAULT,
            pattern=None, text=None, plen=None, tlen=None, k_max=1)
