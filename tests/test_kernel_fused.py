"""Fused-grid kernel features: heuristics under trace, compacting band,
gather modes, the in-grid BiWFA meet, and engine ``backend_opts`` plumbing.

Everything here is exact-equality: scores are integers and the compacting
band / gather / blocking knobs are all contracted to be bit-identical to
the full-width reference whenever the live span fits the band.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cigar as cigar_mod
from repro.core import wavefront as wf
from repro.core.engine import AlignmentEngine, problem_bounds
from repro.core.penalties import DEFAULT, Penalties
from repro.core.scoring import AdaptiveBand, Edit, ZDrop, as_model
from repro.data.reads import ReadPairSpec, generate_pairs
from repro.kernels.wfa import ops as kops
from repro.kernels.wfa import ref_scores

HEURS = [AdaptiveBand(min_wf_len=4, max_distance_diff=10), ZDrop(zdrop=12)]
MODELS = [DEFAULT, Edit()]           # affine + linear recurrences
_hid = lambda h: type(h).__name__
_mid = lambda m: as_model(m).kind


def _pairs(n, L, E, seed):
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=n, read_len=L, edit_frac=E, seed=seed))
    # exact worst-case bounds: s_max large enough that the heuristic (not
    # the score budget) is what limits the wavefront
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, None)
    return P, plen, T, tlen, s_max, k_max


def _jnp_cigars(P, T, plen, tlen, pen, s_max, k_max, heur=None,
                band_cap=None):
    res = wf.wfa_scores_packed(jnp.asarray(P), jnp.asarray(T),
                               jnp.asarray(plen), jnp.asarray(tlen),
                               pen=pen, s_max=s_max, k_max=k_max, heur=heur,
                               band_cap=band_cap)
    return np.asarray(res.score), cigar_mod.traceback_packed_batch(
        res, pen, P, T, plen, tlen)


def _kernel_cigars(P, T, plen, tlen, pen, s_max, k_max, heur=None, **kw):
    score, m_bt, i_bt, d_bt = kops.wfa_align_trace(
        P, T, plen, tlen, pen=pen, s_max=s_max, k_max=k_max, heur=heur,
        **kw)
    res = wf.WFAResult(score, None, None, None, jnp.int32(s_max),
                       m_bt, i_bt, d_bt)
    return np.asarray(score), cigar_mod.traceback_packed_batch(
        res, pen, P, T, plen, tlen)


# -- heuristics through the kernel trace path -------------------------------


@pytest.mark.parametrize("pen", MODELS, ids=_mid)
@pytest.mark.parametrize("heur", HEURS, ids=_hid)
def test_kernel_heuristic_trace_parity(heur, pen):
    """AdaptiveBand/ZDrop x linear/affine, trace=True: the kernel's pruned
    scores AND CIGARs must match the jnp solver's exactly."""
    P, plen, T, tlen, s_max, k_max = _pairs(12, 72, 0.08, 21)
    ref_s, ref_c = _jnp_cigars(P, T, plen, tlen, pen, s_max, k_max, heur)
    got_s, got_c = _kernel_cigars(P, T, plen, tlen, pen, s_max, k_max, heur)
    np.testing.assert_array_equal(ref_s, got_s)
    for i, (a, b) in enumerate(zip(ref_c, got_c)):
        np.testing.assert_array_equal(a, b, err_msg=f"pair {i}")


@pytest.mark.parametrize("pen", MODELS, ids=_mid)
@pytest.mark.parametrize("heur", HEURS, ids=_hid)
def test_kernel_heuristic_scores_vs_ref(heur, pen):
    P, plen, T, tlen, s_max, k_max = _pairs(16, 64, 0.10, 22)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=pen, s_max=s_max,
                                k_max=k_max, heur=heur))
    got = np.asarray(kops.wfa_align(P, T, plen, tlen, pen=pen, s_max=s_max,
                                    k_max=k_max, heur=heur))
    np.testing.assert_array_equal(ref, got)


# -- compacting band: bit-identical when the live span fits -----------------


@pytest.mark.parametrize("pen", MODELS, ids=_mid)
@pytest.mark.parametrize("heur", HEURS, ids=_hid)
def test_band_compaction_jnp_identical(heur, pen):
    """Full-width vs compacting-band jnp solve: same scores, same CIGARs.
    The heuristic's own band_cap bounds its live span, so compaction is a
    pure re-indexing (per-pair offset), not an approximation."""
    P, plen, T, tlen, s_max, k_max = _pairs(12, 72, 0.08, 23)
    cap = heur.band_cap(2 * k_max + 1)
    assert cap is not None and cap < 2 * k_max + 1
    full_s, full_c = _jnp_cigars(P, T, plen, tlen, pen, s_max, k_max, heur)
    band_s, band_c = _jnp_cigars(P, T, plen, tlen, pen, s_max, k_max, heur,
                                 band_cap=cap)
    np.testing.assert_array_equal(full_s, band_s)
    for i, (a, b) in enumerate(zip(full_c, band_c)):
        np.testing.assert_array_equal(a, b, err_msg=f"pair {i}")


@pytest.mark.parametrize("heur", HEURS, ids=_hid)
def test_band_compaction_kernel_identical(heur):
    P, plen, T, tlen, s_max, k_max = _pairs(12, 72, 0.08, 24)
    cap = heur.band_cap(2 * k_max + 1)
    full_s, full_c = _kernel_cigars(P, T, plen, tlen, DEFAULT, s_max, k_max,
                                    heur)
    band_s, band_c = _kernel_cigars(P, T, plen, tlen, DEFAULT, s_max, k_max,
                                    heur, band_cap=cap)
    np.testing.assert_array_equal(full_s, band_s)
    for i, (a, b) in enumerate(zip(full_c, band_c)):
        np.testing.assert_array_equal(a, b, err_msg=f"pair {i}")


def test_band_scores_offset_correctness():
    """Score-only band path on ragged lengths: the per-pair offset must
    track fronts centered far from k=0 (tlen != plen)."""
    rng = np.random.default_rng(9)
    n = 10
    plen = rng.integers(20, 90, size=n).astype(np.int32)
    tlen = np.clip(plen + rng.integers(-15, 16, size=n), 4,
                   None).astype(np.int32)
    P = rng.integers(65, 69, size=(n, int(plen.max()))).astype(np.int32)
    T = rng.integers(65, 69, size=(n, int(tlen.max()))).astype(np.int32)
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, None)
    heur = ZDrop(zdrop=40)
    cap = heur.band_cap(2 * k_max + 1)
    full = np.asarray(wf.wfa_scores(P, T, plen, tlen, pen=DEFAULT,
                                    s_max=s_max, k_max=k_max,
                                    heur=heur).score)
    band = np.asarray(wf.wfa_scores(P, T, plen, tlen, pen=DEFAULT,
                                    s_max=s_max, k_max=k_max, heur=heur,
                                    band_cap=cap).score)
    np.testing.assert_array_equal(full, band)


# -- gather / blocking invariance -------------------------------------------


@pytest.mark.parametrize("pen", [DEFAULT, Penalties(1, 0, 1)],
                         ids=["affine", "linear"])
def test_gather_mode_invariance(pen):
    """'index' and 'onehot' char fetches are the same function."""
    P, plen, T, tlen, s_max, k_max = _pairs(8, 32, 0.06, 25)
    idx = np.asarray(kops.wfa_align(P, T, plen, tlen, pen=pen, s_max=s_max,
                                    k_max=k_max, gather="index"))
    oh = np.asarray(kops.wfa_align(P, T, plen, tlen, pen=pen, s_max=s_max,
                                   k_max=k_max, gather="onehot"))
    np.testing.assert_array_equal(idx, oh)


def test_ext_stride_invariance():
    P, plen, T, tlen, s_max, k_max = _pairs(8, 48, 0.06, 26)
    one = np.asarray(kops.wfa_align(P, T, plen, tlen, pen=DEFAULT,
                                    s_max=s_max, k_max=k_max, ext_stride=1))
    four = np.asarray(kops.wfa_align(P, T, plen, tlen, pen=DEFAULT,
                                     s_max=s_max, k_max=k_max, ext_stride=4))
    np.testing.assert_array_equal(one, four)


# -- device-resident BiWFA meet ---------------------------------------------


@pytest.mark.parametrize("pen,states",
                         [(DEFAULT, ("M", "M")), (DEFAULT, ("I", "D")),
                          (Edit(), ("M", "M"))],
                         ids=["affine-MM", "affine-ID", "linear-MM"])
def test_meet_kernel_parity(pen, states):
    """The fused meet kernel returns the jnp solver's result field for
    field — same breakpoint, same safety flag, same unmet handling.
    (I/D boundary states exist only under gap-affine models.)"""
    begin, end = states
    P, plen, T, tlen, s_max, k_max = _pairs(10, 56, 0.08, 27)
    starget = wf.wfa_scores_packed(jnp.asarray(P), jnp.asarray(T),
                                   jnp.asarray(plen), jnp.asarray(tlen),
                                   pen=pen, s_max=s_max, k_max=k_max,
                                   begin_state=begin, end_state=end).score
    ref = wf.wfa_bidir_meet(P, T, plen, tlen, starget, pen=pen, s_max=s_max,
                            k_max=k_max, begin_state=begin, end_state=end)
    got = kops.wfa_bidir_meet_kernel(P, T, plen, tlen, starget, pen=pen,
                                     s_max=s_max, k_max=k_max,
                                     begin_state=begin, end_state=end)
    for field in ("score", "meet_state", "meet_a", "meet_b", "meet_k",
                  "meet_h", "meet_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)), np.asarray(getattr(got, field)),
            err_msg=field)


def test_meet_kernel_block_invariance():
    P, plen, T, tlen, s_max, k_max = _pairs(10, 48, 0.08, 28)
    starget = wf.wfa_scores(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                            k_max=k_max).score
    a = kops.wfa_bidir_meet_kernel(P, T, plen, tlen, starget, pen=DEFAULT,
                                   s_max=s_max, k_max=k_max, block_pairs=4)
    b = kops.wfa_bidir_meet_kernel(P, T, plen, tlen, starget, pen=DEFAULT,
                                   s_max=s_max, k_max=k_max, block_pairs=16)
    for field in ("score", "meet_state", "meet_a", "meet_b", "meet_k",
                  "meet_h", "meet_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            err_msg=field)


# -- engine backend_opts plumbing -------------------------------------------


def _strs(P, lens):
    return ["".join(chr(c) for c in row[:n]) for row, n in zip(P, lens)]


@pytest.fixture(scope="module")
def seqs():
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=12, read_len=64, edit_frac=0.06, seed=29))
    return _strs(P, plen), _strs(T, tlen)


def test_engine_rejects_unknown_backend_opt():
    with pytest.raises(ValueError, match="bogus"):
        AlignmentEngine(backend="ring", backend_opts={"bogus": 1})
    with pytest.raises(ValueError, match="block_pairs"):
        # kernel-only knob on the ring backend: rejected at construction
        AlignmentEngine(backend="ring", backend_opts={"block_pairs": 4})


def test_engine_block_pairs_parity(seqs):
    pats, txts = seqs
    base = AlignmentEngine(backend="kernel").align(pats, txts,
                                                   output="cigar")
    bp = AlignmentEngine(backend="kernel",
                         backend_opts={"block_pairs": 4}).align(
        pats, txts, output="cigar")
    np.testing.assert_array_equal(base.scores, bp.scores)
    for a, b in zip(base.cigars, bp.cigars):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("backend", ["ring", "kernel"])
def test_engine_band_cap_auto(seqs, backend):
    """band_cap='auto' resolves through the heuristic's radius and stays
    score-identical to the full-width heuristic run (related pairs: the
    live span fits the band)."""
    pats, txts = seqs
    heur = AdaptiveBand()
    full = AlignmentEngine(backend=backend, heuristic=heur).align(pats, txts)
    band = AlignmentEngine(backend=backend, heuristic=heur,
                           backend_opts={"band_cap": "auto"}).align(
        pats, txts)
    assert band.approximate
    np.testing.assert_array_equal(full.scores, band.scores)


def test_engine_band_cap_auto_exact_is_noop(seqs):
    """Exact alignment has no pruning radius: 'auto' must stay full width
    (and in particular must not raise or change scores)."""
    pats, txts = seqs
    plain = AlignmentEngine(backend="ring").align(pats, txts)
    auto = AlignmentEngine(backend="ring",
                           backend_opts={"band_cap": "auto"}).align(
        pats, txts)
    np.testing.assert_array_equal(plain.scores, auto.scores)


def test_engine_kernel_bidir_meet_variant(seqs):
    """trace_variant='bidir' on the kernel backend routes meet waves
    through the fused meet kernel and still yields packed-identical
    CIGARs."""
    pats, txts = seqs
    packed = AlignmentEngine(backend="kernel").align(pats, txts,
                                                     output="cigar")
    bidir = AlignmentEngine(backend="kernel").align(
        pats, txts, output="cigar", trace_variant="bidir")
    np.testing.assert_array_equal(packed.scores, bidir.scores)
    for a, b in zip(packed.cigars, bidir.cigars):
        np.testing.assert_array_equal(a, b)
