"""Read-mapping subsystem: index invariants, chaining oracle, end-to-end
recall on ground truth, SAM round-trip, out-of-order ticket retirement."""
import io

import numpy as np
import pytest

from repro.core.gotoh import score_cigar
from repro.data.dna import (NCODE, as_ascii, decode_2bit, encode_2bit,
                            random_reference, revcomp)
from repro.data.reads import sample_from_reference
from repro.mapping.chain import chain_anchors, read_anchors
from repro.mapping.extend import ReadMapper
from repro.mapping.index import MinimizerIndex, extract_minimizers
from repro.mapping.sam import write_sam

K, W = 15, 10


# ---------------------------------------------------------------------------
# DNA helpers vs string oracles.


_COMP = {"A": "T", "C": "G", "G": "C", "T": "A"}


def _revcomp_oracle(s: str) -> str:
    return "".join(_COMP.get(c.upper(), "N") for c in reversed(s))


class TestDNA:
    def test_revcomp_string_oracle(self, rng):
        for _ in range(20):
            s = "".join(rng.choice(list("ACGTN"), size=int(rng.integers(1, 60))))
            assert revcomp(s) == _revcomp_oracle(s)

    def test_revcomp_types_and_involution(self):
        s = "ACGTTGCA"
        arr = as_ascii(s)
        assert isinstance(revcomp(s), str)
        assert revcomp(revcomp(s)) == s
        out = revcomp(arr)
        assert isinstance(out, np.ndarray)
        assert out.tobytes().decode() == _revcomp_oracle(s)

    def test_2bit_roundtrip(self, rng):
        for _ in range(10):
            s = "".join(rng.choice(list("ACGTN"), size=30))
            assert decode_2bit(encode_2bit(s)) == s

    def test_2bit_lowercase_and_iupac(self):
        codes = encode_2bit("acgtRYN")
        assert list(codes[:4]) == [0, 1, 2, 3]
        assert all(c == NCODE for c in codes[4:])

    def test_n_never_seeds(self):
        # a sentinel inside any k-mer window suppresses that minimizer
        seq = "ACGTAGCTTGCAGT" * 8
        seq = seq[:40] + "N" + seq[41:]
        _, pos, _ = extract_minimizers(seq, K, W)
        assert all(not (p <= 40 < p + K) for p in pos)


# ---------------------------------------------------------------------------
# Minimizer-index invariants.


@pytest.fixture(scope="module")
def ref():
    return random_reference(20000, seed=1)


@pytest.fixture(scope="module")
def index(ref):
    return MinimizerIndex.build([ref], ["chr1"], k=K, w=W, occ_cap=64)


class TestIndex:
    def test_every_seed_retrievable(self, ref, index):
        # each reference minimizer below the cap is stored at its position
        seeds, pos, strand = extract_minimizers(ref, K, W)
        start, count = index.lookup(seeds)
        assert (count > 0).all()          # random 20kb: nothing capped
        for i in range(0, len(seeds), 97):
            occ = slice(int(start[i]), int(start[i]) + int(count[i]))
            assert int(pos[i]) in index.occ_pos[occ]

    def test_occurrence_cap_drops_repeats(self):
        motif = random_reference(200, seed=7)
        ref = np.concatenate([motif] * 12)       # every seed occurs ~12x
        idx = MinimizerIndex.build([ref], occ_cap=4)
        seeds, _, _ = extract_minimizers(motif, K, W)
        _, count = idx.lookup(seeds)
        assert (count == 0).all()                # capped wholesale
        assert idx.n_seeds_capped > 0
        # capped occurrences are reclaimed, not kept as unreachable rows
        assert idx.n_occurrences == int(idx.table_count.sum())

    def test_strand_canonicalization(self, ref, index):
        # a reverse-complemented substring anchors to the same locus with
        # the strand bit set and a consistent diagonal
        sub = ref[3000:3120]
        rid, rpos, qpos, strand = read_anchors(index, revcomp(sub))
        assert len(rpos) > 0
        assert (strand == 1).all()
        assert (rpos - qpos == 3000).all()
        # and the forward substring anchors on strand 0 at the same diag
        rid, rpos, qpos, strand = read_anchors(index, sub)
        assert (strand == 0).all()
        assert (rpos - qpos == 3000).all()

    def test_pickle_roundtrip(self, ref, index, tmp_path):
        path = str(tmp_path / "idx.pkl")
        index.save(path)
        loaded = MinimizerIndex.load(path)
        seeds, _, _ = extract_minimizers(ref[:2000], K, W)
        s0, c0 = index.lookup(seeds)
        s1, c1 = loaded.lookup(seeds)
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(c0, c1)
        assert loaded.names == ["chr1"]

    def test_short_and_empty_sequences(self):
        idx = MinimizerIndex.build(["ACGT", ""], ["a", "b"])
        assert idx.n_occurrences == 0            # too short for any k-mer
        assert read_anchors(idx, "ACGTACGT")[0].size == 0


# ---------------------------------------------------------------------------
# Chaining oracle on hand-built anchor sets.


class TestChain:
    def test_perfect_diagonal_single_chain(self):
        n = 8
        rpos = 500 + 20 * np.arange(n)
        qpos = 10 + 20 * np.arange(n)
        chains = chain_anchors(np.zeros(n), rpos, qpos, np.zeros(n), K)
        assert len(chains) == 1
        c = chains[0]
        assert c.n_anchors == n
        assert (c.rstart, c.qstart) == (500, 10)
        assert (c.rend, c.qend) == (int(rpos[-1]) + K, int(qpos[-1]) + K)
        assert c.diag == 490

    def test_off_diagonal_noise_excluded(self):
        rpos = np.array([100, 120, 140, 5000])
        qpos = np.array([0, 20, 40, 60])
        chains = chain_anchors(np.zeros(4), rpos, qpos, np.zeros(4), K)
        best = chains[0]
        assert best.n_anchors == 3               # the 5000 jump never chains
        assert best.rend == 140 + K

    def test_two_loci_ranked_by_score(self):
        # locus A: 5 colinear anchors; locus B: 2 — A must rank first
        rpos = np.array([100, 120, 140, 160, 180, 9000, 9020])
        qpos = np.array([0, 20, 40, 60, 80, 0, 20])
        chains = chain_anchors(np.zeros(7), rpos, qpos, np.zeros(7), K)
        assert len(chains) == 2
        assert chains[0].n_anchors == 5 and chains[1].n_anchors == 2
        assert chains[0].score > chains[1].score

    def test_branch_stub_does_not_inherit_primary_score(self):
        # 6-anchor primary + one branch anchor off its prefix + a genuine
        # 3-anchor second locus: the branch backtrack truncates at used
        # anchors and must NOT keep the primary's full DP score, or it
        # would outrank the real secondary
        rpos = np.array([100, 120, 140, 160, 180, 200,   # primary
                         165,                            # branch off prefix
                         9000, 9020, 9040])              # second locus
        qpos = np.array([0, 20, 40, 60, 80, 100,
                         62,
                         0, 20, 40])
        chains = chain_anchors(np.zeros(10), rpos, qpos, np.zeros(10), K)
        assert chains[0].n_anchors == 6
        assert len(chains) >= 2
        assert chains[1].rstart == 9000 and chains[1].n_anchors == 3
        # any surviving branch stub ranks below the genuine second locus
        assert all(c.score < chains[1].score for c in chains[2:])

    def test_colinearity_is_strict(self):
        # same qpos twice: the second anchor cannot extend the first
        rpos = np.array([100, 120])
        qpos = np.array([10, 10])
        chains = chain_anchors(np.zeros(2), rpos, qpos, np.zeros(2), K)
        assert all(c.n_anchors == 1 for c in chains)

    def test_groups_never_mix(self):
        # identical geometry on two strands stays two chains
        rpos = np.array([100, 120, 100, 120])
        qpos = np.array([0, 20, 0, 20])
        strand = np.array([0, 0, 1, 1])
        chains = chain_anchors(np.zeros(4), rpos, qpos, strand, K)
        assert sorted(c.strand for c in chains) == [0, 1]
        assert all(c.n_anchors == 2 for c in chains)


# ---------------------------------------------------------------------------
# End-to-end: ground-truth recall, re-scoring, MAPQ, out-of-order tickets.


@pytest.fixture(scope="module")
def mapper(index):
    return ReadMapper(index, top_n=2, edit_frac=0.02, read_len=100)


class TestMapping:
    def test_recall_both_strands(self, ref, index, mapper):
        reads = sample_from_reference(ref, 200, read_len=100,
                                      edit_frac=0.02, seed=3)
        assert {r.strand for r in reads} == {0, 1}
        results = mapper.map([r.read for r in reads])
        hits = sum(
            m[0].mapped and m[0].strand == r.strand
            and abs(m[0].pos - r.pos) <= 6
            for r, m in zip(reads, results))
        assert hits >= 0.95 * len(reads)
        assert mapper.stats.n_reads == len(reads)
        assert mapper.stats.n_mapped >= 0.95 * len(reads)

    def test_cigar_pos_rescore_to_cost(self, ref, mapper):
        reads = sample_from_reference(ref, 40, read_len=100,
                                      edit_frac=0.02, seed=11)
        results = mapper.map([r.read for r in reads])
        pen = mapper.pen.as_penalties()
        for r, maps in zip(reads, results):
            for m in maps:
                if not m.mapped:
                    continue
                txt = r.read if m.strand == 0 else revcomp(r.read)
                window = ref[m.pos: m.pos + m.ref_span()]
                cost, ci, cj, ok = score_cigar(m.ops, window, txt, pen)
                assert ok and cost == m.score
                assert ci == m.ref_span() and cj == len(txt)

    def test_exact_read_maps_exactly(self, ref, mapper):
        maps = mapper.map([ref[4000:4100]])[0]
        m = maps[0]
        assert (m.pos, m.strand, m.score) == (4000, 0, 0)
        assert m.mapq == 60

    def test_duplicate_locus_gets_mapq_zero(self, ref):
        dup = np.concatenate([ref[:8000], ref[2000:2400]])
        idx = MinimizerIndex.build([dup], ["chr"], k=K, w=W)
        mapper = ReadMapper(idx, top_n=2, edit_frac=0.02, read_len=100)
        maps = mapper.map([dup[2100:2200]])[0]
        assert maps[0].mapq == 0                 # ambiguous: two ties
        assert len(maps) == 2 and maps[1].secondary
        assert {m.pos for m in maps} == {2100, 8100}

    def test_unmappable_and_empty_reads(self, mapper):
        results = mapper.map([random_reference(100, seed=99), "ACG"])
        for maps in results:
            assert len(maps) == 1 and not maps[0].mapped

    def test_ticket_meta_rides_the_session(self, mapper):
        payload = [("read", 0, "locus")]
        with mapper.engine.stream() as sess:
            t = sess.submit(["ACGTACGT"], ["ACGTACGT"], meta=payload)
            t.result()
        assert t.meta is payload

    def test_out_of_order_retirement(self, ref, index):
        # read 0: clean 35bp prefix (so it chains) + heavy mutation (so
        # its extension overflows pass 1 into the recovery queue); read 1:
        # clean.  One ticket per read => read 1 must retire first.
        noisy = ref[6000:6100].copy()
        noisy[35::3] = revcomp(noisy[35::3])[::-1]   # complement = sub each
        clean = ref[9000:9100]
        mapper = ReadMapper(index, top_n=1, edit_frac=0.02, read_len=100,
                            batch_reads=1)
        order = [maps[0].read_id for maps in mapper.map_stream([noisy, clean])]
        assert order == [1, 0]
        res = {m[0].read_id: m[0]
               for m in mapper.map([noisy, clean])}
        assert res[1].pos == 9000 and res[1].score == 0
        assert res[0].mapped and res[0].score > 0

    def test_per_submit_scoring_seam(self, index, ref):
        from repro.core.scoring import Edit
        mapper = ReadMapper(index, top_n=1, edit_frac=0.02, read_len=100,
                            penalties=Edit())
        reads = sample_from_reference(ref, 10, read_len=100,
                                      edit_frac=0.02, seed=5)
        results = mapper.map([r.read for r in reads])
        pen = Edit().as_penalties()
        # under edit distance (no gap-open) the global optimum may
        # interleave the forced window end-gaps with matches, so the
        # trimmed cost is only bounded by n_edits + the window padding
        delta = 3                                # ceil(E*L) + extra_pad
        for r, maps in zip(reads, results):
            m = maps[0]
            assert m.mapped and m.score <= r.n_edits + 2 * delta
            txt = r.read if m.strand == 0 else revcomp(r.read)
            cost, _, _, ok = score_cigar(
                m.ops, ref[m.pos: m.pos + m.ref_span()], txt, pen)
            assert ok and cost == m.score


# ---------------------------------------------------------------------------
# SAM round-trip (pysam-free parsing).


def _parse_cigar_ops(cigar: str, seq: str, ref_window: str):
    """Classic-CIGAR string -> core.cigar op codes, deriving =/X for M
    runs by comparing SEQ to the reference window."""
    import re
    ops, i, j = [], 0, 0            # i: ref offset, j: read offset
    for n, op in re.findall(r"(\d+)([MIDX=])", cigar):
        n = int(n)
        if op in "M=X":
            for _ in range(n):
                ops.append(0 if ref_window[i] == seq[j] else 1)
                i, j = i + 1, j + 1
        elif op == "I":
            ops.extend([2] * n)
            j += n
        else:
            ops.extend([3] * n)
            i += n
    return np.asarray(ops, np.int8), i, j


class TestSAM:
    def test_roundtrip_fields(self, ref, index, mapper):
        reads = sample_from_reference(ref, 30, read_len=100,
                                      edit_frac=0.02, seed=21)
        seqs = [r.read for r in reads]
        names = [f"r{i}" for i in range(len(reads))]
        results = mapper.map(seqs)
        buf = io.StringIO()
        n = write_sam(buf, results, seqs, names, index.names, index.lengths)
        lines = buf.getvalue().splitlines()
        header = [ln for ln in lines if ln.startswith("@")]
        records = [ln for ln in lines if not ln.startswith("@")]
        assert n == len(records) >= len(reads)
        assert header[0].startswith("@HD\tVN:")
        assert header[1] == f"@SQ\tSN:chr1\tLN:{len(ref)}"
        assert any(ln.startswith("@PG\t") for ln in header)

        pen = mapper.pen.as_penalties()
        ref_str = ref.tobytes().decode()
        by_name = {}
        for ln in records:
            f = ln.split("\t")
            assert len(f) >= 11
            by_name.setdefault(f[0], []).append(f)
            flag = int(f[1])
            if flag & 0x4:
                continue
            pos = int(f[3]) - 1                  # SAM POS is 1-based
            assert 0 <= pos < len(ref)
            seq, cigar = f[9], f[5]
            ops, ref_span, read_span = _parse_cigar_ops(
                cigar, seq, ref_str[pos:])
            assert read_span == len(seq)
            tags = dict(t.split(":", 1) for t in f[11:])
            as_cost = -int(tags["AS"].split(":")[-1])
            cost, _, _, ok = score_cigar(
                ops, as_ascii(ref_str[pos: pos + ref_span]),
                as_ascii(seq), pen)
            assert ok and cost == as_cost
        assert set(by_name) == set(names)        # every read has a record

    def test_strand_and_secondary_flags(self, ref, index):
        dup = np.concatenate([ref[:8000], ref[2000:2400]])
        idx = MinimizerIndex.build([dup], ["chr"], k=K, w=W)
        mapper = ReadMapper(idx, top_n=2, edit_frac=0.02, read_len=100)
        read = revcomp(dup[2100:2200])           # reverse strand + 2 loci
        buf = io.StringIO()
        write_sam(buf, mapper.map([read]), [read], ["q"], idx.names,
                  idx.lengths)
        recs = [ln.split("\t") for ln in buf.getvalue().splitlines()
                if not ln.startswith("@")]
        assert len(recs) == 2
        flags = sorted(int(r[1]) for r in recs)
        assert flags[0] & 0x10 and not flags[0] & 0x100
        assert flags[1] & 0x10 and flags[1] & 0x100
        # SEQ is on the forward reference strand: revcomp of the read
        fwd = dup[2100:2200].tobytes().decode()
        assert all(r[9] == fwd for r in recs)

    def test_unmapped_record(self, index, mapper):
        read = random_reference(80, seed=123)
        buf = io.StringIO()
        write_sam(buf, mapper.map([read]), [read], ["q"], index.names,
                  index.lengths)
        rec = [ln.split("\t") for ln in buf.getvalue().splitlines()
               if not ln.startswith("@")]
        assert len(rec) == 1
        assert int(rec[0][1]) & 0x4
        assert rec[0][2] == "*" and rec[0][3] == "0" and rec[0][5] == "*"


# ---------------------------------------------------------------------------
# Ground-truth sampler.


class TestSampler:
    def test_deterministic_and_bounded(self, ref):
        a = sample_from_reference(ref, 20, read_len=100, edit_frac=0.04,
                                  seed=2)
        b = sample_from_reference(ref, 20, read_len=100, edit_frac=0.04,
                                  seed=2)
        for ra, rb in zip(a, b):
            assert (ra.pos, ra.strand, ra.n_edits) == (rb.pos, rb.strand,
                                                       rb.n_edits)
            np.testing.assert_array_equal(ra.read, rb.read)
            assert ra.n_edits <= 4
            assert abs(len(ra.read) - 100) <= ra.n_edits

    def test_zero_edit_reads_match_reference(self, ref):
        for r in sample_from_reference(ref, 40, read_len=60,
                                       edit_frac=0.02, seed=6):
            if r.n_edits:
                continue
            window = ref[r.pos: r.pos + 60]
            expect = window if r.strand == 0 else revcomp(window)
            np.testing.assert_array_equal(r.read, expect)


# ---------------------------------------------------------------------------
# Launchers: align --sam-out header regression + map_reads end to end.


def _write_fasta(path, names, seqs):
    with open(path, "w") as f:
        for n, s in zip(names, seqs):
            f.write(f">{n}\n{as_ascii(s).tobytes().decode()}\n")


def _write_fastq(path, names, seqs):
    with open(path, "w") as f:
        for n, s in zip(names, seqs):
            seq = as_ascii(s).tobytes().decode()
            f.write(f"@{n}\n{seq}\n+\n{'I' * len(seq)}\n")


class TestLaunchers:
    def test_align_sam_header_regression(self, tmp_path):
        from repro.launch import align
        out = str(tmp_path / "out.sam")
        rc = align.main(["--pairs", "6", "--read-len", "40", "--mode",
                         "sync", "--output", "sam", "--sam-out", out,
                         "--chunk-pairs", "8"])
        assert rc == 0
        lines = open(out).read().splitlines()
        header = [ln for ln in lines if ln.startswith("@")]
        records = [ln for ln in lines if not ln.startswith("@")]
        assert header[0].startswith("@HD\tVN:")
        sq = [ln for ln in header if ln.startswith("@SQ\t")]
        assert len(sq) == 6
        assert all("\tLN:" in ln and "SN:ref" in ln for ln in sq)
        assert any(ln.startswith("@PG\t") for ln in header)
        assert len(records) == 6
        for ln in records:
            f = ln.split("\t")
            assert len(f) >= 11 and f[2].startswith("ref")

    @pytest.mark.slow
    def test_map_reads_cli_end_to_end(self, tmp_path, ref):
        from repro.launch import map_reads
        refs = str(tmp_path / "ref.fa")
        reads_p = str(tmp_path / "reads.fq")
        out = str(tmp_path / "out.sam")
        idx_p = str(tmp_path / "idx.pkl")
        _write_fasta(refs, ["chr1"], [ref])
        sampled = sample_from_reference(ref, 60, read_len=100,
                                        edit_frac=0.02, seed=31)
        _write_fastq(reads_p, [f"r{i}" for i in range(len(sampled))],
                     [r.read for r in sampled])
        rc = map_reads.main(["--refs", refs, "--reads", reads_p,
                             "--sam-out", out, "--save-index", idx_p])
        assert rc == 0
        truth = {f"r{i}": s for i, s in enumerate(sampled)}
        lines = open(out).read().splitlines()
        assert lines[0].startswith("@HD\t")
        assert any(ln == f"@SQ\tSN:chr1\tLN:{len(ref)}" for ln in lines)
        hits = total = 0
        for ln in lines:
            if ln.startswith("@"):
                continue
            f = ln.split("\t")
            flag = int(f[1])
            if flag & 0x100:
                continue                         # secondaries don't count
            total += 1
            t = truth[f[0]]
            if (not flag & 0x4 and bool(flag & 0x10) == bool(t.strand)
                    and abs(int(f[3]) - 1 - t.pos) <= 6):
                hits += 1
        assert total == len(sampled)
        assert hits >= 0.95 * total
        # the saved index reloads and serves the same run
        rc = map_reads.main(["--index", idx_p, "--reads", reads_p,
                             "--sam-out", str(tmp_path / "out2.sam")])
        assert rc == 0
        # build-time flags cannot silently apply to a prebuilt index
        with pytest.raises(SystemExit):
            map_reads.main(["--index", idx_p, "--reads", reads_p,
                            "--k", "21", "--sam-out", "-"])
