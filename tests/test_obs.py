"""repro.obs: tracer thread-safety and disabled-mode cost, flow IDs
linking submit -> retire across out-of-order tickets, bounded-memory
histogram accuracy, Prometheus exposition, ServerStats/scrape percentile
parity, EngineStats.merge, and the overhead-gate CI wiring."""
import json
import threading

import numpy as np
import pytest
from conftest import random_pairs as _random_pairs

from repro import obs
from repro.core.engine import AlignmentEngine, BucketInfo, EngineStats
from repro.data.reads import ArrivalSpec, generate_trace
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import ServeLoop, replay_trace


@pytest.fixture
def tracer():
    """Enabled tracer with a clean buffer; always disabled afterwards so
    test order can't leak trace state into other modules."""
    was_on = obs_trace.enabled()
    obs_trace.reset()
    obs_trace.enable()
    yield obs_trace
    (obs_trace.enable if was_on else obs_trace.disable)()
    obs_trace.reset()


# ------------------------------------------------------------ tracer ----


def test_disabled_mode_emits_nothing_and_allocates_nothing():
    obs_trace.disable()
    obs_trace.reset()
    # the disabled span is THE shared singleton: no per-call allocation
    assert obs_trace.span("x") is obs_trace.NULL
    assert obs_trace.span("y", cat="c", args={"k": 1}) is obs_trace.NULL
    with obs_trace.span("z") as sp:
        sp.set(a=1).flow_start(7)
        sp.flow_step(7)
        sp.flow_end(7)
    obs_trace.instant("i", args={"k": 2})
    obs_trace.counter("c", 3)
    assert obs_trace.events() == []


def test_concurrent_spans_produce_valid_ordered_trace(tracer, tmp_path):
    """>= 8 threads emitting nested spans -> loadable Chrome trace JSON
    with per-thread lanes and consistent, monotone timestamps."""
    n_threads, n_spans = 8, 40
    barrier = threading.Barrier(n_threads)

    def work(k):
        barrier.wait()
        for i in range(n_spans):
            with tracer.span(f"outer{k}", cat="test", args={"i": i}):
                with tracer.span(f"inner{k}", cat="test"):
                    pass
            tracer.instant(f"tick{k}", cat="test")

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    path = tracer.save(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    ev = doc["traceEvents"]
    assert ev[0]["ph"] == "M"                      # process_name metadata
    xs = [e for e in ev if e["ph"] == "X"]
    assert len(xs) == n_threads * n_spans * 2
    assert len([e for e in ev if e["ph"] == "i"]) == n_threads * n_spans
    assert len({e["tid"] for e in xs}) == n_threads
    by_tid = {}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        by_tid.setdefault(e["tid"], []).append(e)
    for lane in by_tid.values():
        # one thread's spans exit sequentially: buffer order == time order
        ends = [e["ts"] + e["dur"] for e in lane]
        assert all(a <= b + 1e-6 for a, b in zip(ends, ends[1:]))


def test_flow_ids_connect_submit_to_retire_out_of_order(tracer, rng):
    """Each ticket's self-allocated flow threads submit -> scatter ->
    kernel -> gather -> done even when waves retire out of order."""
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    chunks = [_random_pairs(rng, 8, lo=5, hi=150) for _ in range(3)]
    with eng.stream(max_inflight_waves=2) as sess:
        tickets = [sess.submit(p, t) for p, t in chunks]
        for tk in tickets:
            tk.result()
    ev = tracer.events()
    names = {e["name"] for e in ev if e["ph"] == "X"}
    for expected in ("session.submit", "wave.scatter", "wave.kernel",
                     "wave.gather", "session.ticket_done"):
        assert expected in names, f"missing span {expected}"
    flows = {}
    for e in ev:
        if e["ph"] in ("s", "t", "f"):
            flows.setdefault(e["id"], []).append(e)
    assert len(flows) == len(tickets)   # one self-allocated flow each
    for fid, chain in flows.items():
        phs = [e["ph"] for e in chain]
        assert phs[0] == "s" and phs[-1] == "f", fid
        assert phs.count("s") == 1 and phs.count("f") == 1
        assert "t" in phs                         # >= 1 wave step between
        ts = [e["ts"] for e in chain]
        assert ts[0] <= min(ts) and ts[-1] >= max(ts) - 1e-6


def test_capture_trace_writes_and_restores(tracer, tmp_path):
    obs_trace.disable()
    path = tmp_path / "cap.json"
    with obs.capture_trace(str(path)):
        assert obs_trace.enabled()
        with obs_trace.span("inside"):
            pass
    assert not obs_trace.enabled()        # switch restored (was off)
    names = {e["name"] for e in json.load(open(path))["traceEvents"]}
    assert "inside" in names
    with obs.capture_trace(None):         # no-op path
        assert not obs_trace.enabled()


# ----------------------------------------------------------- metrics ----


def test_histogram_quantiles_within_one_bucket_of_exact(rng):
    h = obs_metrics.Histogram("lat", "test")
    samples = np.exp(rng.normal(-5.0, 1.5, size=2000))   # ~ms latencies
    for v in samples:
        h.observe(float(v))
    assert h.count == 2000
    assert h.sum == pytest.approx(samples.sum())
    assert h.max == samples.max()
    s = np.sort(samples)
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = s[int(np.ceil(q * len(s))) - 1]
        got = h.quantile(q)
        assert exact <= got <= exact * h.factor, q


def test_histogram_memory_is_bounded():
    h = obs_metrics.Histogram("lat", "test")
    before = h.nbytes()
    for i in range(10_000):
        h.observe(1e-6 * (i + 1))         # spans below-lo .. above cases
    h.observe(1e9)                        # saturates the top bucket
    assert h.nbytes() == before           # the bounded-memory contract
    assert h.n_buckets == len(h.counts())
    assert sum(h.counts()) == h.count == 10_001
    assert h.counts()[-1] == 1            # saturated into the top bucket
    # the saturated sample reports the top edge (clamped by max): no
    # sample is dropped, only its magnitude saturates
    assert h.quantile(1.0) == min(h.bucket_edge(h.n_buckets - 1), h.max)
    assert h.max == 1e9


def test_registry_get_or_create_attach_and_prometheus():
    reg = obs_metrics.Registry()
    c = reg.counter("hits_total", "help text")
    c.inc()
    c.inc(2)
    assert reg.counter("hits_total") is c and c.value == 3
    g = reg.gauge("depth")
    g.set(5)
    g.dec()
    assert g.value == 4
    with pytest.raises(TypeError):
        reg.gauge("hits_total")           # name/type conflicts are loud
    h = reg.histogram("lat_seconds")
    for v in (0.001, 0.002, 0.004, 0.2):
        h.observe(v)
    text = reg.render_prometheus()
    assert "# HELP hits_total help text" in text
    assert "# TYPE hits_total counter" in text
    assert "hits_total 3" in text
    assert "depth 4" in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    assert "lat_seconds_p99" in text
    # attach() replaces: a fresh per-instance histogram wins the name
    h2 = obs_metrics.Histogram("lat_seconds", "newest server")
    reg.attach(h2)
    assert reg.get("lat_seconds") is h2


def test_registry_snapshot_jsonl_roundtrip(tmp_path):
    reg = obs_metrics.Registry()
    reg.counter("a_total").inc(7)
    reg.histogram("h").observe(0.5)
    path = str(tmp_path / "metrics.jsonl")
    reg.write_jsonl(path)
    reg.counter("a_total").inc()
    reg.write_jsonl(path)
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2
    assert lines[0]["metrics"]["a_total"]["value"] == 7
    assert lines[1]["metrics"]["a_total"]["value"] == 8
    assert lines[1]["metrics"]["h"]["count"] == 1
    assert lines[1]["metrics"]["h"]["p50"] == pytest.approx(0.5, rel=0.2)


# ----------------------------------------------- serving integration ----


def test_serverstats_percentiles_match_prometheus_scrape(rng):
    """ServerStats and the /metrics exposition read the SAME histogram:
    identical p50/p99, and the memory stays bounded for a long run."""
    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    payloads, _ = generate_trace(ArrivalSpec(
        n_requests=150, pairs_per_request=1, read_len=30, seed=9))
    with ServeLoop(eng, wave_pairs=64, form_deadline=0.005) as server:
        nbytes0 = server._latency_hist.nbytes()
        replay_trace(server, payloads, np.zeros(150))
        st = server.stats()
    assert st.n_latency_samples == 150
    # bounded memory: 150 (or 150M) samples, same bucket array
    assert server._latency_hist.nbytes() == nbytes0
    scrape = {}
    for line in obs_metrics.render_prometheus().splitlines():
        if line.startswith("serve_request_latency_seconds_p"):
            k, v = line.split()
            scrape[k] = float(v)
    # %g exposition keeps 6 significant digits of the identical value
    assert scrape["serve_request_latency_seconds_p50"] \
        == pytest.approx(st.latency_p50, rel=1e-5)
    assert scrape["serve_request_latency_seconds_p99"] \
        == pytest.approx(st.latency_p99, rel=1e-5)


# -------------------------------------------------- EngineStats.merge ----


def test_engine_stats_merge_sums_and_maxes():
    a = EngineStats(n_pairs=10, n_workers=2, cache_hits=3, t_kernel=1.0,
                    rows_real=10, peak_trace_bytes=100,
                    buckets=[BucketInfo(64, 4, 8, 20)])
    b = EngineStats(n_pairs=5, n_workers=4, cache_hits=2, t_kernel=0.5,
                    rows_real=5, peak_trace_bytes=300,
                    buckets=[BucketInfo(128, 2, 4, 30)])
    out = a.merge(b)
    assert out is a                        # in-place, returns self
    assert a.n_pairs == 15 and a.cache_hits == 5 and a.rows_real == 15
    assert a.t_kernel == pytest.approx(1.5)
    assert a.n_workers == 4 and a.peak_trace_bytes == 300
    assert len(a.buckets) == 2
    # child tickets re-process parent-counted pairs: n_pairs untouched
    c = EngineStats(n_pairs=99, cache_misses=1)
    a.merge(c, count_pairs=False)
    assert a.n_pairs == 15 and a.cache_misses == 1


# ----------------------------------------------------- CI gate wiring ----


def test_obs_overhead_gate_detects_each_regression():
    """check() trips on disabled-path bloat and enabled-mode slowdowns,
    passes a healthy snapshot, and never passes on missing rows."""
    from benchmarks import obs_overhead

    def rows(frac=0.001, ratio=0.99):
        return [("obs/disabled_frac", frac, ""),
                ("obs/on_ratio", ratio, "")]

    assert obs_overhead.check(rows()) == []
    assert len(obs_overhead.check(rows(frac=0.05))) == 1
    assert len(obs_overhead.check(rows(ratio=0.5))) == 1
    assert len(obs_overhead.check(rows(0.5, 0.5))) == 2
    assert len(obs_overhead.check(rows(frac=float("nan")))) == 1
    with pytest.raises(KeyError):
        obs_overhead.check([])
