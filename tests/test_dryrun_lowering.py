"""Lowering/dry-run machinery at test scale: a subprocess forces 16 host
devices and lowers smoke-size cells on a 4x4 mesh, proving the sharding
rules compose before the (expensive) production 512-device campaign.
Also asserts the PIM property: the aligner cell lowers with ZERO collectives.
"""
import json
import os
import subprocess
import sys

import pytest

DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.analysis.hlo import collective_bytes
from repro.configs import smoke_config, wfa_paper
from repro.distributed.compat import cost_analysis
from repro.launch.lowering import build_lm_cell, build_wfa_cell, lower_cell
from repro.launch.mesh import make_mesh
from repro.models.common import ShapeSpec

mesh = make_mesh((4, 4), ("data", "model"))
out = {}

for arch, shape in [("qwen3-0.6b", ShapeSpec("t", 64, 8, "train")),
                    ("deepseek-v2-lite-16b", ShapeSpec("t", 64, 8, "train")),
                    ("mamba2-780m", ShapeSpec("d", 128, 8, "decode")),
                    ("whisper-base", ShapeSpec("p", 64, 8, "prefill"))]:
    cfg = smoke_config(arch)
    cell = build_lm_cell(cfg, shape, mesh, mode="roofline")
    lowered, _ = lower_cell(cell, mesh)
    compiled = lowered.compile()
    cost = cost_analysis(compiled)
    out[f"{arch}:{shape.kind}"] = {
        "flops": float(cost.get("flops", -1)),
        "coll": collective_bytes(compiled.as_text(), 16)["total"],
    }

# EP-MoE numerics on a real multi-device mesh
import jax.numpy as jnp
import numpy as np
from repro.distributed.sharding import split_annotations, use_mesh
from repro.models import moe as MOE
cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
    n_experts=8, top_k=2, capacity_factor=8.0, n_shared_experts=0,
    compute_dtype="float32")
params, _ = split_annotations(MOE.init_moe(cfg, jax.random.key(0)))
xm = jax.random.normal(jax.random.key(1), (4, 32, cfg.d_model), jnp.float32)
with mesh, use_mesh(mesh):
    yb, _ = jax.jit(lambda p, x: MOE.moe_forward(p, x, cfg))(params, xm)
    ye, _ = jax.jit(lambda p, x: MOE.moe_forward(
        p, x, cfg.replace(moe_ep=True)))(params, xm)
out["moe_ep_err"] = float(jnp.max(jnp.abs(yb - ye)))

for variant in ("pjit", "shard_map"):
    cell = build_wfa_cell(wfa_paper, mesh, pairs_per_device=8, variant=variant)
    lowered, _ = lower_cell(cell, mesh)
    compiled = lowered.compile()
    out[f"wfa_{variant}"] = {
        "coll": collective_bytes(compiled.as_text(), 16)["total"]}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_lowering_on_16_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", DRIVER], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])

    # every LM cell compiled and did real work
    for key, rec in out.items():
        if key.startswith("wfa_") or not isinstance(rec, dict):
            continue
        assert rec["flops"] > 0, (key, rec)
    # model-parallel cells must communicate...
    assert out["qwen3-0.6b:train"]["coll"] > 0
    # ...the baseline aligner carries only the tiny lock-step termination
    # all-reduce (DESIGN.md §9.7) ...
    assert 0 < out["wfa_pjit"]["coll"] < 1e5
    # ...and the shard_map variant is collective-FREE (the paper's
    # no-inter-DPU-communication property, restored)
    assert out["wfa_shard_map"]["coll"] == 0.0
    # EP MoE numerics must match the pjit dispatch on a real mesh
    assert out["moe_ep_err"] < 1e-4
