"""repro.obs.analyze + repro.obs.record: phase accounting on a
hand-built synthetic trace (known durations, one deliberate bubble, one
cross-thread flow), critical paths, pipeline occupancy, trace/snapshot
diff attribution, flight-recorder ring/dump semantics (shed + timeout
hooks), nesting-safe capture_trace, and the obs_report CLI."""
import json

import numpy as np
import pytest

from repro import obs
from repro.core.engine import AlignmentEngine
from repro.core.session import AlignmentSession, run_streamed
from repro.data.reads import ReadPairSpec, generate_pairs
from repro.launch import obs_report
from repro.obs import analyze
from repro.obs import record as obs_record
from repro.obs import trace as obs_trace
from repro.serve import ServeLoop


# ------------------------------------------------------ synthetic trace ----
# Two waves with exact durations, one deliberate 20.5ms bubble between
# them, and one cross-thread flow (submit on tid 2 -> kernel/gather on
# tid 1).  All times in microseconds.


def _x(name, ts, dur, tid=1, args=None):
    return {"name": name, "cat": "wave", "ph": "X", "ts": ts, "dur": dur,
            "pid": 1, "tid": tid, "args": args or {}}


def _c(name, ts, value):
    return {"name": name, "cat": "repro", "ph": "C", "ts": ts, "pid": 1,
            "tid": 0, "args": {"value": value}}


def _f(ph, fid, ts, tid):
    ev = {"name": "flow", "cat": "flow", "ph": ph, "id": fid, "ts": ts,
          "pid": 1, "tid": tid}
    if ph == "f":
        ev["bp"] = "e"
    return ev


SYNTHETIC = [
    _x("session.submit", 0, 1_000, tid=2),
    _x("wave.scatter", 0, 10_000, args={"wave": 0}),
    _x("wave.kernel", 10_000, 20_000, args={"wave": 0, "rows": 256}),
    _x("wave.gather", 30_000, 5_000, args={"wave": 0}),
    _x("wave.traceback", 35_000, 2_000, args={"wave": 0}),
    # deliberate bubble: nothing in flight 40_000 .. 60_500
    _x("wave.scatter", 60_000, 4_000, args={"wave": 1}),
    _x("wave.kernel", 64_000, 6_000, args={"wave": 1, "rows": 64}),
    _x("wave.gather", 70_000, 1_000, args={"wave": 1}),
    _c("inflight_waves", 500, 1),
    _c("inflight_waves", 40_000, 0),
    _c("inflight_waves", 60_500, 1),
    _c("inflight_waves", 71_000, 0),
    # one cross-thread flow: submit (tid 2) -> kernel -> gather (tid 1)
    _f("s", 7, 500, tid=2),
    _f("t", 7, 11_000, tid=1),
    _f("f", 7, 30_500, tid=1),
]


@pytest.fixture
def synth():
    return analyze.Trace.from_events(SYNTHETIC)


def test_phase_accounting_exact_totals(synth):
    pt = analyze.phase_accounting(synth)
    assert pt.get("scatter").total_us == pytest.approx(14_000)
    assert pt.get("kernel").total_us == pytest.approx(26_000)
    assert pt.get("kernel").count == 2
    assert pt.get("kernel").mean_us == pytest.approx(13_000)
    assert pt.get("kernel").max_us == pytest.approx(20_000)
    assert pt.get("gather").total_us == pytest.approx(6_000)
    assert pt.get("traceback").total_us == pytest.approx(2_000)
    assert pt.accounted_us == pytest.approx(48_000)
    assert pt.share("kernel") == pytest.approx(26_000 / 48_000)
    # session.submit is not a wave phase: never in the table
    assert sum(s.total_us for s in pt.stats.values()) == \
        pytest.approx(48_000)
    assert not pt.is_empty()
    rows = pt.as_rows()
    names = [n for n, _, _ in rows]
    assert "phase/kernel_s" in names and "phase/scatter_share" in names
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["phase/kernel_s"] == pytest.approx(26_000 / 1e6)
    # empty trace -> empty table (the CI smoke assertion path)
    assert analyze.phase_accounting(
        analyze.Trace.from_events([])).is_empty()


def test_pipeline_finds_the_deliberate_bubble(synth):
    rep = analyze.pipeline_analysis(synth)
    assert len(rep.bubbles) == 1
    assert rep.bubbles[0].ts == pytest.approx(40_000)
    assert rep.bubbles[0].dur_us == pytest.approx(20_500)
    assert rep.busy_us == pytest.approx(50_000)
    assert rep.span_us == pytest.approx(70_500)
    assert rep.occupancy == pytest.approx(50_000 / 70_500)
    assert rep.mean_inflight == pytest.approx(50_000 / 70_500)
    # host spans: [0,10k] [30k,37k] [60k,64k] [70k,71k] = 22ms, of which
    # 21ms overlaps the busy intervals ([500,40k] and [60.5k,71k])
    assert rep.host_busy_us == pytest.approx(22_000)
    assert rep.host_overlap_us == pytest.approx(21_000)
    assert rep.host_overlap_frac == pytest.approx(21_000 / 22_000)


def test_pipeline_falls_back_to_kernel_spans_without_counter():
    ev = [e for e in SYNTHETIC if e["ph"] != "C"]
    rep = analyze.pipeline_analysis(analyze.Trace.from_events(ev))
    # busy = union of kernel spans: [10k,30k] + [64k,70k]
    assert rep.busy_us == pytest.approx(26_000)
    assert len(rep.bubbles) == 1
    assert rep.bubbles[0].dur_us == pytest.approx(34_000)


def test_cross_thread_critical_path(synth):
    paths = analyze.critical_paths(synth)
    assert len(paths) == 1
    p = paths[0]
    assert p.id == 7
    assert [s.name for s in p.segments] == \
        ["session.submit", "wave.kernel", "wave.gather"]
    assert {s.tid for s in p.segments} == {1, 2}    # crosses threads
    # kernel waited 9ms after submit ended (1_000 -> 10_000)
    assert p.segments[1].wait_us == pytest.approx(9_000)
    assert p.segments[2].wait_us == pytest.approx(0)
    assert p.latency_us == pytest.approx(35_000)    # 0 -> gather end
    assert p.busy_us == pytest.approx(26_000)
    assert p.wait_us == pytest.approx(9_000)


def test_slow_waves_orders_by_duration(synth):
    waves = analyze.slow_waves(synth, k=2)
    assert [w.dur for w in waves] == [20_000, 6_000]
    assert analyze.slow_waves(synth, k=1)[0].args["rows"] == 256


def test_diff_attributes_regression_to_suite_and_phase():
    a = {"serving/p99_ms": 10.0, "serving/pairs_per_s": 1000.0,
         "obs/on_ratio": 0.97, "phase/kernel_s": 1.0}
    b = dict(a, **{"phase/kernel_s": 3.0, "serving/p99_ms": 10.5})
    deltas = analyze.diff_rows(a, b)
    worst = deltas[0]
    assert (worst.suite, worst.phase) == ("phase", "kernel_s")
    assert worst.ratio == pytest.approx(3.0)
    # unchanged rows sort last
    assert deltas[-1].ratio == pytest.approx(1.0)
    # phase-table diff names the mover too
    ta = analyze.phase_accounting(analyze.Trace.from_events(SYNTHETIC))
    slowed = [dict(e, dur=e["dur"] * (4 if e["name"] == "wave.gather"
                                      else 1)) if e["ph"] == "X" else e
              for e in SYNTHETIC]
    tb = analyze.phase_accounting(analyze.Trace.from_events(slowed))
    pd = analyze.diff_phase_tables(ta, tb)
    assert pd[0].phase == "gather"
    assert pd[0].ratio == pytest.approx(4.0)


def test_trace_file_roundtrip(tmp_path, synth):
    path = tmp_path / "t.json"
    path.write_text(json.dumps({"traceEvents": SYNTHETIC,
                                "displayTimeUnit": "ms"}))
    t2 = analyze.Trace.from_file(str(path))
    assert len(t2.spans) == len(synth.spans)
    assert analyze.phase_accounting(t2).accounted_us == \
        pytest.approx(48_000)
    # bare-list form loads too
    (tmp_path / "bare.json").write_text(json.dumps(SYNTHETIC))
    assert len(analyze.Trace.from_file(
        str(tmp_path / "bare.json")).flows) == 3


# --------------------------------------------------------- flight rec ----


@pytest.fixture
def flightrec(tmp_path):
    """Explicit recorder dumping into tmp with no cooldown; always torn
    down so the NULL-span disabled contract holds for other modules."""
    was_on = obs_trace.enabled()
    obs_trace.disable()
    rec = obs_record.enable(capacity=64, out_dir=str(tmp_path),
                            min_interval_s=0.0)
    yield rec
    obs_record.disable()
    (obs_trace.enable if was_on else obs_trace.disable)()
    obs_trace.reset()


def test_ring_is_bounded_and_tracer_stays_empty(flightrec):
    assert not obs_trace.enabled()
    for i in range(200):
        with obs_trace.span("w", args={"i": i}):
            pass
    assert obs_trace.events() == []          # full tracer still off
    assert len(flightrec) == 64              # ring kept only the newest
    assert flightrec.events()[-1]["args"]["i"] == 199


def test_dump_writes_postmortem_and_rate_limits(flightrec, tmp_path):
    with obs_trace.span("before_failure"):
        pass
    path = flightrec.dump("unit_test", {"k": 1})
    assert path is not None
    doc = json.load(open(path))
    assert doc["flightrec"]["reason"] == "unit_test"
    assert doc["flightrec"]["args"] == {"k": 1}
    names = [e["name"] for e in doc["traceEvents"]]
    assert "before_failure" in names
    assert any(n.startswith("flightrec.dump:") for n in names)
    assert "metrics" in doc
    # cooldown: min_interval_s=0 always dumps; a long interval suppresses
    flightrec.min_interval_s = 3600.0
    assert flightrec.dump("unit_test") is None
    assert flightrec.dump("other_reason") is not None   # per-reason


def test_module_dump_is_noop_when_inactive():
    assert obs_record.active() is None
    assert obs_record.dump("nothing") is None
    # and the disabled-mode zero-allocation contract holds
    obs_trace.disable()
    assert obs_trace.span("x") is obs_trace.NULL


def test_serveloop_dumps_on_shed(flightrec, tmp_path, rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    P, plen, T, tlen = generate_pairs(ReadPairSpec(
        n_pairs=8, read_len=40, edit_frac=0.02, seed=3))
    loop = ServeLoop(eng, wave_pairs=8, form_deadline=0.01,
                     max_queue_depth=4)
    loop.start()
    loop.submit_packed(P, plen, T, tlen).result(timeout=30)
    loop.stop()
    # the queue is closed now: this offer is shed deterministically
    fut = loop.submit_packed(P, plen, T, tlen)
    with pytest.raises(Exception):
        fut.result(timeout=5)
    dumps = list(tmp_path.glob("flightrec_shed_*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    assert doc["flightrec"]["reason"] == "shed"
    assert doc["flightrec"]["args"]["n_pairs"] == 8


def test_session_dumps_on_as_completed_timeout(flightrec, tmp_path,
                                               monkeypatch, rng):
    monkeypatch.setattr(AlignmentSession, "_wave_ready",
                        staticmethod(lambda w: False))
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    P, plen, T, tlen = generate_pairs(ReadPairSpec(
        n_pairs=8, read_len=40, edit_frac=0.02, seed=4))
    with pytest.raises(TimeoutError):
        with eng.stream(max_inflight_waves=2) as sess:
            sess.submit_packed(P, plen, T, tlen)
            for _ in sess.as_completed(timeout=0.05):
                pass
    dumps = list(tmp_path.glob("flightrec_as_completed_timeout_*.json"))
    assert len(dumps) == 1
    doc = json.load(open(dumps[0]))
    assert "detail" in doc["flightrec"]["args"]


# ------------------------------------------------- capture nesting ----


def test_capture_trace_is_nesting_safe(tmp_path):
    was_on = obs_trace.enabled()
    obs_trace.disable()
    obs_trace.reset()
    outer, inner = tmp_path / "outer.json", tmp_path / "inner.json"
    try:
        with obs.capture_trace(str(outer)):
            with obs_trace.span("before"):
                pass
            with obs.capture_trace(str(inner)):
                with obs_trace.span("inside"):
                    pass
            # the inner exit must NOT clobber the outer capture
            assert obs_trace.enabled()
            with obs_trace.span("after"):
                pass
        assert not obs_trace.enabled()
        names = {e["name"]
                 for e in json.load(open(outer))["traceEvents"]}
        assert {"before", "inside", "after"} <= names
    finally:
        (obs_trace.enable if was_on else obs_trace.disable)()
        obs_trace.reset()


def test_isolated_restores_outer_timeline():
    was_on = obs_trace.enabled()
    obs_trace.reset()
    obs_trace.enable()
    try:
        with obs_trace.span("outer_kept"):
            pass
        with obs_trace.isolated():
            obs_trace.disable()
            obs_trace.enable()
            with obs_trace.span("dropped"):
                pass
            assert {e["name"] for e in obs_trace.events()} == {"dropped"}
        assert obs_trace.enabled()              # switch restored
        names = {e["name"] for e in obs_trace.events()}
        assert names == {"outer_kept"}          # inner events dropped
    finally:
        (obs_trace.enable if was_on else obs_trace.disable)()
        obs_trace.reset()


# ------------------------------------------------------ live agreement ----


def test_live_phase_sums_agree_with_session_stats(tmp_path):
    """Acceptance: analyzer phase sums over a live streamed capture match
    the SessionStats wall-time accounting within 5%."""
    spec = ReadPairSpec(n_pairs=512, read_len=100, edit_frac=0.02, seed=7)
    P, plen, T, tlen = generate_pairs(spec)
    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    run_streamed(eng, P, plen, T, tlen, submit_pairs=128)   # warm cache
    was_on = obs_trace.enabled()
    obs_trace.reset()
    path = tmp_path / "live.json"
    try:
        with obs.capture_trace(str(path)):
            _, _, st, _ = run_streamed(eng, P, plen, T, tlen,
                                       submit_pairs=128)
    finally:
        (obs_trace.enable if was_on else obs_trace.disable)()
        obs_trace.reset()
    pt = analyze.phase_accounting(analyze.Trace.from_file(str(path)))
    tol = dict(rel=0.05, abs=2e-3)
    assert pt.total_s("scatter") == pytest.approx(st.t_scatter, **tol)
    assert pt.total_s("kernel") == pytest.approx(st.t_kernel, **tol)
    # traceback time is folded into t_gather by the session accounting
    assert pt.total_s("gather") + pt.total_s("traceback") == \
        pytest.approx(st.t_gather, **tol)
    assert not analyze.critical_paths(
        analyze.Trace.from_file(str(path))) == []


# ------------------------------------------------------------- CLI ----


def test_obs_report_cli_phase_table(tmp_path, capsys):
    path = tmp_path / "t.json"
    path.write_text(json.dumps({"traceEvents": SYNTHETIC}))
    assert obs_report.main([str(path), "--assert-phases"]) == 0
    out = capsys.readouterr().out
    assert "phase table" in out
    assert "kernel (DPU)" in out                # paper mapping shown
    assert "bubbles: 1" in out
    assert "critical paths (1 flows)" in out


def test_obs_report_assert_phases_fails_on_empty(tmp_path, capsys):
    path = tmp_path / "empty.json"
    path.write_text(json.dumps({"traceEvents": []}))
    assert obs_report.main([str(path)]) == 0            # report-only: ok
    assert obs_report.main([str(path), "--assert-phases"]) == 1


def _bench_snapshot(path, kernel_s):
    rows = [{"name": "serving/p99_ms", "us_per_call": 10.0, "derived": ""},
            {"name": "phase/kernel_s", "us_per_call": kernel_s,
             "derived": ""}]
    path.write_text(json.dumps({"rows": rows}))


def test_obs_report_diff_names_suite_and_phase(tmp_path, capsys):
    a, b = tmp_path / "BENCH_a.json", tmp_path / "BENCH_b.json"
    _bench_snapshot(a, 1.0)
    _bench_snapshot(b, 3.0)
    assert obs_report.main(["--diff", str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "biggest mover: suite=phase phase=kernel_s" in out


def test_snapshot_diff_helper_compares_two_newest(tmp_path, capsys):
    from benchmarks.common import snapshot_diff
    _bench_snapshot(tmp_path / "BENCH_1.json", 1.0)
    assert snapshot_diff(str(tmp_path / "BENCH_*.json")) == []  # need 2
    _bench_snapshot(tmp_path / "BENCH_2.json", 2.0)
    lines = snapshot_diff(str(tmp_path / "BENCH_*.json"))
    assert any("suite=phase phase=kernel_s" in ln for ln in lines)
