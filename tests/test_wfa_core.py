"""Unit tests for the WFA core: bounds, hand-checked alignments, batching."""
import numpy as np
import pytest

from repro.core.aligner import AlignResult, WFAligner, pack_batch, problem_bounds
from repro.core.gotoh import gotoh_score, score_cigar
from repro.core.penalties import DEFAULT, Penalties, band_bound, score_bound


def test_penalties_window():
    assert DEFAULT.window == max(DEFAULT.x, DEFAULT.o + DEFAULT.e) + 1
    assert Penalties(1, 0, 1).window == 2


def test_score_bound_covers_regime():
    # paper regime: 100bp reads, E=4% -> at most 4 edits
    s = score_bound(DEFAULT, 100, 0.04)
    assert s >= 4 * max(DEFAULT.x, DEFAULT.o + DEFAULT.e)


def test_band_bound_monotone():
    prev = 0
    for s in range(1, 60, 7):
        k = band_bound(DEFAULT, s)
        assert k >= prev
        prev = k


@pytest.mark.parametrize("backend", ["ref", "ring", "kernel"])
def test_identical_sequences(backend):
    al = WFAligner(backend=backend)
    res = al.align(["ACGTACGT"], ["ACGTACGT"])
    assert res.scores[0] == 0


@pytest.mark.parametrize("backend", ["ref", "ring"])
def test_single_mismatch(backend):
    al = WFAligner(backend=backend)
    res = al.align(["ACGTACGT"], ["ACGAACGT"])
    assert res.scores[0] == DEFAULT.x


def test_single_insertion():
    al = WFAligner(with_cigar=True, backend="ref")
    res = al.align(["ACGT"], ["ACGGT"])
    assert res.scores[0] == DEFAULT.o + DEFAULT.e
    assert res.cigar_strings()[0].count("I") == 1


def test_single_deletion():
    al = WFAligner(with_cigar=True, backend="ref")
    res = al.align(["ACGGT"], ["ACGT"])
    assert res.scores[0] == DEFAULT.o + DEFAULT.e
    assert res.cigar_strings()[0].count("D") == 1


def test_affine_gap_preference():
    # one 3-long gap (o+3e=12) must beat three isolated 1-gaps (3(o+e)=24)
    al = WFAligner(with_cigar=True, backend="ref")
    res = al.align(["AAAATTTTCCCC"], ["AAAATTTTCCCCGGG"])
    assert res.scores[0] == DEFAULT.o + 3 * DEFAULT.e
    assert res.cigar_strings()[0].endswith("3I")


def test_empty_vs_nonempty():
    al = WFAligner(backend="ref")
    res = al.align([""], ["ACGT"])
    assert res.scores[0] == DEFAULT.o + 4 * DEFAULT.e
    res = al.align(["ACGT"], [""])
    assert res.scores[0] == DEFAULT.o + 4 * DEFAULT.e
    res = al.align([""], [""])
    assert res.scores[0] == 0


def test_score_cap_returns_minus_one():
    al = WFAligner(s_max=3, backend="ring")  # too small for any edit
    res = al.align(["AAAA"], ["TTTT"])
    assert res.scores[0] == -1


def test_batch_matches_individual(rng):
    pats = ["".join(rng.choice(list("ACGT"), size=rng.integers(5, 30)))
            for _ in range(17)]
    txts = ["".join(rng.choice(list("ACGT"), size=rng.integers(5, 30)))
            for _ in range(17)]
    al = WFAligner(backend="ring")
    batch = al.align(pats, txts)
    for i in range(17):
        single = al.align([pats[i]], [txts[i]])
        assert batch.scores[i] == single.scores[0], i


def test_pack_batch_pads_and_lengths():
    codes, lens = pack_batch(["AC", "ACGTACG"], multiple=8)
    assert codes.shape == (2, 8)
    assert list(lens) == [2, 7]


def test_cigar_matches_score_against_gotoh(rng):
    pen = Penalties(x=3, o=4, e=1)
    al = WFAligner(pen, backend="ref", with_cigar=True)
    for _ in range(10):
        p = rng.choice(list("ACGT"), size=rng.integers(1, 25))
        t = rng.choice(list("ACGT"), size=rng.integers(1, 25))
        p, t = "".join(p), "".join(t)
        res = al.align([p], [t])
        g = gotoh_score(np.frombuffer(p.encode(), np.uint8),
                        np.frombuffer(t.encode(), np.uint8), pen)
        assert res.scores[0] == g
        cost, ci, cj, ok = score_cigar(
            res.cigars[0], np.frombuffer(p.encode(), np.uint8),
            np.frombuffer(t.encode(), np.uint8), pen)
        assert ok and cost == g and ci == len(p) and cj == len(t)


def test_problem_bounds_len_diff():
    plens = np.array([10], np.int32)
    tlens = np.array([30], np.int32)
    s_max, k_max = problem_bounds(DEFAULT, plens, tlens, None)
    assert k_max >= 20  # must reach the final diagonal
