import os

# Tests must see the host as-is (1 CPU device) — only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# Modules whose tests form the ~2min pre-commit smoke tier (run with
# ``-m quick``); anything marked ``slow`` is excluded even within these.
QUICK_MODULES = {
    "test_wfa_core",
    "test_engine",
    "test_session",
    "test_cigar_pipeline",
    "test_scoring_models",
    "test_mapping",
    "test_serving",
    "test_wfa_property",
    "test_biwfa",
    "test_analysis",
    "test_fault_dist",
    "test_obs",
    "test_obs_analyze",
}


@pytest.fixture(autouse=True, scope="session")
def _flightrec_tmpdir(tmp_path_factory):
    """Route flight-recorder post-mortems to a tmp dir for the whole run.

    Session/serve failure tests trip the dump hooks on purpose; without
    this they would scatter ``results/flightrec/*.json`` into the repo.
    """
    d = tmp_path_factory.mktemp("flightrec")
    prev = os.environ.get("REPRO_FLIGHTREC_DIR")
    os.environ["REPRO_FLIGHTREC_DIR"] = str(d)
    yield
    if prev is None:
        os.environ.pop("REPRO_FLIGHTREC_DIR", None)
    else:
        os.environ["REPRO_FLIGHTREC_DIR"] = prev


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_pairs(rng, n, lo=5, hi=200, drift=4):
    """n read pairs whose mate drifts by < ``drift`` edits (shared helper)."""
    pats, txts = [], []
    for _ in range(n):
        L = int(rng.integers(lo, hi))
        p = "".join(rng.choice(list("ACGT"), size=L))
        t = list(p)
        for _ in range(int(rng.integers(0, drift))):
            pos = int(rng.integers(0, max(1, len(t))))
            r = rng.random()
            if r < 0.5 and t:
                t[pos] = rng.choice(list("ACGT"))
            elif r < 0.8:
                t.insert(pos, rng.choice(list("ACGT")))
            elif t:
                del t[pos]
        pats.append(p)
        txts.append("".join(t))
    return pats, txts


def gotoh_oracle(pats, txts, pen=None):
    """Exact dense-DP scores for string pairs (the correctness contract)."""
    from repro.core.gotoh import gotoh_score_vec
    from repro.core.penalties import DEFAULT
    return np.asarray([
        gotoh_score_vec(np.frombuffer(p.encode(), np.uint8),
                        np.frombuffer(t.encode(), np.uint8), pen or DEFAULT)
        for p, t in zip(pats, txts)], np.int32)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end drills")
    config.addinivalue_line(
        "markers", "quick: ~2min smoke subset (pre-commit tier; -m quick)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if (item.module.__name__ in QUICK_MODULES
                and "slow" not in item.keywords):
            item.add_marker(pytest.mark.quick)
