import os

# Tests must see the host as-is (1 CPU device) — only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end drills")
