import os

# Tests must see the host as-is (1 CPU device) — only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# Modules whose tests form the <60s pre-commit smoke tier (run with
# ``-m quick``); anything marked ``slow`` is excluded even within these.
QUICK_MODULES = {
    "test_wfa_core",
    "test_engine",
    "test_wfa_property",
    "test_analysis",
    "test_fault_dist",
}


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end drills")
    config.addinivalue_line(
        "markers", "quick: <60s smoke subset (pre-commit tier; -m quick)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if (item.module.__name__ in QUICK_MODULES
                and "slow" not in item.keywords):
            item.add_marker(pytest.mark.quick)
