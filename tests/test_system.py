"""End-to-end behaviour of the paper's system: generate the paper's
workload, run the PIM pipeline, check exactness and the throughput-mode
consistency (Fig. 1's Total vs Kernel decomposition)."""
import numpy as np
import pytest

from repro.configs import wfa_paper
from repro.core.aligner import WFAligner
from repro.core.gotoh import gotoh_score_vec
from repro.core.pim import PIMBatchAligner
from repro.data.reads import ReadPairSpec, generate_pairs


@pytest.mark.parametrize("edit_frac", [0.02, 0.04])
def test_paper_regime_end_to_end(edit_frac):
    """100bp reads at the paper's E thresholds: every score exact."""
    spec = ReadPairSpec(n_pairs=48, read_len=100, edit_frac=edit_frac, seed=0)
    P, plen, T, tlen = generate_pairs(spec)
    al = WFAligner(wfa_paper.pen, backend="ring", edit_frac=edit_frac)
    scores, stats = PIMBatchAligner(al).run_arrays(P, plen, T, tlen)
    assert (scores >= 0).all()      # E-derived budget must cover the data
    for i in range(48):
        g = gotoh_score_vec(P[i, : plen[i]], T[i, : tlen[i]], wfa_paper.pen)
        assert scores[i] == g, i
    assert stats.t_total >= stats.t_kernel > 0


def test_backends_agree_on_paper_regime():
    spec = ReadPairSpec(n_pairs=24, read_len=100, edit_frac=0.04, seed=5)
    P, plen, T, tlen = generate_pairs(spec)
    results = {}
    for backend in ("ref", "ring", "kernel"):
        al = WFAligner(wfa_paper.pen, backend=backend, edit_frac=0.04)
        res = al.align([P[i, : plen[i]] for i in range(24)],
                       [T[i, : tlen[i]] for i in range(24)])
        results[backend] = res.scores
    np.testing.assert_array_equal(results["ref"], results["ring"])
    np.testing.assert_array_equal(results["ref"], results["kernel"])


def test_wfa_complexity_advantage():
    """WFA score-loop trips scale with divergence (O(n*s)), not length
    (O(n*m)) — the property that makes it the state of the art the paper
    accelerates."""
    al = WFAligner(wfa_paper.pen, backend="ring")
    low = al.align(["A" * 200], ["A" * 200])       # identical: s=0
    assert low.n_steps <= 2
    spec = ReadPairSpec(n_pairs=1, read_len=200, edit_frac=0.03, seed=1)
    P, plen, T, tlen = generate_pairs(spec)
    mid = al.align([P[0, : plen[0]]], [T[0, : tlen[0]]])
    assert mid.n_steps <= 80            # ~s_max trips, never ~n*m
