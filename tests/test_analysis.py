"""Units for the dry-run analysis layer: HLO collective parsing, roofline
term arithmetic, ZeRO sharding specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import collective_bytes, hlo_op_histogram
from repro.distributed.compat import make_mesh as compat_make_mesh
from repro.analysis.roofline import attn_s2_traffic, fmt_seconds, terms
from repro.distributed.sharding import ann, split_annotations, zero_shardings

HLO = """
  %all-reduce.1 = f32[1024]{0} all-reduce(%x), replica_groups=[1,8]<=[8], to_apply=%add
  %all-gather.2 = bf16[16,128]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %reduce-scatter.3 = f32[64]{0} reduce-scatter(%z), replica_groups=[2,4]<=[8], dimensions={0}
  %collective-permute.4 = s32[256]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %add.5 = f32[4]{0} add(%a, %b)
"""


def test_collective_bytes_formulas():
    out = collective_bytes(HLO, n_devices=8)
    # all-reduce: 2 * 4096B * 7/8
    assert abs(out["all-reduce"] - 2 * 4096 * 7 / 8) < 1e-6
    # all-gather over 4: 16*128*2B * 3/4
    assert abs(out["all-gather"] - 16 * 128 * 2 * 3 / 4) < 1e-6
    # reduce-scatter over 4: 64*4B * 3
    assert abs(out["reduce-scatter"] - 64 * 4 * 3) < 1e-6
    # permute: raw bytes
    assert abs(out["collective-permute"] - 256 * 4) < 1e-6
    assert out["count_all-reduce"] == 1
    assert out["total"] == pytest.approx(
        out["all-reduce"] + out["all-gather"] + out["reduce-scatter"]
        + out["collective-permute"])


def test_collective_bytes_ignores_plain_ops():
    assert collective_bytes("  %m = f32[8,8]{1,0} dot(%a, %b)", 8)["total"] == 0


def test_hlo_op_histogram():
    h = hlo_op_histogram(HLO)
    assert h.get("all-reduce") == 1 and h.get("add") == 1


def test_roofline_terms_dominant():
    rec = {"status": "ok", "arch": "nonexistent-arch", "shape": "train_4k",
           "n_devices": 256, "flops_per_device": 197e12,     # 1s compute
           "bytes_per_device": 819e9 * 2,                    # 2s memory
           "collectives": {"total": 50e9 * 0.5},             # 0.5s coll
           "model_flops": 197e12 * 256}
    t = terms(rec)
    assert t["dominant"] == "memory"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["mfu_bound"] - 0.5) < 1e-9  # 1s useful / 2s bound


def test_attn_s2_traffic_shapes():
    dense = attn_s2_traffic("qwen3-0.6b", "train_4k", 256)
    assert dense > 0
    assert attn_s2_traffic("mamba2-780m", "train_4k", 256) == 0.0  # attn-free
    assert attn_s2_traffic("qwen3-0.6b", "decode_32k", 256) == 0.0  # 1 token
    hybrid = attn_s2_traffic("zamba2-7b", "train_4k", 256)
    assert 0 < hybrid < dense * 10


def test_fmt_seconds():
    assert fmt_seconds(0) == "0"
    assert fmt_seconds(5e-7).endswith("µs")
    assert fmt_seconds(5e-2).endswith("ms")
    assert fmt_seconds(2.0).endswith("s")


def test_zero_shardings_sharding():
    n = jax.device_count()
    mesh = compat_make_mesh((n, 1), ("data", "model"))
    tree = {"big": ann(jnp.zeros((4 * n, 8 * n)), None, "ff"),
            "small": ann(jnp.zeros((4,)), None)}
    params, axes = split_annotations(tree)
    sh = zero_shardings(mesh, params, axes, min_size=0)
    # big: dim 1 ('ff' -> model=1 -> unsharded), so dim 0 takes 'data'
    assert sh["big"].spec in (P("data", None), P(None, None))
    if n > 1:
        assert sh["big"].spec == P("data", None)
