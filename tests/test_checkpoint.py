"""Checkpointing + fault tolerance drills: atomic save, keep-k, async,
restore-template checks, and the full kill->restart->bit-identical drill."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import smoke_config
from repro.distributed.fault import FailureInjector
from repro.launch.train import train
from repro.optim.adamw import AdamWConfig


def _tiny_state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)),
                   "b": jnp.zeros((8,))},
        "opt": {"m": {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    state = _tiny_state()
    ckpt.save(str(tmp_path), 7, state)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_cleanup(tmp_path):
    state = _tiny_state()
    for s in range(5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_restore_rejects_shape_mismatch(tmp_path):
    ckpt.save(str(tmp_path), 0, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((5,))})


def test_restore_rejects_missing_leaf(tmp_path):
    ckpt.save(str(tmp_path), 0, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((4,)),
                                     "extra": jnp.zeros((2,))})


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    state = _tiny_state()
    for s in (10, 20):
        w.save(s, state)
    w.wait()
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_atomicity_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 3, _tiny_state())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


@pytest.mark.slow
def test_restart_continuation_bit_identical(tmp_path):
    """Train 8 steps straight vs train->simulated-failure->resume: the final
    parameters must match bit-for-bit (deterministic data keyed by step)."""
    cfg = smoke_config("qwen3-0.6b").replace(n_layers=2, d_model=64, d_ff=128,
                                             n_heads=2, n_kv_heads=1,
                                             d_head=32, vocab_size=128)
    opt = AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=2)
    kw = dict(steps=8, global_batch=2, seq_len=32, opt_cfg=opt, log_every=100)

    state_ref, losses_ref = train(cfg, **kw)

    d1 = str(tmp_path / "a")
    with pytest.raises(FailureInjector.SimulatedFailure):
        train(cfg, ckpt_dir=d1, ckpt_every=4, fail_at_step=5, **kw)
    state_res, losses_res = train(cfg, ckpt_dir=d1, ckpt_every=4,
                                  resume=True, **kw)

    for a, b in zip(jax.tree.leaves(state_ref["params"]),
                    jax.tree.leaves(state_res["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(state_res["step"]) == int(state_ref["step"])


def test_injector_fires_only_at_step():
    inj = FailureInjector(3)
    inj.check(2)
    with pytest.raises(FailureInjector.SimulatedFailure):
        inj.check(3)
    FailureInjector(None).check(3)  # disabled never fires
