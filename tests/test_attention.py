"""Attention-path consistency: chunked==unchunked, decode==teacher-forced
forward, MLA absorbed==naive, M-RoPE degenerates to RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.sharding import split_annotations
from repro.models import layers as L
from repro.models import get_model_fns


def _params(init, cfg, seed=0):
    tree = init(cfg, jax.random.key(seed))
    params, _ = split_annotations(tree)
    return params


def test_gqa_chunked_matches_unchunked():
    cfg = smoke_config("qwen3-0.6b").replace(q_chunk=16)
    p = _params(L.init_gqa, cfg)
    h = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32)[None], (2, 64))
    y_chunk = L.gqa_forward(p, h, cfg, pos)                  # 64 > 16 -> scan
    y_full = L.gqa_forward(p, h, cfg, pos, q_chunk=64)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=0, atol=2e-2)


def test_mla_chunked_matches_unchunked():
    cfg = smoke_config("deepseek-v2-lite-16b").replace(q_chunk=16)
    p = _params(L.init_mla, cfg)
    h = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32)[None], (2, 64))
    y_chunk = L.mla_forward(p, h, cfg, pos)
    y_full = L.mla_forward(p, h, cfg, pos, q_chunk=64)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=0, atol=2e-2)


def test_mla_absorbed_decode_matches_naive():
    cfg = smoke_config("deepseek-v2-lite-16b")
    p = _params(L.init_mla, cfg)
    B, S = 2, 12
    ckv = jax.random.normal(jax.random.key(2), (B, S, cfg.kv_lora_rank),
                            jnp.float32) * 0.3
    kr = jax.random.normal(jax.random.key(3), (B, S, cfg.qk_rope_dim),
                           jnp.float32) * 0.3
    h1 = jax.random.normal(jax.random.key(4), (B, 1, cfg.d_model),
                           jnp.float32) * 0.3
    cd = jnp.dtype(cfg.cache_dtype)
    y_naive, *_ = L.mla_decode(p, h1, cfg, ckv.astype(cd), kr.astype(cd),
                               jnp.int32(S - 1))
    cfg_a = cfg.replace(mla_absorb=True)
    y_abs, *_ = L.mla_decode(p, h1, cfg_a, ckv.astype(cd), kr.astype(cd),
                             jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(y_naive, np.float32),
                               np.asarray(y_abs, np.float32),
                               rtol=0, atol=3e-2)


def test_mrope_equals_rope_on_equal_sections():
    """When all three position components are equal, M-RoPE == RoPE."""
    dim, theta = 64, 1e4
    pos = jnp.arange(10, dtype=jnp.int32)[None]
    pos3 = jnp.broadcast_to(pos[..., None], (1, 10, 3))
    c1, s1 = L.rope_cos_sin(pos, dim, theta, jnp.float32)
    c3, s3 = L.mrope_cos_sin(pos3, dim, theta, (10, 11, 11), jnp.float32)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c3), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s3), atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-lite-16b",
                                  "mamba2-780m", "zamba2-7b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(tokens[:t]) + serve_step chain == forward(tokens) logits.

    fp32 compute/cache isolates PATH divergence from bf16 rounding noise,
    so the tolerance can be tight.  capacity_factor is raised so MoE
    capacity drops (which legitimately differ between a 48-token forward
    and a 1-token decode) cannot occur."""
    cfg = smoke_config(arch).replace(compute_dtype="float32",
                                     cache_dtype="float32",
                                     capacity_factor=8.0)
    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    params = state["params"]
    B, S = 2, 24
    toks = np.asarray(
        jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab_size),
        np.int32)

    logits_full, _ = fns.forward(params, cfg, jnp.asarray(toks))
    logits_full = np.asarray(logits_full, np.float32)

    t0 = S // 2
    _, pcache = fns.prefill(params, cfg, jnp.asarray(toks[:, :t0]))
    if cfg.family in ("ssm", "hybrid"):
        cache = pcache
        if cfg.family == "hybrid":
            grown = {}
            for k, v in pcache.items():
                if k.startswith("attn_"):
                    pad = [(0, 0)] * v.ndim
                    pad[2] = (0, S - v.shape[2])
                    grown[k] = jnp.pad(v, pad)
                else:
                    grown[k] = v
            cache = grown
    else:
        cache = fns.init_cache(cfg, B, S)
        cache = {k: jax.lax.dynamic_update_slice_in_dim(
            cache[k], pcache[k].astype(cache[k].dtype), 0, axis=2)
            for k in cache}
    for t in range(t0, S):
        logits_t, cache = fns.serve_step(params, cfg, cache,
                                         jnp.asarray(toks[:, t]),
                                         jnp.int32(t))
        # serve_step consumed token t with cache holding 0..t-1: its output
        # must match forward's logits at position t
        np.testing.assert_allclose(np.asarray(logits_t, np.float32),
                                   logits_full[:, t], rtol=0, atol=2e-3)
