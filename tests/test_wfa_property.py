"""Property-based tests: WFA is EXACT — its score must equal the dense
Gotoh gap-affine DP on every input.  That equality (plus CIGAR re-scoring)
is the paper's correctness contract, fuzzed here over sequences, lengths,
alphabets and penalty settings."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.aligner import WFAligner
from repro.core.gotoh import gotoh_score, gotoh_score_vec, score_cigar
from repro.core.penalties import Penalties

# small alphabets maximize coincidental matches (the extension loop's
# hardest case); singleton alphabet forces pure-indel alignments
alphabet = st.sampled_from([("A",), ("A", "C"), ("A", "C", "G", "T")])
penalties = st.sampled_from([
    Penalties(4, 6, 2),   # WFA2-lib default (the paper's setting)
    Penalties(1, 0, 1),   # edit distance
    Penalties(2, 3, 1),
    Penalties(5, 1, 1),
    Penalties(1, 8, 4),
])


@st.composite
def seq_pair(draw):
    ab = draw(alphabet)
    p = "".join(draw(st.lists(st.sampled_from(ab), min_size=0, max_size=40)))
    t = "".join(draw(st.lists(st.sampled_from(ab), min_size=0, max_size=40)))
    return p, t


@settings(max_examples=120, deadline=None)
@given(seq_pair(), penalties)
def test_wfa_equals_gotoh(pair, pen):
    p, t = pair
    al = WFAligner(pen, backend="ref", with_cigar=True)
    res = al.align([p], [t])
    pa = np.frombuffer(p.encode(), np.uint8)
    ta = np.frombuffer(t.encode(), np.uint8)
    g = gotoh_score(pa, ta, pen)
    assert res.scores[0] == g, (p, t, pen)
    cost, ci, cj, ok = score_cigar(res.cigars[0], pa, ta, pen)
    assert ok and cost == g and ci == len(p) and cj == len(t), (p, t, pen)


@settings(max_examples=60, deadline=None)
@given(seq_pair(), penalties)
def test_ring_equals_ref(pair, pen):
    p, t = pair
    ref = WFAligner(pen, backend="ref").align([p], [t])
    ring = WFAligner(pen, backend="ring").align([p], [t])
    assert ref.scores[0] == ring.scores[0], (p, t, pen)


@settings(max_examples=40, deadline=None)
@given(st.lists(seq_pair(), min_size=1, max_size=9), penalties)
def test_batched_lockstep_isolation(pairs, pen):
    """Pairs in one batch must not affect each other's scores."""
    ps = [p for p, _ in pairs]
    ts = [t for _, t in pairs]
    al = WFAligner(pen, backend="ring")
    batch = al.align(ps, ts)
    for i, (p, t) in enumerate(pairs):
        g = gotoh_score(np.frombuffer(p.encode(), np.uint8),
                        np.frombuffer(t.encode(), np.uint8), pen)
        assert batch.scores[i] == g, (i, p, t, pen)


@settings(max_examples=60, deadline=None)
@given(seq_pair(), penalties)
def test_gotoh_vectorized_equals_naive(pair, pen):
    p, t = pair
    pa = np.frombuffer(p.encode(), np.uint8)
    ta = np.frombuffer(t.encode(), np.uint8)
    assert gotoh_score(pa, ta, pen) == gotoh_score_vec(pa, ta, pen)


@settings(max_examples=40, deadline=None)
@given(seq_pair())
def test_symmetry_insertion_deletion(pair):
    """Swapping pattern/text swaps I<->D but keeps the optimal cost
    (penalties here are symmetric in the two gap types)."""
    p, t = pair
    al = WFAligner(backend="ring")
    assert al.align([p], [t]).scores[0] == al.align([t], [p]).scores[0]
