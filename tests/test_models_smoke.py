"""Per-architecture smoke matrix: every assigned arch instantiates a reduced
same-family config and runs one forward/train step on CPU with finite loss
and correct shapes (the FULL configs are exercised by the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.models import get_model_fns, synth_batch
from repro.models.common import SHAPES, ShapeSpec
from repro.optim.adamw import AdamWConfig

SMOKE_TRAIN = ShapeSpec("smoke_train", 64, 2, "train")


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = smoke_config(arch)
    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    step = jax.jit(fns.make_train_step(cfg, AdamWConfig(total_steps=4), 1))
    batch = synth_batch(cfg, SMOKE_TRAIN, seed=1)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_config(arch)
    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    batch = synth_batch(cfg, SMOKE_TRAIN, seed=2)
    if cfg.family == "encdec":
        logits, _ = jax.jit(lambda p, b: fns.forward(p, cfg, b["tokens"],
                                                     b["frames"]))(
            state["params"], batch)
    else:
        logits, _ = jax.jit(lambda p, b: fns.forward(
            p, cfg, b["tokens"], patch_embeds=b.get("patch_embeds"),
            mrope_pos=b.get("mrope_pos")))(state["params"], batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step(arch):
    cfg = smoke_config(arch)
    fns = get_model_fns(cfg)
    state, _ = fns.init_train_state(cfg, jax.random.key(0))
    B, S = 2, 32
    cache = fns.init_cache(cfg, B, S)
    tok = np.array([1, 2], np.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["mrope_pos"] = jnp.zeros((B, 1, 3), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t, l: fns.serve_step(p, cfg, c, t, l, **kw))(
        state["params"], cache, tok, jnp.int32(3))
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_all_archs_have_full_configs():
    assert len(ARCH_NAMES) == 10
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0


def test_param_counts_near_published():
    """Analytic param counts should land near the published sizes."""
    expect = {
        "qwen3-32b": (28e9, 36e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "granite-34b": (30e9, 38e9),
        "granite-8b": (7e9, 9.5e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 45e9),
        "zamba2-7b": (6e9, 9e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "whisper-base": (0.05e9, 0.12e9),
        "qwen2-vl-7b": (6.5e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n / 1e9)


def test_moe_active_params_smaller():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
    dsl = get_config("deepseek-v2-lite-16b")
    assert dsl.active_param_count() < 0.35 * dsl.param_count()


def test_long_context_flags():
    assert get_config("mamba2-780m").supports_long_context
    assert get_config("zamba2-7b").supports_long_context
    for arch in ("qwen3-32b", "granite-34b", "deepseek-v2-lite-16b",
                 "whisper-base", "qwen2-vl-7b"):
        assert not get_config(arch).supports_long_context
