"""SSD (Mamba2) and MoE unit invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.sharding import split_annotations
from repro.models import moe as MOE
from repro.models import ssm as SSM


def _params(init, cfg, seed=0):
    params, _ = split_annotations(init(cfg, jax.random.key(seed)))
    return params


# ---------------------------------------------------------------- SSD ----


def test_ssd_chunk_size_invariance():
    cfg8 = smoke_config("mamba2-780m").replace(ssm_chunk=8)
    cfg16 = cfg8.replace(ssm_chunk=16)
    p = _params(SSM.init_ssm, cfg8)
    h = jax.random.normal(jax.random.key(1), (2, 32, cfg8.d_model),
                          jnp.float32) * 0.5
    y8 = SSM.ssm_forward(p, h, cfg8)
    y16 = SSM.ssm_forward(p, h, cfg16)
    np.testing.assert_allclose(np.asarray(y8, np.float32),
                               np.asarray(y16, np.float32), atol=3e-2)


def test_ssd_state_continuation():
    """forward(x[:,:16]) state feeds forward(x[:,16:]) == forward(x)."""
    cfg = smoke_config("mamba2-780m").replace(ssm_chunk=8)
    p = _params(SSM.init_ssm, cfg)
    h = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, (state_f, _) = SSM.ssm_forward(p, h, cfg, return_state=True)
    y1, (state1, conv1) = SSM.ssm_forward(p, h[:, :16], cfg, return_state=True)
    # continuation must consume both the ssm state AND the conv tail; the
    # public decode path does this (test_attention covers it end-to-end).
    # Here we check the ssm state algebra alone with a clean conv boundary.
    h2 = h.at[:, 16 - (cfg.ssm_conv - 1):16].set(0.0)
    y1b, (state1b, _) = SSM.ssm_forward(p, h2[:, :16], cfg, return_state=True)
    y2, (state2, _) = SSM.ssm_forward(p, h2[:, 16:], cfg,
                                      initial_state=state1b,
                                      return_state=True)
    y_ref, (state_ref, _) = SSM.ssm_forward(p, h2, cfg, return_state=True)
    np.testing.assert_allclose(np.asarray(y2, np.float32),
                               np.asarray(y_ref[:, 16:], np.float32),
                               atol=3e-2)
    np.testing.assert_allclose(np.asarray(state2), np.asarray(state_ref),
                               atol=3e-2)


def test_ssd_decay_is_contractive():
    """With A<0 the recurrence decays: zero input -> state shrinks."""
    cfg = smoke_config("mamba2-780m")
    p = _params(SSM.init_ssm, cfg)
    B = 2
    state = jnp.ones((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                     jnp.float32)
    conv = jnp.zeros((B, cfg.ssm_conv - 1,
                      cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                     jnp.float32)
    h = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    _, (new_state, _) = SSM.ssm_decode(p, h, cfg, state, conv)
    assert float(jnp.max(jnp.abs(new_state))) <= 1.0 + 1e-5


# ---------------------------------------------------------------- MoE ----


def test_moe_router_weights_normalized():
    cfg = smoke_config("phi3.5-moe-42b-a6.6b")
    p = _params(MOE.init_moe, cfg)
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    y, aux = MOE.moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 1.0 - 1e-3  # E*sum(me*ce) >= 1 at any routing


def test_moe_single_expert_equals_dense():
    """E=1, top-1, no drop -> exactly the expert MLP."""
    cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
        n_experts=1, top_k=1, capacity_factor=4.0, n_shared_experts=0)
    p = _params(MOE.init_moe, cfg)
    x = jax.random.normal(jax.random.key(4), (1, 8, cfg.d_model),
                          jnp.float32) * 0.3
    y, _ = MOE.moe_forward(p, x, cfg)
    c = cfg.cdtype()
    xt = x.reshape(-1, cfg.d_model)
    g = jnp.einsum("td,edf->tef", xt.astype(c), p["w1"].astype(c))[:, 0]
    u = jnp.einsum("td,edf->tef", xt.astype(c), p["w3"].astype(c))[:, 0]
    ref = jnp.einsum("tf,efd->ted", jax.nn.silu(g) * u,
                     p["w2"].astype(c))[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model),
                                          np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some token routes must be dropped (zeros)."""
    cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
        n_experts=2, top_k=1, capacity_factor=0.05, n_shared_experts=0)
    p = _params(MOE.init_moe, cfg)
    x = jax.random.normal(jax.random.key(5), (4, 64, cfg.d_model),
                          jnp.float32)
    y, _ = MOE.moe_forward(p, x, cfg)
    norms = np.linalg.norm(np.asarray(y, np.float32), axis=-1).reshape(-1)
    assert (norms < 1e-6).sum() > 0  # dropped tokens contribute zero


def test_moe_shared_expert_always_on():
    cfg = smoke_config("deepseek-v2-lite-16b").replace(
        n_experts=4, top_k=1, capacity_factor=0.01, n_shared_experts=1)
    p = _params(MOE.init_moe, cfg)
    x = jax.random.normal(jax.random.key(6), (2, 32, cfg.d_model),
                          jnp.float32)
    y, _ = MOE.moe_forward(p, x, cfg)
    norms = np.linalg.norm(np.asarray(y, np.float32), axis=-1).reshape(-1)
    assert (norms > 1e-6).all()  # shared expert output survives drops


def test_moe_ep_matches_baseline_single_device():
    """moe_ep flag is a no-op without a multi-way 'model' axis (CPU), and
    the EP path itself is validated on a forced 8-device mesh in
    tests/test_dryrun_lowering.py."""
    import jax.numpy as jnp
    cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
        moe_ep=True, compute_dtype="float32", capacity_factor=8.0)
    p = _params(MOE.init_moe, cfg)
    x = jax.random.normal(jax.random.key(9), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    y_ep, _ = MOE.moe_forward(p, x, cfg)
    y_base, _ = MOE.moe_forward(p, x, cfg.replace(moe_ep=False))
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_base),
                               atol=1e-5)
