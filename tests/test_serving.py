"""repro.serve: continuous batching, admission control, exactly-once
futures — deadline and full flushes, per-request seams, shedding, split
requests, open-loop replay accounting and latency percentiles."""
import threading
import time

import numpy as np
import pytest
from conftest import gotoh_oracle as _oracle
from conftest import random_pairs as _random_pairs

from repro.core.engine import AlignmentEngine
from repro.core.scoring import Edit
from repro.data.reads import ArrivalSpec, generate_trace, poisson_arrivals
from repro.serve import (AlignRequest, RequestQueue, ServeLoop, ShedError,
                         WaveFormer, replay_trace)


def _engine(**kw):
    kw.setdefault("backend", "ring")
    kw.setdefault("edit_frac", 0.05)
    return AlignmentEngine(**kw)


def _request(rng, n, lo=20, hi=60, **kw):
    pats, txts = _random_pairs(rng, n, lo=lo, hi=hi)
    return AlignRequest.from_seqs(pats, txts, **kw), pats, txts


# ------------------------------------------------------ wave forming ----


def test_deadline_flush_of_lonely_request(rng):
    """A single request must not wait forever for company: the forming
    deadline flushes it as a padded wave and its future resolves."""
    eng = _engine()
    with ServeLoop(eng, wave_pairs=64, form_deadline=0.01) as server:
        fut = server.submit(*_random_pairs(rng, 3, lo=20, hi=40))
        res = fut.result(timeout=30)
    st = server.stats()
    assert res.scores.shape == (3,)
    assert st.waves_deadline >= 1 and st.waves_full == 0
    # 3 real rows rode a 64-row padded wave: the waste is visible
    assert st.wave_occupancy < 0.5
    assert st.padding_waste_frac == pytest.approx(1 - st.wave_occupancy)


def test_full_bucket_flush(rng):
    """wave_pairs same-bucket rows flush immediately as a full wave."""
    eng = _engine()
    pats, txts = _random_pairs(rng, 16, lo=40, hi=60)
    with ServeLoop(eng, wave_pairs=16, form_deadline=5.0) as server:
        t0 = time.monotonic()
        fut = server.submit(pats, txts)
        res = fut.result(timeout=30)
        waited = time.monotonic() - t0
    st = server.stats()
    assert st.waves_full >= 1
    # flushed on full, not by the (5s) forming deadline
    assert waited < 5.0
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))


def test_padded_partial_wave_reuses_full_wave_executable(rng):
    """The zero-retrace serving contract: a deadline-flushed partial wave
    is padded to the SAME executable shape a full wave compiles, so the
    second (lonely) request hits the cache."""
    eng = _engine()
    pats, txts = _random_pairs(rng, 16, lo=40, hi=60)
    with ServeLoop(eng, wave_pairs=16, form_deadline=0.01) as server:
        server.submit(pats, txts).result(timeout=30)      # full -> traces
        traces0 = eng.cache_traces()
        server.submit(pats[:2], txts[:2]).result(timeout=30)  # padded partial
    assert eng.cache_traces() == traces0


def test_mixed_models_land_in_separate_waves(rng):
    """Per-request penalties ride the engine's per-submit seams: edit and
    affine traffic coexist, each correct under its own model."""
    eng = _engine()
    pats, txts = _random_pairs(rng, 8, lo=30, hi=60)
    edit = Edit()
    with ServeLoop(eng, wave_pairs=8, form_deadline=0.01) as server:
        f_aff = server.submit(pats, txts)
        f_edit = server.submit(pats, txts, penalties=edit)
        r_aff = f_aff.result(timeout=30)
        r_edit = f_edit.result(timeout=30)
    st = server.stats()
    np.testing.assert_array_equal(r_aff.scores, _oracle(pats, txts))
    np.testing.assert_array_equal(r_edit.scores,
                                  _oracle(pats, txts, pen=edit.as_penalties()))
    # incompatible seams can never share a wave
    assert st.n_waves >= 2
    assert r_aff.n_waves == r_edit.n_waves == 1


def test_split_oversized_request_resolves_once(rng):
    """A request larger than wave_pairs spans several waves yet resolves
    exactly once, rows reassembled in request order."""
    eng = _engine()
    pats, txts = _random_pairs(rng, 20, lo=40, hi=60)
    with ServeLoop(eng, wave_pairs=8, form_deadline=0.01) as server:
        fut = server.submit(pats, txts)
        res = fut.result(timeout=30)
    assert res.n_waves >= 3                   # 20 rows / 8-row waves
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    with pytest.raises(Exception):            # exactly-once tripwire
        fut.set_result(None)


def test_cigar_output_mode_roundtrip(rng):
    from repro.core.gotoh import score_cigar
    from repro.core.penalties import DEFAULT
    eng = _engine(with_cigar=True)
    pats, txts = _random_pairs(rng, 6, lo=20, hi=50)
    with ServeLoop(eng, wave_pairs=8, form_deadline=0.01) as server:
        res = server.submit(pats, txts, output="cigar").result(timeout=30)
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    assert res.cigars is not None and len(res.cigars) == 6
    for i, (p, t) in enumerate(zip(pats, txts)):
        cost, ci, cj, ok = score_cigar(
            res.cigars[i], np.frombuffer(p.encode(), np.uint8),
            np.frombuffer(t.encode(), np.uint8), DEFAULT)
        assert ok and cost == res.scores[i]
        assert ci == len(p) and cj == len(t)


def test_waveformer_groups_by_bucket_and_seams(rng):
    """Unit: the former keeps incompatible requests apart and flushes
    full-vs-deadline correctly without a running loop."""
    former = WaveFormer(wave_pairs=4, form_deadline=0.5, min_bucket_len=16)
    short, _, _ = _request(rng, 4, lo=10, hi=14)
    long, _, _ = _request(rng, 2, lo=100, hi=120)
    for req in (short, long):
        req.pen, req.heur, req.out = None, None, "score"
        former.add(req, now=100.0)
    waves = former.take_ready(now=100.0)      # only the full 4-row group
    assert len(waves) == 1 and waves[0].reason == "full"
    assert waves[0].n_real == 4
    assert former.n_pending == 2              # the long pair still forming
    assert former.next_deadline() == pytest.approx(100.5)
    assert former.take_ready(now=100.4) == []
    (wave,) = former.take_ready(now=100.6)    # deadline expired
    assert wave.reason == "deadline" and wave.n_real == 2
    assert wave.n_rows == 4                   # padded to wave_pairs in-bucket
    assert former.n_pending == 0


# ------------------------------------------------- admission control ----


def test_bounded_queue_sheds_with_typed_error(rng):
    """Unit: the queue answers over-capacity offers with ShedError."""
    q = RequestQueue(max_depth=2)
    reqs = [_request(rng, 1)[0] for _ in range(3)]
    assert q.offer(reqs[0]) and q.offer(reqs[1])
    assert not q.offer(reqs[2])
    with pytest.raises(ShedError) as ei:
        reqs[2].future.result(timeout=0)
    assert ei.value.reason == "queue full"
    assert ei.value.max_depth == 2 and ei.value.queue_depth == 2
    assert q.n_offered == 3 and q.n_shed == 1
    # admitted requests still drain after a shed
    assert q.drain() == reqs[:2]


def test_submit_after_stop_sheds_server_stopped(rng):
    eng = _engine()
    server = ServeLoop(eng, wave_pairs=8, form_deadline=0.01).start()
    server.submit(*_random_pairs(rng, 2, lo=20, hi=40)).result(timeout=30)
    server.stop()
    fut = server.submit(*_random_pairs(rng, 2, lo=20, hi=40))
    with pytest.raises(ShedError) as ei:
        fut.result(timeout=0)
    assert ei.value.reason == "server stopped"
    st = server.stats()
    assert st.n_shed == 1 and st.n_outstanding == 0


def test_unservable_request_fails_fast_on_future(rng):
    eng = _engine()
    with ServeLoop(eng, wave_pairs=8, form_deadline=0.01) as server:
        fut = server.submit(*_random_pairs(rng, 2), output="bogus")
        with pytest.raises(ValueError):
            fut.result(timeout=0)             # resolved at admission


# ---------------------------------------------------- open-loop replay ----


def test_replay_accounts_every_future_exactly_once(rng):
    """Every request in a replayed trace is answered exactly once — ok,
    shed or failed sum to the trace size."""
    eng = _engine(edit_frac=0.02)
    payloads, arrivals = generate_trace(ArrivalSpec(
        n_requests=24, pairs_per_request=4, read_len=60, seed=3))
    with ServeLoop(eng, wave_pairs=32, form_deadline=0.01) as server:
        report = replay_trace(server, payloads, arrivals * 1e-3)
    assert report.n_requests == 24
    assert report.n_ok + report.n_shed + report.n_failed == 24
    assert report.n_failed == 0 and report.n_ok == 24
    assert report.pairs_done == 24 * 4
    # served scores match the batch-mode engine on the identical pairs
    P = np.concatenate([p for p, _, _, _ in payloads])
    plen = np.concatenate([pl for _, pl, _, _ in payloads])
    T = np.concatenate([t for _, _, t, _ in payloads])
    tlen = np.concatenate([tl for _, _, _, tl in payloads])
    batch = eng.align_packed(P, plen, T, tlen)
    got = np.concatenate([r.scores for r in report.results])
    np.testing.assert_array_equal(got, batch.scores)


def test_latency_percentiles_from_many_completions(rng):
    """p50/p95/p99 computed from >= 100 completions, properly ordered."""
    eng = _engine(edit_frac=0.02)
    payloads, _ = generate_trace(ArrivalSpec(
        n_requests=120, pairs_per_request=2, read_len=40, seed=5))
    with ServeLoop(eng, wave_pairs=64, form_deadline=0.005) as server:
        report = replay_trace(server, payloads, np.zeros(120))
        st = server.stats()
    assert st.n_latency_samples >= 100
    assert report.latencies.size == 120
    p50, p95, p99 = (report.percentile_ms(q) for q in (50, 95, 99))
    assert 0 < p50 <= p95 <= p99
    assert st.latency_p50 <= st.latency_p95 <= st.latency_p99 \
        <= st.latency_max
    # ServerStats percentiles come from the bounded log-bucketed histogram:
    # within one bucket (a factor) of the exact driver-side percentile
    factor = server._latency_hist.factor
    assert (p50 / 1e3) / factor <= st.latency_p50 \
        <= (p50 / 1e3) * factor + 1e-12
    assert np.isfinite(st.latency_mean)


def test_poisson_arrivals_deterministic_and_sorted():
    a = poisson_arrivals(64, rate=100.0, seed=7)
    b = poisson_arrivals(64, rate=100.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) >= 0).all() and a.shape == (64,)
    assert not np.array_equal(a, poisson_arrivals(64, 100.0, seed=8))
    with pytest.raises(ValueError):
        poisson_arrivals(4, rate=0.0)


def test_concurrent_submitters_one_server(rng):
    """Many caller threads share one server; every future resolves with
    oracle-correct scores (the serve loop's thread-safety contract)."""
    eng = _engine()
    chunks = [_random_pairs(np.random.default_rng(i), 4, lo=20, hi=60)
              for i in range(12)]
    futs = [None] * 12
    with ServeLoop(eng, wave_pairs=16, form_deadline=0.01,
                   n_threads=2) as server:
        def _submit(i):
            futs[i] = server.submit(*chunks[i])
        threads = [threading.Thread(target=_submit, args=(i,))
                   for i in range(12)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        results = [f.result(timeout=30) for f in futs]
    for res, (p, t) in zip(results, chunks):
        np.testing.assert_array_equal(res.scores, _oracle(p, t))
    st = server.stats()
    assert st.n_completed == 12 and st.n_outstanding == 0


def test_stop_resolves_everything_before_returning(rng):
    """stop() drains: no accepted future is left pending."""
    eng = _engine()
    server = ServeLoop(eng, wave_pairs=64, form_deadline=10.0).start()
    futs = [server.submit(*_random_pairs(rng, 2, lo=20, hi=40))
            for _ in range(5)]
    server.stop()                 # long deadline: only the drain flushes
    for fut in futs:
        assert fut.done()
        assert fut.result(timeout=0).scores.shape == (2,)
    assert server.stats().waves_drain >= 1


def test_empty_request_resolves_immediately():
    eng = _engine()
    with ServeLoop(eng, wave_pairs=8, form_deadline=0.01) as server:
        res = server.submit([], []).result(timeout=5)
    assert res.scores.shape == (0,) and res.n_waves == 0


def test_serving_benchmark_emits_gated_rows():
    """The benchmark emits every gated row, verifies exactly-once +
    batch-identical scores internally, and measures zero retraces (the
    gate's ratio arm needs real scale, so it is not asserted here)."""
    from benchmarks import serving
    rows = serving.run(requests=8, pairs_per_request=4, read_len=40,
                       wave_pairs=16, load=0.5)
    names = {n for n, _, _ in rows}
    for suffix in ("batch", "sustained", "ratio", "p50", "p95", "p99",
                   "occupancy", "waste", "shed", "retraces"):
        assert f"serving/ring/{suffix}" in names
    by = {n: v for n, v, _ in rows}
    assert by["serving/ring/retraces"] == 0
    assert by["serving/ring/shed"] == 0
    assert 0 < by["serving/ring/occupancy"] <= 1


def test_serving_gate_detects_each_regression():
    """check() trips on low ratio, steady-state retraces and p99 blowup,
    and passes a healthy snapshot (the CI wiring contract)."""
    from benchmarks import serving

    def rows(ratio=0.8, retraces=0.0, p99_us=50e3):
        return [("serving/ring/ratio", ratio, ""),
                ("serving/ring/retraces", retraces, ""),
                ("serving/ring/p99", p99_us, "")]

    assert serving.check(rows()) == []
    assert len(serving.check(rows(ratio=0.3))) == 1
    assert len(serving.check(rows(retraces=2.0))) == 1
    assert len(serving.check(rows(p99_us=3e6))) == 1
    assert len(serving.check(rows(p99_us=float("nan")))) == 1
    assert len(serving.check(rows(0.1, 1.0, 9e6))) == 3
    with pytest.raises(KeyError):
        serving.check([])                     # missing rows never pass
