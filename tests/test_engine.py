"""AlignmentEngine: backend registry, bucketed batching, executable cache,
adaptive two-pass overflow recovery — all against the Gotoh oracle."""
import numpy as np
import pytest
from conftest import gotoh_oracle as _oracle
from conftest import random_pairs as _random_pairs

from repro.core.backends import (available_backends, get_backend,
                                 register_backend, unregister_backend)
from repro.core.engine import AlignmentEngine, pack_batch
from repro.core.penalties import DEFAULT, Penalties
from repro.core.wavefront import WFAResult, wfa_scores


# ------------------------------------------------------------ registry ----


def test_builtin_backends_registered():
    for name in ("ref", "ring", "kernel", "shardmap"):
        assert name in available_backends()
        # every built-in serves output="cigar" via a trace variant
        assert get_backend(name).supports_cigar, name
    assert get_backend("shardmap").needs_mesh


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown alignment backend"):
        get_backend("nope")
    with pytest.raises(KeyError):
        AlignmentEngine(backend="nope")


def test_plugin_backend_dispatches():
    calls = []

    @register_backend("test-plugin", doc="ring + call counter")
    def _plugin(pattern, text, plen, tlen, *, pen, s_max, k_max):
        calls.append(1)   # trace-time; engine jits around this
        return wfa_scores(pattern, text, plen, tlen, pen=pen,
                          s_max=s_max, k_max=k_max)

    try:
        eng = AlignmentEngine(backend="test-plugin", edit_frac=0.1)
        res = eng.align(["ACGTACGT"], ["ACGAACGT"])
        assert res.scores[0] == DEFAULT.x
        assert calls   # plugin actually traced
    finally:
        unregister_backend("test-plugin")
    assert "test-plugin" not in available_backends()


def test_cigar_needs_capable_backend():
    # a plug-in without a trace variant is score-only: CIGAR output must be
    # rejected at construction (default output) and per call
    @register_backend("score-only")
    def _scores(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return wfa_scores(pattern, text, plen, tlen, pen=pen,
                          s_max=s_max, k_max=k_max)

    try:
        with pytest.raises(ValueError, match="score-only"):
            AlignmentEngine(backend="score-only", with_cigar=True)
        with pytest.raises(ValueError, match="score-only"):
            AlignmentEngine(backend="score-only", output="cigar")
        eng = AlignmentEngine(backend="score-only", edit_frac=0.1)
        with pytest.raises(ValueError, match="score-only"):
            eng.align(["ACGT"], ["ACGT"], output="cigar")
    finally:
        unregister_backend("score-only")
    with pytest.raises(ValueError, match="output mode"):
        AlignmentEngine(backend="ring", output="sideways")


# ------------------------------------------------- bucketing + oracle ----


def test_mixed_length_batch_matches_gotoh(rng):
    pats, txts = _random_pairs(rng, 80, lo=5, hi=250)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    res = eng.align(pats, txts)
    assert res.stats.n_buckets >= 2          # genuinely bucketed run
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    # every pair resolved: the recovery pass leaves no -1 behind
    assert (res.scores >= 0).all()


def test_bucketed_equals_unbucketed(rng):
    pats, txts = _random_pairs(rng, 40, lo=5, hi=150)
    kw = dict(backend="ring", edit_frac=0.05)
    bucketed = AlignmentEngine(bucket_by_length=True, **kw).align(pats, txts)
    flat = AlignmentEngine(bucket_by_length=False, **kw).align(pats, txts)
    np.testing.assert_array_equal(bucketed.scores, flat.scores)
    assert flat.stats.n_buckets == 1


def test_ref_backend_bucketed_cigars(rng):
    pen = Penalties(x=3, o=4, e=1)
    pats, txts = _random_pairs(rng, 20, lo=4, hi=120)
    # with_cigar is the deprecated spelling of the default output mode
    eng = AlignmentEngine(pen, backend="ref", edit_frac=0.1, with_cigar=True)
    assert eng.with_cigar and eng.default_output == "cigar"
    res = eng.align(pats, txts)
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts, pen))
    from repro.core.gotoh import score_cigar
    for i, (p, t) in enumerate(zip(pats, txts)):
        cost, ci, cj, ok = score_cigar(
            res.cigars[i], np.frombuffer(p.encode(), np.uint8),
            np.frombuffer(t.encode(), np.uint8), pen)
        assert ok and cost == res.scores[i]
        assert ci == len(p) and cj == len(t)


# ------------------------------------------------- adaptive two-pass ----


def test_large_len_diff_recovers_with_stable_bucket_bounds(rng):
    # one pair's length diff exceeds the E-derived band: pass-1 bounds must
    # stay data-independent (same cache key), the pair recovers in pass 2
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    near = _random_pairs(rng, 8, lo=100, hi=120)
    base = eng.align(*near)
    k1 = [(b.lmax, b.s_max, b.k_max) for b in base.stats.buckets
          if not b.recovery]
    pats = list(near[0]) + ["A" * 120]
    txts = list(near[1]) + ["A" * 40]       # diff 80 >> band
    res = eng.align(pats, txts)
    k2 = [(b.lmax, b.s_max, b.k_max) for b in res.stats.buckets
          if not b.recovery]
    assert k1 == k2                          # outlier didn't reshape pass 1
    assert res.stats.n_overflow >= 1 and res.stats.n_recovered >= 1
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))


def test_overflow_pairs_get_real_scores_on_second_pass():
    # wildly divergent pairs: far beyond the 2% budget of pass 1
    pats = ["A" * 40, "ACGT" * 10, "G" * 30]
    txts = ["T" * 40, "TGCA" * 10, "C" * 35]
    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    res = eng.align(pats, txts)
    assert res.stats.n_overflow == 3
    assert res.stats.n_recovered == 3
    assert any(b.recovery for b in res.stats.buckets)
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))


def test_adaptive_off_leaves_overflow_unresolved():
    eng = AlignmentEngine(backend="ring", edit_frac=0.02, adaptive=False)
    res = eng.align(["A" * 40], ["T" * 40])
    assert res.scores[0] == -1
    assert res.stats.n_overflow == 1        # counted, but no recovery ran
    assert res.stats.n_recovered == 0
    assert not any(b.recovery for b in res.stats.buckets)


def test_reregistered_backend_invalidates_cache():
    from repro.core.wavefront import wfa_scores as _ws

    @register_backend("swap-test")
    def _v1(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return _ws(pattern, text, plen, tlen, pen=pen, s_max=s_max,
                   k_max=k_max)

    try:
        eng = AlignmentEngine(backend="swap-test", edit_frac=0.1)
        eng.align(["ACGTACGT"], ["ACGAACGT"])

        @register_backend("swap-test")
        def _v2(pattern, text, plen, tlen, *, pen, s_max, k_max):
            res = _ws(pattern, text, plen, tlen, pen=pen, s_max=s_max,
                      k_max=k_max)
            return WFAResult(res.score * 0 + 99, None, None, None,
                             res.n_steps)

        res = eng.align(["ACGTACGT"], ["ACGAACGT"])
        assert res.scores[0] == 99      # new fn used, not a stale executable
    finally:
        unregister_backend("swap-test")


def test_explicit_s_max_pins_cap_no_recovery():
    eng = AlignmentEngine(backend="ring", s_max=3)
    res = eng.align(["AAAA"], ["TTTT"])
    assert res.scores[0] == -1
    assert not any(b.recovery for b in res.stats.buckets)


# ------------------------------------------------- executable cache ----


def test_cache_hits_on_repeated_same_bucket_calls(rng):
    pats, txts = _random_pairs(rng, 30, lo=40, hi=120)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    first = eng.align(pats, txts)
    assert first.stats.cache_misses > 0 and first.stats.cache_hits == 0
    assert first.stats.n_traces == first.stats.cache_misses

    second = eng.align(pats, txts)
    assert second.stats.cache_misses == 0
    assert second.stats.cache_hits == first.stats.cache_misses
    assert second.stats.n_traces == 0       # zero re-traces at serving time
    np.testing.assert_array_equal(first.scores, second.scores)

    # same buckets, different data: still fully cached
    pats2, txts2 = _random_pairs(rng, 30, lo=40, hi=120)
    third = eng.align(pats2, txts2)
    assert third.stats.n_traces == 0 and third.stats.cache_misses == 0


def test_pair_count_quantization_shares_executables(rng):
    # 17 and 23 pairs both pad to the same quantized pair count (24)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    p1, t1 = _random_pairs(rng, 17, lo=50, hi=60)
    p2, t2 = _random_pairs(rng, 23, lo=50, hi=60)
    eng.align(p1, t1)
    res = eng.align(p2, t2)
    assert res.stats.cache_hits > 0 and res.stats.n_traces == 0


# ------------------------------------------------- wrappers / shims ----


def test_wfaligner_shim_matches_engine(rng):
    from repro.core.aligner import WFAligner
    pats, txts = _random_pairs(rng, 25, lo=5, hi=100)
    shim = WFAligner(backend="ring", edit_frac=0.05).align(pats, txts)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05).align(pats, txts)
    np.testing.assert_array_equal(shim.scores, eng.scores)


def test_pim_shim_returns_stats(rng):
    from repro.core.aligner import WFAligner
    from repro.core.pim import PIMBatchAligner
    pats, txts = _random_pairs(rng, 12, lo=20, hi=60)
    p, plen = pack_batch(pats)
    t, tlen = pack_batch(txts)
    ex = PIMBatchAligner(WFAligner(backend="ring", edit_frac=0.05),
                         chunk_pairs=8)
    scores, stats = ex.run_arrays(p, plen, t, tlen)
    assert stats.n_pairs == 12
    assert stats.bytes_in > 0 and stats.bytes_out >= 12 * 4
    assert stats.t_total >= stats.t_kernel
    np.testing.assert_array_equal(scores, _oracle(pats, txts))


def test_kernel_backend_through_engine():
    eng = AlignmentEngine(backend="kernel", edit_frac=0.1,
                          min_bucket_len=16)
    pats = ["ACGTACGTAC", "TTTTGGGG"]
    txts = ["ACGAACGTAC", "TTTTGGGA"]
    res = eng.align(pats, txts)
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
