"""Scoring-model & wavefront-heuristic subsystem (``core.scoring``).

Every backend must produce oracle-exact scores and re-scorable CIGARs for
every penalty model (edit / gap-linear / gap-affine); adaptive-band
pruning must stay score-safe on the paper's regime and flag its results
approximate; mixed-model tickets must coexist in one streaming session;
and the deprecated shims must forward the engine-era ``penalties`` kwarg
instead of raising.
"""
import gzip

import numpy as np
import pytest
from conftest import random_pairs as _random_pairs

from repro.core.engine import AlignmentEngine
from repro.core.gotoh import gotoh_score_vec, score_cigar
from repro.core.penalties import DEFAULT, Penalties
from repro.core.scoring import (EXACT, AdaptiveBand, Edit, GapAffine,
                                GapLinear, NoHeuristic, ZDrop, as_heuristic,
                                as_model, parse_heuristic, parse_penalties)

MODELS = [Edit(), GapLinear(mismatch=3, gap_extend=2), GapAffine(4, 6, 2)]


def _oracle(pats, txts, model):
    pen = model.as_penalties()
    return np.asarray([
        gotoh_score_vec(np.frombuffer(p.encode(), np.uint8),
                        np.frombuffer(t.encode(), np.uint8), pen)
        for p, t in zip(pats, txts)], np.int32)


def _levenshtein(p, t):
    """Independent O(nm) edit distance (no shared code with Gotoh/WFA)."""
    prev = list(range(len(t) + 1))
    for i, pc in enumerate(p, 1):
        cur = [i]
        for j, tc in enumerate(t, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (pc != tc)))
        prev = cur
    return prev[-1]


def _assert_rescore(res, pats, txts, model, oracle):
    pen = model.as_penalties()
    np.testing.assert_array_equal(res.scores, oracle)
    assert res.cigars is not None
    for i, (p, t) in enumerate(zip(pats, txts)):
        pa = np.frombuffer(p.encode(), np.uint8)
        ta = np.frombuffer(t.encode(), np.uint8)
        cost, ci, cj, ok = score_cigar(res.cigars[i], pa, ta, pen)
        assert ok, (i, p, t)
        assert cost == oracle[i], (i, cost, oracle[i])
        assert ci == len(p) and cj == len(t), (i, ci, cj)


# ------------------------------------------------ model/heuristic types ----


def test_model_normalization_and_attrs():
    assert as_model(Penalties(4, 6, 2)) == GapAffine(4, 6, 2)
    assert as_model(None) == GapAffine()
    assert as_model(Edit()) is not None
    e = Edit()
    assert (e.x, e.o, e.e, e.kind, e.window) == (1, 0, 1, "linear", 2)
    lin = GapLinear(mismatch=4, gap_extend=2)
    assert (lin.o, lin.kind) == (0, "linear")
    aff = GapAffine(4, 6, 2)
    assert (aff.kind, aff.window) == ("affine", 9)
    # hashable: usable as jit static args / cache keys
    assert len({Edit(), Edit(), GapLinear(), aff}) == 3
    assert as_heuristic(None) == EXACT and EXACT.exact
    assert not AdaptiveBand().exact and not ZDrop().exact


def test_parse_specs():
    assert parse_penalties("edit") == Edit()
    assert parse_penalties("linear:3,2") == GapLinear(3, 2)
    assert parse_penalties("affine:4,6,2") == GapAffine(4, 6, 2)
    assert parse_penalties("4,6,2") == GapAffine(4, 6, 2)
    with pytest.raises(ValueError):
        parse_penalties("bogus")
    assert parse_heuristic("none") == NoHeuristic()
    assert parse_heuristic("adaptive:8,40") == AdaptiveBand(8, 40)
    assert parse_heuristic("zdrop:64") == ZDrop(64)
    with pytest.raises(ValueError):
        parse_heuristic("adaptive:1")


def test_model_bounds_shrink_with_model():
    # the E-derived score cap shrinks with the per-edit unit cost
    aff, ed = GapAffine(4, 6, 2), Edit()
    assert ed.unit_cost() == 1 < aff.unit_cost()
    assert ed.score_bound(100, 0.04) < aff.score_bound(100, 0.04)
    assert ed.worst_score(50, 60) == 50 + 10


# ------------------------------------------------ backend parity suite ----


@pytest.mark.parametrize("model", MODELS, ids=["edit", "linear", "affine"])
@pytest.mark.parametrize("backend", ["ref", "ring"])
def test_model_oracle_parity_score_and_cigar(rng, model, backend):
    pats, txts = _random_pairs(rng, 10, lo=4, hi=70)
    pats += ["ACGT" * 10, ""]            # divergent + empty edges
    txts += ["TTTT" * 11, "ACG"]
    oracle = _oracle(pats, txts, model)
    eng = AlignmentEngine(model, backend=backend, edit_frac=0.05)
    res = eng.align(pats, txts)
    np.testing.assert_array_equal(res.scores, oracle)
    assert not res.approximate
    resc = eng.align(pats, txts, output="cigar")
    _assert_rescore(resc, pats, txts, model, oracle)


@pytest.mark.parametrize("model", MODELS, ids=["edit", "linear", "affine"])
def test_kernel_model_parity(rng, model):
    # one bucket shape: pallas interpret-mode compiles dominate, keep small
    pats, txts = _random_pairs(rng, 6, lo=8, hi=56)
    oracle = _oracle(pats, txts, model)
    eng = AlignmentEngine(model, backend="kernel", edit_frac=0.08,
                          bucket_by_length=False)
    res = eng.align(pats, txts, output="cigar")
    _assert_rescore(res, pats, txts, model, oracle)


@pytest.mark.parametrize("model", MODELS, ids=["edit", "linear", "affine"])
def test_shardmap_model_parity(rng, model):
    import jax
    from repro.distributed.compat import make_mesh
    mesh = make_mesh((jax.device_count(),), ("pairs",))
    pats, txts = _random_pairs(rng, 8, lo=6, hi=48)
    oracle = _oracle(pats, txts, model)
    eng = AlignmentEngine(model, backend="shardmap", mesh=mesh,
                          edit_frac=0.08, bucket_by_length=False)
    res = eng.align(pats, txts, output="cigar")
    _assert_rescore(res, pats, txts, model, oracle)


def test_edit_model_is_levenshtein(rng):
    pats, txts = _random_pairs(rng, 12, lo=3, hi=60)
    eng = AlignmentEngine(Edit(), backend="ring", edit_frac=0.1)
    res = eng.align(pats, txts)
    want = [_levenshtein(p, t) for p, t in zip(pats, txts)]
    np.testing.assert_array_equal(res.scores, want)


def test_per_call_model_override_shares_engine(rng):
    pats, txts = _random_pairs(rng, 8, lo=5, hi=50)
    eng = AlignmentEngine(backend="ring", edit_frac=0.1)   # affine default
    r_aff = eng.align(pats, txts)
    r_edit = eng.align(pats, txts, penalties=Edit())
    np.testing.assert_array_equal(r_aff.scores,
                                  _oracle(pats, txts, GapAffine()))
    np.testing.assert_array_equal(r_edit.scores, _oracle(pats, txts, Edit()))
    # both models' executables coexist in one cache; re-running re-traces
    # nothing
    before = eng.cache_traces()
    eng.align(pats, txts, penalties=Edit())
    eng.align(pats, txts)
    assert eng.cache_traces() == before


# ------------------------------------------------ heuristics --------------


def test_adaptive_band_score_safety(rng):
    # the paper's regime: reads with bounded divergence — the adaptive band
    # must not change any score, only flag approximation
    pats, txts = _random_pairs(rng, 16, lo=20, hi=120, drift=5)
    eng = AlignmentEngine(backend="ring", edit_frac=0.1)
    exact = eng.align(pats, txts)
    approx = eng.align(pats, txts, heuristic=AdaptiveBand())
    assert approx.approximate and not exact.approximate
    np.testing.assert_array_equal(exact.scores, approx.scores)


def test_heuristic_upper_bound_on_divergent_pairs(rng):
    # truly divergent pairs: a tight band may miss the optimum, but any
    # resolved heuristic score must stay an upper bound on the exact cost
    pats = ["".join(rng.choice(list("ACGT"), size=60)) for _ in range(6)]
    txts = ["".join(rng.choice(list("ACGT"), size=60)) for _ in range(6)]
    eng = AlignmentEngine(backend="ring")        # exact worst-case bounds
    exact = eng.align(pats, txts)
    approx = eng.align(pats, txts,
                       heuristic=AdaptiveBand(min_wf_len=4,
                                              max_distance_diff=8))
    found = approx.scores >= 0
    assert (approx.scores[found] >= exact.scores[found]).all()


def test_heuristic_cigars_rescore_to_reported_score(rng):
    pats, txts = _random_pairs(rng, 10, lo=10, hi=80)
    eng = AlignmentEngine(backend="ring", edit_frac=0.1,
                          heuristic=AdaptiveBand())
    res = eng.align(pats, txts, output="cigar")
    assert res.approximate
    for i, (p, t) in enumerate(zip(pats, txts)):
        if res.scores[i] < 0:
            continue
        cost, ci, cj, ok = score_cigar(
            res.cigars[i], np.frombuffer(p.encode(), np.uint8),
            np.frombuffer(t.encode(), np.uint8), DEFAULT)
        assert ok and cost == res.scores[i], (i, cost, res.scores[i])


def test_zdrop_on_kernel_backend(rng):
    pats, txts = _random_pairs(rng, 6, lo=8, hi=56)
    eng = AlignmentEngine(backend="kernel", edit_frac=0.08,
                          bucket_by_length=False)
    exact = eng.align(pats, txts)
    zd = eng.align(pats, txts, heuristic=ZDrop(zdrop=100))
    assert zd.approximate
    np.testing.assert_array_equal(exact.scores, zd.scores)


def test_heuristic_unaware_plugin_fails_loudly(rng):
    from repro.core import wavefront as wf
    from repro.core.backends import register_backend, unregister_backend

    @register_backend("no-heur")
    def _plain(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return wf.wfa_scores(pattern, text, plen, tlen, pen=pen,
                             s_max=s_max, k_max=k_max)

    try:
        eng = AlignmentEngine(backend="no-heur", edit_frac=0.1)
        pats, txts = _random_pairs(rng, 4, lo=5, hi=30)
        res = eng.align(pats, txts)            # exact path still serves
        np.testing.assert_array_equal(res.scores,
                                      _oracle(pats, txts, GapAffine()))
        with pytest.raises(ValueError, match="heuristic"):
            eng.align(pats, txts, heuristic=AdaptiveBand())
        with pytest.raises(ValueError, match="linear"):
            eng.align(pats, txts, penalties=Edit())   # affine-only plug-in
        # a rejected submit must not brick the session: validation happens
        # before the ticket exists, so prior tickets still complete
        with eng.stream() as sess:
            ok = sess.submit(pats, txts)
            with pytest.raises(ValueError, match="heuristic"):
                sess.submit(pats, txts, heuristic=AdaptiveBand())
            np.testing.assert_array_equal(ok.result().scores,
                                          _oracle(pats, txts, GapAffine()))
    finally:
        unregister_backend("no-heur")


def test_linear_only_plugin_serves_cigar(rng):
    # a backend declaring only the linear recurrence must serve
    # output="cigar" for linear models (the kind check must use the model
    # in play, not assume affine)
    from repro.core import wavefront as wf
    from repro.core.backends import register_backend, unregister_backend

    def _trace(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return wf.wfa_scores_packed(pattern, text, plen, tlen, pen=pen,
                                    s_max=s_max, k_max=k_max)

    @register_backend("lin-only", trace_variant=_trace, models=("linear",))
    def _score(pattern, text, plen, tlen, *, pen, s_max, k_max):
        return wf.wfa_scores(pattern, text, plen, tlen, pen=pen,
                             s_max=s_max, k_max=k_max)

    try:
        pats, txts = _random_pairs(rng, 6, lo=5, hi=40)
        eng = AlignmentEngine(Edit(), backend="lin-only", edit_frac=0.1)
        res = eng.align(pats, txts, output="cigar")
        _assert_rescore(res, pats, txts, Edit(), _oracle(pats, txts, Edit()))
        with pytest.raises(ValueError, match="affine"):
            eng.align(pats, txts, penalties=GapAffine())
    finally:
        unregister_backend("lin-only")


# ------------------------------------------------ sessions ---------------


def test_mixed_model_tickets_one_session(rng):
    pats, txts = _random_pairs(rng, 12, lo=5, hi=60)
    eng = AlignmentEngine(backend="ring", edit_frac=0.1, chunk_pairs=8)
    with eng.stream(max_inflight_waves=2) as sess:
        by_index = {}
        for model in MODELS:
            tk = sess.submit(pats, txts, penalties=model, output="cigar")
            by_index[tk.index] = model
        tk_h = sess.submit(pats, txts, heuristic=AdaptiveBand())
        seen = 0
        for tk in sess.as_completed():
            seen += 1
            res = tk.result()
            if tk.index == tk_h.index:
                assert res.approximate
                continue
            model = by_index[tk.index]
            _assert_rescore(res, pats, txts, model,
                            _oracle(pats, txts, model))
    assert seen == len(MODELS) + 1


# ------------------------------------------------ deprecated shims -------


def test_wfaligner_forwards_penalties_kwarg():
    from repro.core.aligner import WFAligner
    with pytest.warns(DeprecationWarning):
        al = WFAligner(penalties=Edit(), backend="ring")
    assert al.engine.pen == Edit()
    r = al.align(["GATTACA"], ["GATTTACA"])
    assert r.scores[0] == 1


def test_pim_batch_aligner_forwards_penalties_kwarg():
    from repro.core.aligner import WFAligner
    from repro.core.pim import PIMBatchAligner
    with pytest.warns(DeprecationWarning):
        al = WFAligner(backend="ring")
        ex = PIMBatchAligner(al, penalties=Edit())
    assert ex.engine.pen == Edit()
    scores, stats = ex.run(["GATTACA"], ["GATTTACA"])
    assert scores[0] == 1 and stats.n_pairs == 1


# ------------------------------------------------ FASTA/FASTQ reader -----


def test_fasta_fastq_readers(tmp_path):
    from repro.data.io import load_pair_files, read_seqs
    fa = tmp_path / "refs.fa"
    fa.write_text(">r0 desc\nACGT\nACGT\n>r1\nGATTACA\n")
    fq_plain = tmp_path / "reads.fq"
    fq_plain.write_text("@q0\nACGTACGA\n+\nIIIIIIII\n@q1 x\nGATTTACA\n+q1\n"
                        "IIIIIIII\n")
    # gzip the fastq under a lying extension: magic-byte sniff must win
    fq = tmp_path / "reads.fastq"
    fq.write_bytes(gzip.compress(fq_plain.read_bytes()))

    names, seqs = read_seqs(str(fa))
    assert names == ["r0", "r1"]
    assert [bytes(s.tobytes()).decode() for s in seqs] == ["ACGTACGT",
                                                           "GATTACA"]
    names, seqs = read_seqs(str(fq))
    assert names == ["q0", "q1"]
    assert len(seqs[0]) == 8

    P, plen, T, tlen = load_pair_files(str(fq), str(fa))
    assert P.shape[0] == 2 and plen.tolist() == [8, 7]
    assert tlen.tolist() == [8, 8]
    eng = AlignmentEngine(backend="ring")
    res = eng.align_packed(P, plen, T, tlen, penalties=Edit())
    assert res.scores[1] == 1          # GATTACA vs GATTTACA


def test_reader_rejects_mismatched_and_malformed(tmp_path):
    from repro.data.io import load_pair_files, read_seqs
    fa = tmp_path / "a.fa"
    fa.write_text(">only\nACGT\n")
    fb = tmp_path / "b.fa"
    fb.write_text(">x\nAC\n>y\nGT\n")
    with pytest.raises(ValueError, match="disagree"):
        load_pair_files(str(fa), str(fb))
    bad = tmp_path / "bad.txt"
    bad.write_text("not a sequence file\n")
    with pytest.raises(ValueError, match="not FASTA or FASTQ"):
        read_seqs(str(bad))
    trunc = tmp_path / "trunc.fq"
    trunc.write_text("@q0\nACGT\n+\n")
    with pytest.raises(ValueError, match="truncated"):
        read_seqs(str(trunc))
