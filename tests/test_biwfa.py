"""BiWFA parity suite: ``trace_variant="bidir"`` must produce CIGARs that
re-score *exactly* to the forward (packed-backtrace) optimum — across all
three penalty models, the ref and ring backends, and a divergence grid —
including affine alignments whose optimal breakpoint falls inside a gap
run (the open/extend joint-state correction), empty/one-sided edges, and
budget-forced deep recursion."""
import numpy as np
import pytest

from repro.core import gotoh
from repro.core.engine import AlignmentEngine
from repro.core.scoring import Edit, GapAffine, GapLinear

ALPHA = np.frombuffer(b"ACGT", np.uint8)
MODELS = [GapAffine(4, 6, 2), GapLinear(4, 2), Edit()]
MODEL_IDS = ["affine", "linear", "edit"]
BACKENDS = ["ref", "ring"]


def _divergent_pairs(rng, n, L, div):
    """Pairs at ~``div`` divergence with multi-base insertion bursts and
    deletions — indel-heavy on purpose, so meets land inside gap runs."""
    ps, ts = [], []
    for _ in range(n):
        p = rng.choice(ALPHA, size=L).astype(np.uint8)
        t = []
        for c in p:
            r = rng.random()
            if r < div * 0.5:
                t.append(int(rng.choice(ALPHA)))
            elif r < div * 0.75:
                t.append(int(c))
                for _ in range(int(rng.integers(1, 4))):
                    t.append(int(rng.choice(ALPHA)))
            elif r < div:
                continue
            else:
                t.append(int(c))
        ps.append(p)
        ts.append(np.asarray(t, np.uint8))
    return ps, ts


def _assert_bidir_exact(eng, pen, ps, ts):
    """bidir scores == packed scores, and every bidir CIGAR re-scores to
    exactly that cost while consuming both sequences in full."""
    ref = eng.align(ps, ts, output="cigar")
    res = eng.align(ps, ts, output="cigar", trace_variant="bidir")
    np.testing.assert_array_equal(res.scores, ref.scores)
    for i, (p, t) in enumerate(zip(ps, ts)):
        p = np.frombuffer(p.encode(), np.uint8) if isinstance(p, str) else p
        t = np.frombuffer(t.encode(), np.uint8) if isinstance(t, str) else t
        cost, ci, cj, ok = gotoh.score_cigar(res.cigars[i], p, t, pen)
        assert ok, i
        assert ci == len(p) and cj == len(t), (i, ci, cj)
        assert cost == res.scores[i], (i, cost, res.scores[i])
    return res


# ------------------------------------------- model x backend x divergence --


# higher-divergence and ref-backend combos are exhaustive-coverage tier
# (the executable-cache misses dominate); the quick tier keeps ring x 3
# models x 2%, which already exercises every code path
_GRID = [pytest.param(d, p, b,
                      marks=([pytest.mark.slow]
                             if (b == "ref" or d > 0.02) else []),
                      id=f"{d}-{mid}-{b}")
         for d in (0.02, 0.10, 0.25)
         for p, mid in zip(MODELS, MODEL_IDS)
         for b in BACKENDS]


@pytest.mark.parametrize("div,pen,backend", _GRID)
def test_bidir_parity_recursive(rng, pen, backend, div):
    # trace_budget far below s*(n+m) forces the meet-and-recurse path on
    # every pair; zero driver fallbacks allowed — exactness must come from
    # the breakpoint math, not the packed safety net
    ps, ts = _divergent_pairs(rng, 6, 240, div)
    eng = AlignmentEngine(pen, backend=backend, trace_budget=1500)
    res = _assert_bidir_exact(eng, pen, ps, ts)
    assert res.stats.n_bidir_fallback == 0
    assert res.stats.n_meet_unmet == 0


@pytest.mark.parametrize("pen", MODELS, ids=MODEL_IDS)
def test_bidir_base_case_direct(rng, pen):
    # default budget: short pairs fit the packed traceback outright, so the
    # driver must base-case without any meet round and still match
    ps, ts = _divergent_pairs(rng, 8, 80, 0.10)
    eng = AlignmentEngine(pen, backend="ring")
    res = _assert_bidir_exact(eng, pen, ps, ts)
    assert res.stats.n_meet_unmet == 0


# ------------------------------------------------------- affine gap joins --


def test_affine_split_inside_gap_run(rng):
    # one long deletion dead-center: the midpoint meet lands *inside* the
    # run, so the I/D joint state must carry across the split (charging the
    # gap open exactly once) or the stitched cost comes out o too high
    pen = GapAffine(4, 6, 2)
    p = rng.choice(ALPHA, size=300).astype(np.uint8)
    t = np.concatenate([p[:140], p[200:]])           # 60-base deletion
    p2 = np.concatenate([p[:150], rng.choice(ALPHA, size=70).astype(np.uint8),
                         p[150:]])                   # 70-base insertion (text side)
    eng = AlignmentEngine(pen, backend="ring", trace_budget=900)
    res = _assert_bidir_exact(eng, pen, [p, p2], [t, p])
    assert res.stats.n_bidir_fallback == 0


def test_affine_gap_at_edges(rng):
    # leading/trailing gap runs exercise the begin/end boundary-state
    # seeding (open already charged by the parent on one side only)
    pen = GapAffine(4, 6, 2)
    core = rng.choice(ALPHA, size=200).astype(np.uint8)
    pad = rng.choice(ALPHA, size=40).astype(np.uint8)
    ps = [np.concatenate([pad, core]), core]
    ts = [core, np.concatenate([core, pad])]
    eng = AlignmentEngine(pen, backend="ring", trace_budget=700)
    _assert_bidir_exact(eng, pen, ps, ts)


# ------------------------------------------------------------------ edges --


@pytest.mark.parametrize("backend", BACKENDS)
def test_bidir_empty_and_one_sided(backend):
    pen = GapAffine(4, 6, 2)
    ps = ["", "ACGTACGTAC", "", "ACGT", "GATTACAGATTACA"]
    ts = ["", "", "TTTTTTTT", "ACGT", "GATTACAGATTACA"]
    eng = AlignmentEngine(pen, backend=backend, trace_budget=40)
    _assert_bidir_exact(eng, pen, ps, ts)


def test_bidir_streamed_submit(rng):
    # the per-submit seam: packed and bidir tickets interleaved in one
    # session, retired out of order via as_completed()
    pen = GapAffine(4, 6, 2)
    ps, ts = _divergent_pairs(rng, 10, 150, 0.10)
    eng = AlignmentEngine(pen, backend="ring", trace_budget=1200)
    with eng.stream(max_inflight_waves=2) as sess:
        tk_b = sess.submit(ps[:5], ts[:5], output="cigar",
                           trace_variant="bidir")
        tk_p = sess.submit(ps[5:], ts[5:], output="cigar")
        done = {t.index: t for t in sess.as_completed()}
    assert set(done) == {tk_b.index, tk_p.index}
    res_b, res_p = done[tk_b.index].result(), done[tk_p.index].result()
    for i in range(5):
        c, ci, cj, ok = gotoh.score_cigar(res_b.cigars[i], ps[i], ts[i], pen)
        assert ok and c == res_b.scores[i]
        assert ci == len(ps[i]) and cj == len(ts[i])
    oracle = [int(gotoh.gotoh_score_vec(p, t, pen.as_penalties()))
              for p, t in zip(ps, ts)]
    np.testing.assert_array_equal(res_b.scores, oracle[:5])
    np.testing.assert_array_equal(res_p.scores, oracle[5:])


def test_bidir_score_output_ignores_variant(rng):
    # trace_variant only governs tracebacks: score-only calls take the
    # plain wavefront path bit-for-bit
    ps, ts = _divergent_pairs(rng, 6, 120, 0.10)
    eng = AlignmentEngine(GapAffine(4, 6, 2), backend="ring",
                          trace_variant="bidir")
    a = eng.align(ps, ts, output="score")
    b = eng.align(ps, ts, output="score", trace_variant="packed")
    np.testing.assert_array_equal(a.scores, b.scores)


# -------------------------------------------------------- trace memory ----


@pytest.mark.slow
def test_bidir_trace_memory_below_packed(rng):
    # the headline: recursion keeps the resident trace high-water mark
    # well under the packed O(s^2) backtrace on a divergent-ish pair
    pen = GapAffine(4, 6, 2)
    ps, ts = _divergent_pairs(rng, 2, 1500, 0.08)
    eng = AlignmentEngine(pen, backend="ring", trace_budget=30000)
    ref = eng.align(ps, ts, output="cigar")
    res = _assert_bidir_exact(eng, pen, ps, ts)
    assert res.stats.peak_trace_bytes > 0
    assert res.stats.peak_trace_bytes < ref.stats.peak_trace_bytes / 4


# ------------------------------------------------- long-read sampler ------


def test_sampler_long_read_profile():
    from repro.data.reads import sample_from_reference
    ref = np.random.default_rng(11).choice(ALPHA, size=100000)
    kw = dict(read_len=5000, edit_frac=0.1, length_dist="lognormal",
              error_profile="ont", seed=5)
    a = sample_from_reference(ref, 30, **kw)
    b = sample_from_reference(ref, 30, **kw)
    for x, y in zip(a, b):             # deterministic per seed
        assert np.array_equal(x.read, y.read)
        assert (x.pos, x.strand, x.win_len) == (y.pos, y.strand, y.win_len)
    lens = np.array([r.win_len for r in a])
    assert lens.min() != lens.max()    # lognormal actually spreads
    for r in a:                        # ground truth window matches read len
        assert 0 <= r.pos <= len(ref) - r.win_len
    with pytest.raises(ValueError):
        sample_from_reference(ref, 1, error_profile="hifi")
    with pytest.raises(ValueError):
        sample_from_reference(ref, 1, length_dist="uniform")


def test_sampler_fixed_length_unchanged():
    from repro.data.reads import sample_from_reference
    ref = np.random.default_rng(12).choice(ALPHA, size=5000)
    reads = sample_from_reference(ref, 20, read_len=100, seed=3)
    assert all(r.win_len == 100 for r in reads)
