"""Pallas WFA kernel vs the pure-jnp oracle: shape/penalty/blocking sweeps.

Scores are integers, so the assertion is exact equality (no tolerance).
The kernel runs interpret=True on CPU (the TPU lowering is exercised
structurally by pallas_call + BlockSpec construction)."""
import numpy as np
import pytest

from repro.core.aligner import problem_bounds
from repro.core.penalties import DEFAULT, Penalties
from repro.data.reads import ReadPairSpec, generate_pairs
from repro.kernels.wfa import ref_scores, wfa_align_np

PENS = [DEFAULT, Penalties(1, 0, 1), Penalties(2, 3, 1), Penalties(5, 1, 1)]


def _regime(n_pairs, read_len, edit_frac, seed, pen):
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=n_pairs, read_len=read_len, edit_frac=edit_frac,
                     seed=seed))
    s_max, k_max = problem_bounds(pen, plen, tlen, edit_frac)
    return P, plen, T, tlen, s_max, k_max


@pytest.mark.parametrize("pen", PENS, ids=lambda p: f"x{p.x}o{p.o}e{p.e}")
@pytest.mark.parametrize("read_len,edit_frac", [(48, 0.05), (100, 0.02),
                                                (100, 0.04)])
def test_kernel_matches_ref(pen, read_len, edit_frac):
    P, plen, T, tlen, s_max, k_max = _regime(16, read_len, edit_frac, 3, pen)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=pen, s_max=s_max,
                                k_max=k_max))
    got = wfa_align_np(P, T, plen, tlen, pen=pen, s_max=s_max, k_max=k_max)
    np.testing.assert_array_equal(ref, got)


@pytest.mark.parametrize("n_pairs", [1, 3, 8, 19])
def test_kernel_pair_padding(n_pairs):
    """Batch sizes that do not divide the block size must still be exact."""
    P, plen, T, tlen, s_max, k_max = _regime(n_pairs, 60, 0.06, 11, DEFAULT)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                                k_max=k_max))
    got = wfa_align_np(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                       k_max=k_max)
    np.testing.assert_array_equal(ref, got)


@pytest.mark.parametrize("block_pairs", [8, 16])
def test_kernel_block_size_invariance(block_pairs):
    P, plen, T, tlen, s_max, k_max = _regime(32, 80, 0.05, 5, DEFAULT)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                                k_max=k_max))
    got = wfa_align_np(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                       k_max=k_max, block_pairs=block_pairs)
    np.testing.assert_array_equal(ref, got)


def test_kernel_ragged_lengths():
    """Mates of different lengths within one block."""
    rng = np.random.default_rng(7)
    pats, txts = [], []
    for i in range(12):
        L = int(rng.integers(8, 90))
        p = rng.integers(65, 69, size=L, dtype=np.int32)
        cut = int(rng.integers(0, 6))
        t = np.concatenate([p[cut:], rng.integers(65, 69, size=cut,
                                                  dtype=np.int32)])
        pats.append(p)
        txts.append(t)
    Lp = max(len(p) for p in pats)
    Lt = max(len(t) for t in txts)
    P = np.zeros((12, Lp), np.int32)
    T = np.zeros((12, Lt), np.int32)
    plen = np.array([len(p) for p in pats], np.int32)
    tlen = np.array([len(t) for t in txts], np.int32)
    for i in range(12):
        P[i, : plen[i]] = pats[i]
        T[i, : tlen[i]] = txts[i]
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, None)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                                k_max=k_max))
    got = wfa_align_np(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                       k_max=k_max)
    np.testing.assert_array_equal(ref, got)


def test_kernel_score_cap():
    """Pairs over the score budget must come back -1, exactly like the ref."""
    P = np.full((8, 16), 65, np.int32)
    T = np.full((8, 16), 67, np.int32)   # all-mismatch
    lens = np.full((8,), 16, np.int32)
    ref = np.asarray(ref_scores(P, T, lens, lens, pen=DEFAULT, s_max=10,
                                k_max=4))
    got = wfa_align_np(P, T, lens, lens, pen=DEFAULT, s_max=10, k_max=4)
    np.testing.assert_array_equal(ref, got)
    assert (got == -1).all()


def test_kernel_empty_and_tiny():
    P = np.zeros((4, 4), np.int32)
    T = np.zeros((4, 4), np.int32)
    P[1, 0] = 65
    T[2, 0] = 66
    plen = np.array([0, 1, 0, 1], np.int32)
    tlen = np.array([0, 1, 1, 0], np.int32)
    P[3, 0] = 67
    s_max, k_max = problem_bounds(DEFAULT, plen, tlen, None)
    ref = np.asarray(ref_scores(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                                k_max=k_max))
    got = wfa_align_np(P, T, plen, tlen, pen=DEFAULT, s_max=s_max,
                       k_max=k_max)
    np.testing.assert_array_equal(ref, got)
