"""AlignmentSession: async submission, pipelined dispatch, out-of-order
gather — parity with the blocking path and the Gotoh oracle, backpressure,
recovery recycling, exception propagation, and zero-retrace steady state."""
import threading
import time

import numpy as np
import pytest
from conftest import gotoh_oracle as _oracle
from conftest import random_pairs as _random_pairs

from repro.core.backends import register_backend, unregister_backend
from repro.core.engine import AlignmentEngine
from repro.core.penalties import DEFAULT
from repro.core.session import AlignmentSession
from repro.core.wavefront import wfa_scores


# ------------------------------------------------------------- parity ----


def test_stream_matches_sync_and_oracle(rng):
    # mixed lengths -> multiple buckets -> out-of-order wave completion
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    chunks = [_random_pairs(rng, 12, lo=5, hi=150) for _ in range(3)]
    sync = [eng.align(p, t) for p, t in chunks]

    with eng.stream(max_inflight_waves=2) as sess:
        tickets = [sess.submit(p, t) for p, t in chunks]
        for tk, sr, (p, t) in zip(tickets, sync, chunks):
            res = tk.result()
            np.testing.assert_array_equal(res.scores, sr.scores)
            np.testing.assert_array_equal(res.scores, _oracle(p, t))
    assert sess.stats.n_submits == 3
    assert sess.stats.n_pairs == 36


def test_out_of_order_gather_covers_all_tickets(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    chunks = [_random_pairs(rng, 8, lo=5, hi=120) for _ in range(4)]
    with eng.stream(max_inflight_waves=3) as sess:
        tickets = [sess.submit(p, t) for p, t in chunks]
        seen = []
        for tk in sess.as_completed():
            assert tk.done()
            seen.append(tk.index)
        assert sorted(seen) == [tk.index for tk in tickets]
    for tk, (p, t) in zip(tickets, chunks):
        np.testing.assert_array_equal(tk.result().scores, _oracle(p, t))


def test_results_iterates_in_submission_order(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    chunks = [_random_pairs(rng, 6, lo=20, hi=60) for _ in range(3)]
    with eng.stream() as sess:
        for p, t in chunks:
            sess.submit(p, t)
        out = list(sess.results())
    assert len(out) == 3
    for res, (p, t) in zip(out, chunks):
        np.testing.assert_array_equal(res.scores, _oracle(p, t))


def test_stream_with_cigar(rng):
    from repro.core.gotoh import score_cigar
    pats, txts = _random_pairs(rng, 12, lo=5, hi=100)
    eng = AlignmentEngine(backend="ref", edit_frac=0.1, with_cigar=True)
    with eng.stream() as sess:
        res = sess.submit(pats, txts).result()
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    for i, (p, t) in enumerate(zip(pats, txts)):
        cost, ci, cj, ok = score_cigar(
            res.cigars[i], np.frombuffer(p.encode(), np.uint8),
            np.frombuffer(t.encode(), np.uint8), DEFAULT)
        assert ok and cost == res.scores[i]
        assert ci == len(p) and cj == len(t)


# ------------------------------------------------------- backpressure ----


def test_backpressure_bounds_inflight_waves(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05,
                          bucket_by_length=False)
    pats, txts = _random_pairs(rng, 64, lo=40, hi=60)
    with eng.stream(max_inflight_waves=2, wave_pairs=4) as sess:
        for lo in range(0, 64, 8):
            sess.submit(pats[lo:lo + 8], txts[lo:lo + 8])
        sess.drain()
    st = sess.stats
    assert st.n_waves >= 16                  # genuinely multi-wave
    assert st.peak_inflight <= 2             # the bound was respected
    assert st.peak_inflight == 2             # ... and the pipeline filled
    scores = np.concatenate([t.result().scores for t in sess.tickets])
    np.testing.assert_array_equal(scores, _oracle(pats, txts))


def test_invalid_session_params():
    eng = AlignmentEngine(backend="ring")
    with pytest.raises(ValueError, match="max_inflight_waves"):
        AlignmentSession(eng, max_inflight_waves=0)
    with pytest.raises(ValueError, match="wave_pairs"):
        AlignmentSession(eng, wave_pairs=0)


# ---------------------------------------------------- overflow recycle ----


def test_overflow_recycles_into_recovery_queue(rng):
    # divergent pairs overflow the E budget; the wave retires anyway and
    # the stragglers re-run with exact bounds before the ticket completes
    near_p, near_t = _random_pairs(rng, 6, lo=24, hi=32)
    pats = near_p + ["A" * 24, "G" * 18]
    txts = near_t + ["T" * 24, "C" * 21]
    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    with eng.stream(max_inflight_waves=2) as sess:
        res = sess.submit(pats, txts).result()
    assert res.stats.n_overflow >= 2
    assert res.stats.n_recovered == res.stats.n_overflow
    assert any(b.recovery for b in res.stats.buckets)
    assert (res.scores >= 0).all()
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    # session-level aggregates match the single ticket
    assert sess.stats.n_overflow == res.stats.n_overflow
    assert sess.stats.n_recovered == res.stats.n_recovered


def test_adaptive_off_stream_leaves_overflow_unresolved():
    eng = AlignmentEngine(backend="ring", edit_frac=0.02, adaptive=False)
    with eng.stream() as sess:
        res = sess.submit(["A" * 40], ["T" * 40]).result()
    assert res.scores[0] == -1
    assert res.stats.n_overflow == 1
    assert res.stats.n_recovered == 0


# ------------------------------------------------- empty / duplicate ----


def test_empty_submit_completes_immediately():
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    with eng.stream() as sess:
        tk = sess.submit([], [])
        assert tk.done()
        res = tk.result()
    assert res.scores.shape == (0,)
    assert res.stats.n_pairs == 0


def test_duplicate_submits_are_independent(rng):
    pats, txts = _random_pairs(rng, 8, lo=20, hi=60)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    with eng.stream(max_inflight_waves=2) as sess:
        t1 = sess.submit(pats, txts)
        t2 = sess.submit(pats, txts)
        r1, r2 = t1.result(), t2.result()
    assert t1 is not t2
    np.testing.assert_array_equal(r1.scores, r2.scores)
    np.testing.assert_array_equal(r1.scores, _oracle(pats, txts))


# ------------------------------------------------- failure semantics ----


def test_backend_runtime_failure_propagates(rng):
    import jax
    import jax.numpy as jnp

    def _boom(scores):
        raise RuntimeError("injected backend failure")

    @register_backend("boom")
    def _boom_backend(pattern, text, plen, tlen, *, pen, s_max, k_max):
        res = wfa_scores(pattern, text, plen, tlen, pen=pen, s_max=s_max,
                         k_max=k_max)
        score = jax.pure_callback(
            _boom, jax.ShapeDtypeStruct(res.score.shape, jnp.int32),
            res.score)
        return res._replace(score=score)

    try:
        pats, txts = _random_pairs(rng, 4, lo=20, hi=40)
        eng = AlignmentEngine(backend="boom", edit_frac=0.05)
        sess = eng.stream(max_inflight_waves=2)
        sess.submit(pats, txts)      # dispatch succeeds; failure is async
        with pytest.raises(Exception):
            sess.drain()
        # the session is poisoned: no further submissions accepted
        with pytest.raises(RuntimeError, match="session failed"):
            sess.submit(pats, txts)
    finally:
        unregister_backend("boom")


def test_submit_after_close_raises(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    sess = eng.stream()
    sess.close()
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit(["ACGT"], ["ACGT"])


# ------------------------------------------------- steady-state cache ----


def test_zero_retraces_across_multiwave_steady_state(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05, chunk_pairs=8)
    chunks = [_random_pairs(rng, 16, lo=40, hi=120) for _ in range(3)]
    with eng.stream(max_inflight_waves=2) as sess:
        for p, t in chunks:
            sess.submit(p, t)
    warm = sess.stats
    assert warm.n_traces == warm.cache_misses > 0

    # steady state: same serving shapes, fresh session -> fully cached
    with eng.stream(max_inflight_waves=2) as sess2:
        for p, t in chunks:
            sess2.submit(p, t)
        for tk in sess2.as_completed():
            assert (tk.result().scores >= 0).all()
    assert sess2.stats.n_traces == 0
    assert sess2.stats.cache_misses == 0
    assert sess2.stats.cache_hits > 0
    assert sess2.stats.n_waves > 1           # genuinely multi-wave


def test_sync_align_is_session_backed(rng):
    # the blocking path routes through the same session machinery
    pats, txts = _random_pairs(rng, 10, lo=10, hi=80)
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    res = eng.align(pats, txts)
    np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    assert res.stats.n_pairs == 10


# ------------------------------------------------- dispatch hooks -------


def test_backend_dispatch_hook_routes_every_wave(rng):
    calls = []

    def _spy_dispatch(fn, *arrays):
        calls.append(arrays[0].shape)
        return fn(*arrays)

    register_backend(
        "spy",
        lambda pattern, text, plen, tlen, *, pen, s_max, k_max:
            wfa_scores(pattern, text, plen, tlen, pen=pen, s_max=s_max,
                       k_max=k_max),
        dispatch=_spy_dispatch)
    try:
        pats, txts = _random_pairs(rng, 12, lo=20, hi=40)
        eng = AlignmentEngine(backend="spy", edit_frac=0.05)
        with eng.stream(wave_pairs=4) as sess:
            res = sess.submit(pats, txts).result()
        assert len(calls) >= 3               # one hook call per wave
        np.testing.assert_array_equal(res.scores, _oracle(pats, txts))
    finally:
        unregister_backend("spy")


# ---------------------------------------------- poll / timeout probes ---


def test_poll_is_nonblocking_and_drains_backlog(rng, monkeypatch):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    pats, txts = _random_pairs(rng, 6, lo=20, hi=50)
    with eng.stream(max_inflight_waves=2) as sess:
        tk = sess.submit(pats, txts)
        # a "still running" wave (readiness probe forced False) must not
        # be gathered: poll returns nothing and never blocks
        monkeypatch.setattr(AlignmentSession, "_wave_ready",
                            staticmethod(lambda wave: False))
        assert sess.poll() == []
        assert not tk.done()
        monkeypatch.undo()
        deadline = time.monotonic() + 30
        done = []
        while not done and time.monotonic() < deadline:
            done = sess.poll()
        assert done == [tk] and tk.done()
        assert sess.poll() == []             # backlog yielded exactly once
    np.testing.assert_array_equal(tk.result().scores, _oracle(pats, txts))


def test_poll_flushes_recovery_stragglers(rng):
    # a lone over-budget pair must not wait for a full recovery wave:
    # poll() re-dispatches queued overflow as soon as the pipe is empty
    eng = AlignmentEngine(backend="ring", edit_frac=0.02)
    with eng.stream() as sess:
        tk = sess.submit(["A" * 40], ["T" * 40])
        deadline = time.monotonic() + 30
        while not tk.done() and time.monotonic() < deadline:
            sess.poll()
        assert tk.done()
    res = tk.result()
    assert res.stats.n_overflow == 1 and res.stats.n_recovered == 1
    np.testing.assert_array_equal(res.scores, _oracle(["A" * 40],
                                                      ["T" * 40]))


def test_as_completed_timeout_raises_with_diagnostics(rng, monkeypatch):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    pats, txts = _random_pairs(rng, 4, lo=20, hi=40)
    with eng.stream(max_inflight_waves=2) as sess:
        sess.submit(pats, txts)
        # freeze the pipeline: the wave never reports ready, so the
        # deadline must fire instead of blocking forever
        monkeypatch.setattr(AlignmentSession, "_wave_ready",
                            staticmethod(lambda wave: False))
        t0 = time.monotonic()
        with pytest.raises(TimeoutError,
                           match=r"wave\(s\) in flight .*ticket 0"):
            list(sess.as_completed(timeout=0.2))
        assert 0.1 < time.monotonic() - t0 < 10
        monkeypatch.undo()
        for tk in sess.as_completed(timeout=60):   # recovers after unfreeze
            np.testing.assert_array_equal(tk.result().scores,
                                          _oracle(pats, txts))


# ------------------------------------------------- thread safety --------


def test_concurrent_submit_and_result_from_two_threads(rng):
    """Two producer threads share one session (the repro.serve contract):
    every ticket resolves with oracle scores, stats account every pair."""
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    chunks = [_random_pairs(np.random.default_rng(i), 6, lo=20, hi=80)
              for i in range(8)]
    out = {}
    errors = []

    def _producer(which):
        try:
            for i in range(which, 8, 2):
                p, t = chunks[i]
                out[i] = sess.submit(p, t).result()
        except BaseException as e:              # noqa: BLE001
            errors.append(e)

    with eng.stream(max_inflight_waves=2) as sess:
        threads = [threading.Thread(target=_producer, args=(w,))
                   for w in (0, 1)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errors
    assert sorted(out) == list(range(8))
    for i, (p, t) in enumerate(chunks):
        np.testing.assert_array_equal(out[i].scores, _oracle(p, t))
    assert sess.stats.n_submits == 8
    assert sess.stats.n_pairs == 48


# ------------------------------------------- occupancy / padding stats --


def test_wave_occupancy_counters(rng):
    # 5 equal-length pairs quantize to a 6-row device batch (3/4 of the
    # next pow2): the padding is counted, not hidden
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    pats = ["ACGTACGTACGTACGTACGT"] * 5
    with eng.stream() as sess:
        res = sess.submit(pats, pats).result()
    st = res.stats
    assert st.rows_real == 5
    assert st.rows_padded == 6
    assert st.wave_occupancy == pytest.approx(5 / 6)
    assert st.padding_waste_frac == pytest.approx(1 / 6)
    # session aggregates match the single ticket
    assert sess.stats.rows_real == 5 and sess.stats.rows_padded == 6


def test_occupancy_is_one_for_full_quantized_waves(rng):
    eng = AlignmentEngine(backend="ring", edit_frac=0.05)
    pats = ["ACGT" * 8] * 8
    with eng.stream(wave_pairs=8) as sess:
        res = sess.submit(pats, pats).result()
    assert res.stats.rows_real == res.stats.rows_padded == 8
    assert res.stats.wave_occupancy == 1.0
    assert res.stats.padding_waste_frac == 0.0


# ------------------------------------------------- deprecated shims -----


def test_wfaligner_shim_warns_deprecation():
    from repro.core.aligner import WFAligner
    with pytest.warns(DeprecationWarning, match="AlignmentEngine"):
        WFAligner(backend="ring")


def test_pim_shim_warns_deprecation():
    import warnings
    from repro.core.aligner import WFAligner
    from repro.core.pim import PIMBatchAligner
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        al = WFAligner(backend="ring")
    with pytest.warns(DeprecationWarning, match="AlignmentSession"):
        PIMBatchAligner(al)


# ------------------------------------------------- wall-clock overlap ---


@pytest.mark.slow
def test_streamed_wall_clock_not_worse_than_sync():
    """Acceptance: streamed >= sync throughput on the paper workload
    (8192 pairs, 100bp, E=2%), identical scores."""
    import time
    from repro.configs import wfa_paper
    from repro.data.reads import ReadPairSpec, generate_pairs

    n, chunk = 8192, 512
    P, plen, T, tlen = generate_pairs(
        ReadPairSpec(n_pairs=n, read_len=100, edit_frac=0.02, seed=2))
    eng = AlignmentEngine(wfa_paper.pen, backend="ring", edit_frac=0.02,
                          chunk_pairs=chunk)
    eng.align_packed(P, plen, T, tlen)       # warm the executable cache

    def sync_once():
        t0 = time.perf_counter()
        res = eng.align_packed(P, plen, T, tlen)
        return res.scores, time.perf_counter() - t0

    def stream_once():
        out = np.empty((n,), np.int32)
        t0 = time.perf_counter()
        with eng.stream(max_inflight_waves=4) as sess:
            offs = {}
            for lo in range(0, n, chunk):
                tk = sess.submit_packed(P[lo:lo + chunk], plen[lo:lo + chunk],
                                        T[lo:lo + chunk], tlen[lo:lo + chunk])
                offs[tk.index] = lo
            for tk in sess.as_completed():
                offset = offs[tk.index]
                out[offset:offset + tk.n_pairs] = tk.result().scores
        return out, time.perf_counter() - t0

    # interleaved best-of-4 so drifting machine load hits both modes alike
    sync_scores = None
    t_sync = t_stream = float("inf")
    for _ in range(4):
        scores, t_s = sync_once()
        sync_scores = scores if sync_scores is None else sync_scores
        streamed, t_p = stream_once()
        np.testing.assert_array_equal(streamed, sync_scores)
        t_sync = min(t_sync, t_s)
        t_stream = min(t_stream, t_p)
    # identical hardware, identical work: pipelining must not cost wall
    # clock (generous scheduling-noise headroom for loaded 2-core CI boxes)
    assert t_stream <= t_sync * 1.25, (t_stream, t_sync)
