"""Straggler detection, elastic remesh planning, sharding rules, data
pipeline determinism, and gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.reads import ReadPairSpec, generate_pairs, generate_shard
from repro.data.tokens import TokenStreamSpec, batch_for_step
from repro.distributed.compat import make_mesh as compat_make_mesh
from repro.distributed.fault import (HeartbeatRegistry, StragglerMonitor,
                                     plan_elastic_mesh)
from repro.distributed.sharding import (constrain, sharding_for, spec_entry,
                                        split_annotations, tree_shardings,
                                        use_mesh, ann)
from repro.optim import compression
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------- fault ----


def test_straggler_detection():
    mon = StragglerMonitor(n_workers=8, factor=2.0)
    for step in range(4):
        for w in range(8):
            mon.record(w, 1.0 if w != 5 else 4.0)
    assert mon.stragglers() == [5]
    plan = mon.reassignment()
    moved = [s for ss in plan.values() for s in ss]
    assert moved == [5]
    assert all(w != 5 for w in plan)


def test_straggler_none_when_uniform():
    mon = StragglerMonitor(n_workers=4)
    for w in range(4):
        mon.record(w, 1.0)
    assert mon.stragglers() == []


def test_heartbeat_dead_detection():
    hb = HeartbeatRegistry(n_workers=3, timeout_s=10.0)
    now = 1000.0
    for w in range(3):
        hb.ping(w, at=now)
    assert hb.dead(now + 5) == []
    hb.ping(0, at=now + 20)
    hb.ping(2, at=now + 20)
    assert hb.dead(now + 20) == [1]
    assert hb.healthy_count(now + 20) == 2


def test_elastic_mesh_plans():
    shape, axes = plan_elastic_mesh(512, model_parallel=16, pods=2)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose 40 chips of one pod -> dp shrinks to the next power of two
    shape, axes = plan_elastic_mesh(472, model_parallel=16, pods=2)
    assert shape == (2, 8, 16)
    shape, axes = plan_elastic_mesh(256, model_parallel=16, pods=1)
    assert shape == (16, 16) and axes == ("data", "model")
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16, pods=1)


# ---------------------------------------------------------- sharding ----


def _mesh2():
    n = jax.device_count()
    return compat_make_mesh((1, n), ("data", "model"))


def test_spec_entry_drops_nondividing_axes():
    mesh = _mesh2()
    # vocab 151936 is not divisible by most mesh sizes times anything odd;
    # with 1-device axes everything degrades to None
    assert spec_entry(mesh, 7, "heads") in (None, "model")


def test_sharding_for_and_constrain_noop():
    mesh = _mesh2()
    s = sharding_for(mesh, (8, 16), ("batch", "heads"))
    assert isinstance(s.spec, P)
    x = jnp.ones((4, 4))
    assert constrain(x, None, None) is x  # no ambient mesh -> no-op
    with use_mesh(mesh):
        y = constrain(x, "batch", None)
        assert y.shape == x.shape


def test_split_annotations_and_tree_shardings():
    mesh = _mesh2()
    tree = {"a": ann(jnp.ones((4, 6)), "batch", None),
            "nested": {"b": ann(jnp.ones((6,)), "ff")}}
    params, axes = split_annotations(tree)
    assert params["a"].shape == (4, 6) and axes["a"] == ("batch", None)
    sh = tree_shardings(mesh, params, axes)
    assert sh["a"].spec == P(None, None) or isinstance(sh["a"].spec, P)


# -------------------------------------------------------------- data ----


def test_reads_deterministic():
    spec = ReadPairSpec(n_pairs=16, read_len=50, edit_frac=0.1, seed=9)
    a = generate_pairs(spec)
    b = generate_pairs(spec)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_reads_edit_budget():
    """Mates differ by at most ceil(E*L) edits (verified via edit distance)."""
    from repro.core.gotoh import gotoh_score_vec
    from repro.core.penalties import Penalties
    spec = ReadPairSpec(n_pairs=12, read_len=60, edit_frac=0.1, seed=2)
    P_, plen, T, tlen = generate_pairs(spec)
    budget = int(np.ceil(spec.edit_frac * spec.read_len))
    for i in range(12):
        d = gotoh_score_vec(P_[i, : plen[i]], T[i, : tlen[i]],
                            Penalties(1, 0, 1))
        assert d <= budget, (i, d, budget)


def test_read_shards_deterministic():
    spec = ReadPairSpec(n_pairs=64, read_len=40, seed=4)
    s0a = generate_shard(spec, 0, 4)
    s0b = generate_shard(spec, 0, 4)
    s1 = generate_shard(spec, 1, 4)
    np.testing.assert_array_equal(s0a[0], s0b[0])
    assert not np.array_equal(s0a[0], s1[0])


def test_token_stream_restart_contract():
    spec = TokenStreamSpec(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    a = batch_for_step(spec, 5)
    b = batch_for_step(spec, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(spec, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # sharded regeneration composes to the same data independent of workers
    sh0 = batch_for_step(spec, 5, shard=0, n_shards=2)
    assert sh0["tokens"].shape == (4, 32)


def test_targets_are_shifted_tokens():
    spec = TokenStreamSpec(vocab_size=512, seq_len=16, global_batch=2, seed=1)
    b = batch_for_step(spec, 0)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    assert (b["targets"][:, -1] == -1).all()


# ------------------------------------------------------- compression ----


def test_bf16_roundtrip_close():
    g = {"w": jnp.linspace(-3, 3, 1024, dtype=jnp.float32)}
    d = compression.decompress_bf16(compression.compress_bf16(g))
    np.testing.assert_allclose(np.asarray(d["w"]), np.asarray(g["w"]),
                               rtol=8e-3, atol=1e-6)


def test_int8_roundtrip_bounded():
    g = {"w": jax.random.normal(jax.random.key(0), (512,), jnp.float32)}
    d = compression.decompress_int8(compression.compress_int8(g))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(d["w"] - g["w"]))) <= scale * 0.5 + 1e-6


def test_error_feedback_accumulates_exactly():
    """EF: the *sum* of transmitted grads tracks the sum of true grads."""
    key = jax.random.key(1)
    res = compression.init_residual({"w": jnp.zeros((256,))})
    total_true = jnp.zeros((256,))
    total_sent = jnp.zeros((256,))
    for i in range(20):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (256,), jnp.float32)}
        sent, res = compression.error_feedback_int8(g, res)
        total_true = total_true + g["w"]
        total_sent = total_sent + sent["w"]
    # residual bounds the drift: |sum_true - sum_sent| == |residual| <= scale
    drift = float(jnp.max(jnp.abs(total_true - total_sent)))
    assert drift <= float(jnp.max(jnp.abs(res["w"]))) + 1e-5
