"""Synthesize the zamba2 fused-vs-split A/B rows for the perf table from the
two generations of dry-run records (campaign3 = fused baseline, campaign4 =
split default) kept in the append-only cells.jsonl."""
import json

gens = []
for line in open("results/dryrun/cells.jsonl"):
    r = json.loads(line)
    if (r.get("arch"), r.get("shape"), r.get("mesh"), r.get("pass")) == \
            ("zamba2-7b", "train_4k", "pod1-16x16", "roofline") \
            and r.get("status") == "ok":
        gens.append(r)
assert len(gens) >= 2, f"need both generations, have {len(gens)}"
for name, rec in (("zamba2_train_fusedproj", gens[-2]),
                  ("zamba2_train_splitproj", gens[-1])):
    row = {"experiment": name, "status": "ok",
           "timestamp": rec["timestamp"], "source": "cells.jsonl",
           "n_devices": rec["n_devices"],
           "model_flops": rec["model_flops"],
           "flops_per_device": rec["flops_per_device"],
           "bytes_per_device": rec["bytes_per_device"],
           "collectives": rec["collectives"],
           "compile_s": rec["compile_s"]}
    with open("results/perf/experiments.jsonl", "a") as f:
        f.write(json.dumps(row) + "\n")
    print(name, f"coll={rec['collectives']['total']:.3e}")
